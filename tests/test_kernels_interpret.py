"""Interpret-mode resolution: env-driven, no import-time hardcoding.

The kernel wrappers historically pinned ``INTERPRET = True`` at import time,
which silently interpreted on real TPUs; ``repro.kernels.interpret_default``
resolves per call from ``REPRO_PALLAS_INTERPRET`` (operator override) or the
active JAX backend.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import kernels
from repro.kernels.ocs_quant import ops as q_ops


@pytest.mark.parametrize("value,expect", [
    ("1", True), ("true", True), ("YES", True), ("on", True),
    ("0", False), ("false", False), ("No", False), ("off", False),
    (" 1 ", True),
])
def test_env_override_resolves_both_settings(monkeypatch, value, expect):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", value)
    assert kernels.interpret_default() is expect


def test_invalid_env_value_raises(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "maybe")
    with pytest.raises(ValueError):
        kernels.interpret_default()


def test_default_follows_backend(monkeypatch):
    """Without the env var, CPU/GPU hosts interpret; a TPU backend would
    compile (asserted via the same code path the wrappers call)."""
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    import jax
    assert kernels.interpret_default() is (jax.default_backend() != "tpu")


def test_wrappers_read_resolution_at_call_time(monkeypatch):
    """Flipping the env var takes effect without re-import: with interpret
    forced on, the wrapped kernels still run (this host has no TPU, so the
    hardcoded-False failure mode would raise at lowering)."""
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    x = jnp.linspace(-2.0, 2.0, 64 * 64, dtype=jnp.float32).reshape(64, 64)
    codes = q_ops.encode(x, 8)
    assert codes.dtype == jnp.uint8
    from repro.core import quantize as qz
    assert np.array_equal(np.asarray(codes), np.asarray(qz.quantize(x, 8)))
