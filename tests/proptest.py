"""Mini property-test harness (hypothesis is not installable offline).

``sweep`` runs a property over a deterministic sample of generated cases and
reports the failing seed/case on error — the shrinking-free essentials of
property-based testing.
"""

from __future__ import annotations

import itertools
from typing import Callable, Iterable, Sequence

import numpy as np


def seeds(n: int, base: int = 0) -> Iterable[int]:
    return range(base, base + n)


def sweep(fn: Callable, cases: Sequence, label: str = "case"):
    """Run fn(case) for each case; annotate failures with the case."""
    for case in cases:
        try:
            fn(case)
        except AssertionError as e:
            raise AssertionError(f"[{label}={case!r}] {e}") from e


def random_floats(seed: int, shape, dtype=np.float32, scale: float = 10.0,
                  specials: bool = True) -> np.ndarray:
    rng = np.random.default_rng(seed)
    x = (rng.standard_normal(shape) * scale).astype(dtype)
    if specials and x.size >= 8:
        flat = x.reshape(-1)
        flat[0] = 0.0
        flat[1] = -0.0
        flat[2] = np.finfo(dtype).max / 2
        flat[3] = -np.finfo(dtype).max / 2
        flat[4] = np.finfo(dtype).tiny
        flat[5] = -np.finfo(dtype).tiny
    return x


def grid(**kwargs):
    keys = list(kwargs)
    for combo in itertools.product(*(kwargs[k] for k in keys)):
        yield dict(zip(keys, combo))
