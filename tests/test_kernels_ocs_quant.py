"""Per-kernel allclose sweep: monotone code kernel vs core.quantize oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import grid, random_floats, sweep
from repro.kernels.ocs_quant import ocs_quant as K
from repro.kernels.ocs_quant import ops as O
from repro.kernels.ocs_quant import ref as R


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bits", [8, 16])
def test_encode_decode_sweep(dtype, bits):
    if dtype == jnp.bfloat16 and bits > 16:
        pytest.skip("bf16 caps at 16-bit codes")

    def prop(case):
        x = jnp.asarray(random_floats(case["seed"], (case["m"], case["k"]),
                                      scale=case["scale"]), dtype)
        c = K.encode(x, bits)
        cr = R.encode(x, bits)
        assert jnp.array_equal(c, cr), "codes"
        d = K.decode(c, bits, dtype)
        dr = R.decode(cr, bits, dtype)
        assert jnp.array_equal(d, dr), "decoded values"
    sweep(prop, list(grid(m=[64, 256], k=[128], scale=[0.1, 100.0],
                          seed=[0, 1])))


def test_straight_through_grad():
    x = jnp.asarray(random_floats(0, (64, 64), specials=False))
    g = jax.grad(lambda v: jnp.sum(O.quantize_st(v, 8) * 3.0))(x)
    assert np.allclose(np.asarray(g), 3.0)


def test_code_width_selection():
    x = jnp.ones((64, 64), jnp.float32)
    assert K.encode(x, 8).dtype == jnp.uint8
    assert K.encode(x, 16).dtype == jnp.uint16
