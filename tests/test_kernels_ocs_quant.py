"""Monotone code kernel vs core.quantize oracle, via the parity harness."""

import jax
import jax.numpy as jnp
import numpy as np

from kernel_parity import ParityOp, check
from proptest import grid, random_floats
from repro.kernels.ocs_quant import ocs_quant as K
from repro.kernels.ocs_quant import ops as O
from repro.kernels.ocs_quant import ref as R

_CASES = list(grid(m=[64, 256], k=[128], scale=[0.1, 100.0], seed=[0, 1],
                   bits=[8, 16], dtype=[jnp.float32, jnp.bfloat16]))


def _x(case):
    return jnp.asarray(random_floats(case["seed"], (case["m"], case["k"]),
                                     scale=case["scale"]), case["dtype"])


ENCODE = ParityOp(
    name="ocs_quant_encode",
    make=lambda case: (_x(case), case["bits"]),
    kernel=K.encode,
    reference=R.encode,
    cases=_CASES,
)

# decode parity over the codes the reference encoder emits (same stream both
# sides, so decode is exercised on exactly the reachable code values)
DECODE = ParityOp(
    name="ocs_quant_decode",
    make=lambda case: (R.encode(_x(case), case["bits"]), case["bits"],
                       case["dtype"]),
    kernel=K.decode,
    reference=R.decode,
    cases=_CASES,
)


def test_encode_parity():
    check(ENCODE)


def test_decode_parity():
    check(DECODE)


def test_straight_through_grad():
    x = jnp.asarray(random_floats(0, (64, 64), specials=False))
    g = jax.grad(lambda v: jnp.sum(O.quantize_st(v, 8) * 3.0))(x)
    assert np.allclose(np.asarray(g), 3.0)


def test_code_width_selection():
    x = jnp.ones((64, 64), jnp.float32)
    assert K.encode(x, 8).dtype == jnp.uint8
    assert K.encode(x, 16).dtype == jnp.uint16
