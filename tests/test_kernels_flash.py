"""Per-kernel allclose sweep: flash attention vs materialized-softmax oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import grid, random_floats, sweep
from repro.kernels.flash_attention import flash_attention as K
from repro.kernels.flash_attention import ops as O
from repro.kernels.flash_attention import ref as R


@pytest.mark.parametrize("causal", [True, False])
def test_flash_sweep(causal):
    def prop(case):
        b, h, hkv, s, d = 1, case["h"], case["hkv"], case["s"], 64
        rng = np.random.default_rng(case["seed"])
        q = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
        o = K.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
        orf = R.flash_attention(q, k, v, causal=causal)
        err = float(jnp.max(jnp.abs(o - orf)))
        assert err < 3e-5, f"err={err}"
    sweep(prop, list(grid(h=[4], hkv=[1, 2, 4], s=[128, 192],
                          seed=[0, 1])))


def test_flash_bf16():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    o = K.flash_attention(q, k, v, causal=True)
    orf = R.flash_attention(q, k, v, causal=True)
    assert float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                 - orf.astype(jnp.float32)))) < 0.05


def test_flash_grad_via_recompute_bwd():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 64, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1, 64, 32)), jnp.float32)

    def loss_kernel(q, k, v):
        return jnp.sum(O.flash_attention(q, k, v, True) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(R.flash_attention(q, k, v, True) ** 2)

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-4
