"""Flash attention vs materialized-softmax oracle, via the parity harness.

Accumulation order differs between the streaming kernel and the oracle, so
forward parity is tolerance-based (per-dtype ``atol`` in the case dicts);
vjp parity runs through ``ops.flash_attention`` (the recompute backward)
against the oracle's autodiff.
"""

import jax.numpy as jnp
import numpy as np

from kernel_parity import ParityOp, check
from proptest import grid
from repro.kernels.flash_attention import flash_attention as K
from repro.kernels.flash_attention import ops as O
from repro.kernels.flash_attention import ref as R


def _qkv(case):
    rng = np.random.default_rng(case["seed"])
    b, h, hkv, s, d = 1, case["h"], case["hkv"], case["s"], case["d"]
    q = jnp.asarray(rng.standard_normal((b, h, s, d)), case["dtype"])
    k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), case["dtype"])
    v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), case["dtype"])
    return q, k, v, case["causal"]


FORWARD = ParityOp(
    name="flash_forward",
    make=_qkv,
    kernel=lambda q, k, v, causal: K.flash_attention(
        q, k, v, causal=causal, block_q=64, block_k=64),
    reference=lambda q, k, v, causal: R.flash_attention(q, k, v,
                                                        causal=causal),
    cases=(list(grid(h=[4], hkv=[1, 2, 4], s=[128, 192], d=[64],
                     seed=[0, 1], causal=[True, False],
                     dtype=[jnp.float32], atol=[3e-5]))
           + list(grid(h=[2], hkv=[2], s=[128], d=[64], seed=[0],
                       causal=[True], dtype=[jnp.bfloat16], atol=[0.05]))),
    atol=3e-5,
)

# the ops wrapper's custom_vjp recomputes the backward from the oracle, so
# kernel-vs-reference gradient parity checks the fwd/bwd pairing end to end
GRAD = ParityOp(
    name="flash_vjp",
    make=_qkv,
    kernel=O.flash_attention,
    reference=lambda q, k, v, causal: R.flash_attention(q, k, v,
                                                        causal=causal),
    cases=list(grid(h=[2], hkv=[1], s=[64], d=[32], seed=[1],
                    causal=[True], dtype=[jnp.float32], atol=[3e-5],
                    grad_atol=[1e-4])),
    diff_argnums=(0, 1, 2),
    cotangent=lambda case, primal: 2.0 * primal,   # == grad of sum(out**2)
)


def test_flash_forward_parity():
    check(FORWARD)


def test_flash_vjp_parity():
    check(GRAD)
