"""MoE routing invariants + dispatch vs dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import moe
from repro.models.moe import _capacity, _route_one_seq


def _cfg(**kw):
    return get_reduced("qwen3-moe-30b-a3b", **kw)


def test_route_positions_within_capacity():
    cfg = _cfg()
    rng = np.random.default_rng(0)
    probs = jax.nn.softmax(
        jnp.asarray(rng.standard_normal((32, cfg.n_experts)), jnp.float32))
    cap = _capacity(cfg, 32)
    e, pos, tok, w = _route_one_seq(cfg, probs, cap)
    assert int(jnp.max(pos)) <= cap
    assert int(jnp.min(pos)) >= 0
    kept = np.asarray(pos) < cap
    # positions unique per expert among kept entries
    pairs = set()
    for ee, pp in zip(np.asarray(e)[kept], np.asarray(pos)[kept]):
        assert (ee, pp) not in pairs
        pairs.add((ee, pp))


def test_topk_weights_normalized():
    cfg = _cfg()
    rng = np.random.default_rng(1)
    probs = jax.nn.softmax(
        jnp.asarray(rng.standard_normal((16, cfg.n_experts)), jnp.float32))
    _, _, tok, w = _route_one_seq(cfg, probs, _capacity(cfg, 16))
    w = np.asarray(w)
    tok = np.asarray(tok)
    for t in range(16):
        assert abs(w[tok == t].sum() - 1.0) < 1e-5


def _dense_moe_oracle(cfg, p, x):
    """Compute-all-experts reference (no capacity, no dropping)."""
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    w = w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)
    gate = jnp.einsum("bsd,edf->besf", x, p["w_gate"])
    up = jnp.einsum("bsd,edf->besf", x, p["w_up"])
    hid = jax.nn.silu(gate) * up
    out_all = jnp.einsum("besf,efd->besd", hid, p["w_down"])   # (B,E,S,d)
    onehot = jax.nn.one_hot(idx, cfg.n_experts)                 # (B,S,k,E)
    comb = jnp.einsum("bske,bsk->bse", onehot, w)
    return jnp.einsum("besd,bse->bsd", out_all, comb)


def test_dispatch_matches_dense_oracle_with_big_capacity():
    cfg = _cfg(capacity_factor=64.0)      # no drops
    rng = np.random.default_rng(2)
    p = {k: v for k, v in jax.tree.map(
        lambda t: t.value,
        moe.moe_init(cfg, jax.random.PRNGKey(0)),
        is_leaf=lambda x: hasattr(x, "axes")).items()}
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    y, aux = moe.moe_apply(cfg, p, x)
    y_ref = _dense_moe_oracle(cfg, p, x)
    err = float(jnp.max(jnp.abs(y - y_ref)))
    assert err < 1e-4, err
    assert float(aux) > 0


def test_capacity_drops_deterministic():
    cfg = _cfg(capacity_factor=0.25)
    rng = np.random.default_rng(3)
    p = jax.tree.map(lambda t: t.value,
                     moe.moe_init(cfg, jax.random.PRNGKey(1)),
                     is_leaf=lambda x: hasattr(x, "axes"))
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    y1, _ = moe.moe_apply(cfg, p, x)
    y2, _ = moe.moe_apply(cfg, p, x)
    assert jnp.array_equal(y1, y2)


def test_moe_grads_finite():
    cfg = _cfg()
    p = jax.tree.map(lambda t: t.value,
                     moe.moe_init(cfg, jax.random.PRNGKey(2)),
                     is_leaf=lambda x: hasattr(x, "axes"))
    x = jnp.asarray(np.random.default_rng(4).standard_normal(
        (2, 8, cfg.d_model)), jnp.float32)

    def loss(p, x):
        y, aux = moe.moe_apply(cfg, p, x)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p, x)
    assert all(np.isfinite(np.asarray(t)).all() for t in jax.tree.leaves(g))
