"""Channel-in-the-loop training curves (ISSUE 2 tentpole acceptance).

Contracts under test:
  * one jitted train-step compilation per ``bits`` value serves the whole
    traced ``p_miss`` lane axis (trace counters);
  * the ``p_miss=0`` lane is bit-for-bit the ideal ``max_q{bits}`` run —
    trained parameters and evaluated accuracy;
  * record/row emission through ``repro.sim.results``;
  * the rng-threaded train step and trainer hook behind the curve runner.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fedocs, vertical
from repro.core.vertical import VerticalConfig
from repro.optim import optimizers, schedules
from repro.sim import results as sim_results
from repro.sim import train_curves as tc
from repro.train.train_step import make_train_step
from repro.train.trainer import TrainerConfig, train

TINY = tc.CurveConfig(bits=(8,), p_miss=(0.0, 0.3), steps=8, batch=16,
                      n_train=128, n_val=64, hw=8, encoder_dims=(8,),
                      embed_dim=8, head_dims=(8,), log_every=4)


def _leaves_equal(a, b, lane=0):
    return all(np.array_equal(np.asarray(x)[lane], np.asarray(y)[lane])
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_one_compilation_per_bits_value():
    cfg = tc.CurveConfig(**{**TINY.__dict__, "bits": (8, 16)})
    tc.reset_trace_counts()
    tc.run_curves(cfg)
    traces = tc.trace_counts()
    assert traces["noisy_step"] == 2, traces
    assert traces["ideal_step"] == 2, traces
    assert traces["noisy_eval"] == 2 and traces["ideal_eval"] == 2, traces


def test_zero_miss_lane_matches_ideal_run_bit_for_bit():
    out = tc.run_curves(TINY)
    assert out.p_miss[0] == 0.0
    # trained parameters: lane 0 of the noisy run == the ideal max_q8 run
    assert _leaves_equal(out.noisy_params[0], out.ideal_params[0], lane=0)
    assert out.acc[0, 0] == out.acc_ideal[0]
    assert out.nll[0, 0] == out.nll_ideal[0]
    # the logged loss history coincides too (same compiled-math trajectory)
    assert np.array_equal(out.loss_history[0, :, 0],
                          out.ideal_loss_history[0])
    # the deterministic ideal reference trains a single vmap lane
    assert jax.tree.leaves(out.ideal_params[0])[0].shape[0] == 1


def test_curve_records_and_rows(tmp_path):
    out = tc.run_curves(TINY)
    recs = sim_results.summarize_curves(out)
    assert len(recs) == len(TINY.bits) * len(TINY.p_miss)
    r0 = recs[0]
    assert r0["bits"] == 8 and r0["p_miss"] == 0.0
    assert r0["acc"] == r0["acc_ideal"] and r0["acc_gap"] == 0.0
    # uplink accounting uses the D-bit payload the winner transmits
    from repro.core import channel
    fed = channel.ocs_load(TINY.n_workers, TINY.embed_dim, bits=8,
                           cfg=channel.ChannelConfig(payload_bits=8))
    assert r0["uplink_bits_fedocs"] == fed.uplink_bits
    rows = sim_results.curve_rows(recs)
    assert len(rows) == len(recs)
    assert rows[0].startswith("curves/b8_p0,")
    sim_results.write_json(recs, str(tmp_path / "curves.json"))
    import json
    loaded = json.loads((tmp_path / "curves.json").read_text())
    assert loaded[1]["p_miss"] == 0.3


def test_run_curves_is_deterministic():
    a = tc.run_curves(TINY)
    b = tc.run_curves(TINY)
    assert np.array_equal(a.acc, b.acc)
    assert _leaves_equal(a.noisy_params[0], b.noisy_params[0], lane=1)


def test_curve_config_validation():
    import pytest
    with pytest.raises(ValueError):
        tc.CurveConfig(bits=(12,))            # no ideal max_q12 reference
    with pytest.raises(ValueError):
        tc.CurveConfig(p_miss=(0.0, 1.0))
    with pytest.raises(ValueError):           # wrong per-worker length
        tc.CurveConfig(p_miss=((0.0, 0.1),))  # n_workers = 4
    with pytest.raises(ValueError):
        tc.CurveConfig(p_miss=(0.0, (0.1, 0.2, 0.3, 1.5)))
    with pytest.raises(ValueError):
        tc.CurveConfig(backend="scan", p_miss=())


def test_curve_per_worker_lanes_broadcast():
    """Scalar and per-worker lanes mix: lane_p_miss broadcasts to (L, N)."""
    cfg = tc.CurveConfig(**{**TINY.__dict__,
                            "p_miss": (0.0, (0.0, 0.1, 0.1, 0.3))})
    lanes = cfg.lane_p_miss()
    assert lanes.shape == (2, 4)
    assert np.array_equal(lanes[0], np.zeros(4, np.float32))
    # all-scalar configs keep the historical (L,) lane axis
    assert TINY.lane_p_miss().shape == (2,)


@pytest.mark.slow
def test_curve_pallas_backend_matches_scan_bit_for_bit():
    """The fused contention kernel drives the whole training loop to the
    exact same trajectory as the scan backend (tentpole acceptance at the
    train-curve level), including a heterogeneous near/far lane.  Slow
    tier: the fast tier covers the same contract at the aggregator level
    (test_kernels_contention + bench_contention --smoke)."""
    small = {**TINY.__dict__, "steps": 4, "n_train": 64, "n_val": 32,
             "p_miss": (0.0, (0.0, 0.1, 0.1, 0.3))}
    a = tc.run_curves(tc.CurveConfig(**{**small, "backend": "scan"}))
    b = tc.run_curves(tc.CurveConfig(**{**small, "backend": "pallas"}))
    assert np.array_equal(a.acc, b.acc)
    assert np.array_equal(a.nll, b.nll)
    assert np.array_equal(a.loss_history, b.loss_history)
    for x, y in zip(jax.tree.leaves(a.noisy_params[0]),
                    jax.tree.leaves(b.noisy_params[0])):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_train_step_with_rng_microbatches():
    """with_rng threading: microbatches receive decorrelated keys and the
    accumulated path stays consistent with the single-batch contract."""
    vcfg = VerticalConfig(n_workers=2, input_dim=4, encoder_dims=(4,),
                          embed_dim=4, head_dims=(4,), output_dim=2,
                          task="classification", aggregation="max_noisy",
                          noise_bits=8, tie_break="first")
    params = vertical.init(vcfg, jax.random.PRNGKey(0))
    opt = optimizers.adamw(schedules.linear_warmup_cosine(1e-3, 1, 4))
    views = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((2, 8, 4)).astype(np.float32))
    labels = jnp.zeros((8,), jnp.int32)

    def loss(values, batch, noise):
        v, y = batch                 # batch-leading for microbatch splitting
        return vertical.loss_fn(vcfg, values, jnp.swapaxes(v, 0, 1), y,
                                noise=noise)

    batch = (jnp.swapaxes(views, 0, 1), labels)      # (B, N, d)
    noise = fedocs.ChannelNoise(rng=jax.random.PRNGKey(3),
                                p_miss=jnp.float32(0.2))
    step1 = make_train_step(loss, opt, with_rng=True)
    step2 = make_train_step(loss, opt, microbatches=2, with_rng=True)
    state = opt.init(params)
    v1, _, m1 = jax.jit(step1)(params, state, batch, noise)
    v2, _, m2 = jax.jit(step2)(params, state, batch, noise)
    for m in (m1, m2):
        assert np.isfinite(float(m["loss_mean"]))
    # both produce finite updated params of identical structure
    assert jax.tree.structure(v1) == jax.tree.structure(v2)
    for x in jax.tree.leaves(v2):
        assert np.isfinite(np.asarray(x)).all()


def test_trainer_channel_rng_hook():
    """trainer.train drives a stochastic (max_noisy) loss via
    channel_rng_seed; the run is reproducible step-for-step."""
    vcfg = VerticalConfig(n_workers=2, input_dim=4, encoder_dims=(4,),
                          embed_dim=4, head_dims=(4,), output_dim=2,
                          task="classification", aggregation="max_noisy",
                          noise_bits=8, tie_break="first")
    init = vertical.init(vcfg, jax.random.PRNGKey(0))
    opt = optimizers.adamw(schedules.linear_warmup_cosine(1e-3, 1, 4))
    rng = np.random.default_rng(0)
    views = jnp.asarray(rng.standard_normal((2, 8, 4)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 2, (8,)), jnp.int32)

    def loss(values, batch, key):
        noise = fedocs.ChannelNoise(rng=key, p_miss=jnp.float32(0.1))
        v, y = batch
        return vertical.loss_fn(vcfg, values, v, y, noise=noise)

    tcfg = TrainerConfig(steps=4, log_every=2, channel_rng_seed=11)
    runs = [train(loss, init, opt, lambda step: (views, labels), tcfg)
            for _ in range(2)]
    assert runs[0].final_step == 4
    assert all(np.isfinite(row["loss_mean"]) for row in runs[0].history)
    for x, y in zip(jax.tree.leaves(runs[0].values),
                    jax.tree.leaves(runs[1].values)):
        assert np.array_equal(np.asarray(x), np.asarray(y))
