"""Channel-in-the-loop training curves (ISSUE 2 + ISSUE 4 + ISSUE 5).

Contracts under test:
  * the fused scan engine trains a whole curve grid in ONE compiled dispatch
    per ``bits`` value (trace + dispatch counters, ``<= ceil(steps/
    log_every) + 2`` per-bits bound), each lane carrying its own traced
    ``repro.protocol.Protocol`` pytree, and is deterministic run-to-run
    (the legacy per-step python driver is gone; its parity contract lives
    on as the FixedBits-schedule bitwise equivalence in
    ``tests/test_protocol.py``);
  * the ``p_miss`` lane axis shards over local devices bit-for-bit
    (forced-host-device subprocess, mirroring the sweep-engine property);
  * the ``p_miss=0`` lane is bit-for-bit the ideal
    ``Protocol.ideal_max(bits)`` run — trained parameters and evaluated
    accuracy;
  * record/row emission through ``repro.sim.results``;
  * the rng-threaded train step (its channel state now the ``(key,
    Protocol)`` tuple), donated train-state carries, and the trainer hook
    behind the curve runner.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import vertical
from repro.core.vertical import VerticalConfig
from repro.optim import optimizers, schedules
from repro.protocol import Protocol
from repro.sim import results as sim_results
from repro.sim import train_curves as tc
from repro.train.train_step import make_train_step
from repro.train.trainer import TrainerConfig, train

TINY = tc.CurveConfig(bits=(8,), p_miss=(0.0, 0.3), steps=8, batch=16,
                      n_train=128, n_val=64, hw=8, encoder_dims=(8,),
                      embed_dim=8, head_dims=(8,), log_every=4)


def _leaves_equal(a, b, lane=0):
    return all(np.array_equal(np.asarray(x)[lane], np.asarray(y)[lane])
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_scan_engine_one_dispatch_per_bits_value():
    """The fused engine compiles once AND dispatches once per bits value —
    the whole steps loop, the ideal reference and both evals included."""
    cfg = dataclasses.replace(TINY, bits=(8, 16))
    tc.reset_trace_counts()
    tc.reset_dispatch_counts()
    tc.run_curves(cfg)
    traces, disp = tc.trace_counts(), tc.dispatch_counts()
    assert traces["fused"] == 2, traces
    assert disp["fused"] == 2, disp
    # nothing fell back to another driver
    assert all(v == 0 for k, v in disp.items() if k != "fused"), disp
    # the ISSUE bound: <= ceil(steps/log_every) + 2 dispatches per bits
    bound = math.ceil(cfg.steps / cfg.log_every) + 2
    assert disp["fused"] / len(cfg.bits) <= bound


def test_heterogeneous_lane_grid_trains_deterministically():
    """Scan-engine invariant (absorbed from the removed python-engine
    parity suite): a grid mixing scalar and per-worker near/far lanes
    trains to identical results on repeat runs — the whole trajectory is a
    pure function of the config's key streams."""
    grid = dataclasses.replace(TINY,
                               p_miss=(0.0, (0.0, 0.1, 0.1, 0.3), 0.3))
    a = tc.run_curves(grid)
    b = tc.run_curves(grid)
    assert np.array_equal(a.acc, b.acc)
    assert np.array_equal(a.nll, b.nll)
    assert np.array_equal(a.acc_ideal, b.acc_ideal)
    assert np.array_equal(a.nll_ideal, b.nll_ideal)
    assert np.array_equal(a.loss_history, b.loss_history)
    assert np.array_equal(a.ideal_loss_history, b.ideal_loss_history)
    assert np.array_equal(a.logged_steps, b.logged_steps)
    for pa, pb in ((a.noisy_params, b.noisy_params),
                   (a.ideal_params, b.ideal_params)):
        for x, y in zip(jax.tree.leaves(pa[0]), jax.tree.leaves(pb[0])):
            assert np.array_equal(np.asarray(x), np.asarray(y))
    # the noisy lanes really did see different channels (lane 0 vs lane 2)
    assert not np.array_equal(a.loss_history[0, :, 0],
                              a.loss_history[0, :, 2])


def test_sharded_curve_lanes_match_vmap_path():
    """p_miss-lane shard_map over >=2 forced host devices is bit-for-bit
    identical to the single-device vmap path — including a lane count that
    does not divide the device count (padding lanes dropped) and a
    per-worker heterogeneous lane."""
    import os
    import subprocess
    import sys
    import textwrap

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import jax, numpy as np
        assert jax.local_device_count() == 4, jax.devices()
        from repro.sim import train_curves as tc
        # 3 lanes: not divisible by 4 nor by 2 -> padding on both meshes
        cfg = tc.CurveConfig(bits=(8,), p_miss=(0.0, (0.0, 0.1, 0.1, 0.3),
                                                0.3),
                             steps=6, batch=16, n_train=128, n_val=64, hw=8,
                             encoder_dims=(8,), embed_dim=8, head_dims=(8,),
                             log_every=3)
        ref = tc.run_curves(cfg, n_devices=1)
        for n_dev in (None, 2, 4):     # None = auto-detect (4 devices)
            got = tc.run_curves(cfg, n_devices=n_dev)
            assert np.array_equal(ref.acc, got.acc), n_dev
            assert np.array_equal(ref.nll, got.nll), n_dev
            assert np.array_equal(ref.loss_history, got.loss_history), n_dev
            for pa, pb in ((ref.noisy_params, got.noisy_params),
                           (ref.ideal_params, got.ideal_params)):
                for x, y in zip(jax.tree.leaves(pa[0]),
                                jax.tree.leaves(pb[0])):
                    assert np.array_equal(np.asarray(x), np.asarray(y)), n_dev
        print("SHARDED_CURVES_OK")
    """)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=900)
    assert proc.returncode == 0, f"OUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    assert "SHARDED_CURVES_OK" in proc.stdout


def test_zero_miss_lane_matches_ideal_run_bit_for_bit():
    out = tc.run_curves(TINY)
    assert out.p_miss[0] == 0.0
    # the traced lane array is what the result reports (float32, not a
    # float64 re-derivation)
    assert out.p_miss.dtype == np.float32
    assert np.array_equal(out.p_miss, TINY.lane_p_miss())
    # trained parameters: lane 0 of the noisy run == the ideal max_q8 run
    assert _leaves_equal(out.noisy_params[0], out.ideal_params[0], lane=0)
    assert out.acc[0, 0] == out.acc_ideal[0]
    assert out.nll[0, 0] == out.nll_ideal[0]
    # the logged loss history coincides too (same compiled-math trajectory)
    assert np.array_equal(out.loss_history[0, :, 0],
                          out.ideal_loss_history[0])
    # the deterministic ideal reference trains a single vmap lane
    assert jax.tree.leaves(out.ideal_params[0])[0].shape[0] == 1


def test_curve_records_and_rows(tmp_path):
    out = tc.run_curves(TINY)
    recs = sim_results.summarize_curves(out)
    assert len(recs) == len(TINY.bits) * len(TINY.p_miss)
    r0 = recs[0]
    assert r0["bits"] == 8 and r0["p_miss"] == 0.0
    assert r0["acc"] == r0["acc_ideal"] and r0["acc_gap"] == 0.0
    # uplink accounting uses the D-bit payload the winner transmits
    from repro.core import channel
    fed = channel.ocs_load(TINY.n_workers, TINY.embed_dim, bits=8,
                           cfg=channel.ChannelConfig(payload_bits=8))
    assert r0["uplink_bits_fedocs"] == fed.uplink_bits
    rows = sim_results.curve_rows(recs)
    assert len(rows) == len(recs)
    assert rows[0].startswith("curves/b8_p0,")
    sim_results.write_json(recs, str(tmp_path / "curves.json"))
    import json
    loaded = json.loads((tmp_path / "curves.json").read_text())
    assert loaded[1]["p_miss"] == 0.3


def test_run_curves_is_deterministic():
    a = tc.run_curves(TINY)
    b = tc.run_curves(TINY)
    assert np.array_equal(a.acc, b.acc)
    assert _leaves_equal(a.noisy_params[0], b.noisy_params[0], lane=1)


def test_curve_config_validation():
    with pytest.raises(ValueError):
        tc.CurveConfig(bits=(12,))            # no ideal max_q12 reference
    with pytest.raises(ValueError):
        tc.CurveConfig(p_miss=(0.0, 1.0))
    with pytest.raises(ValueError):           # wrong per-worker length
        tc.CurveConfig(p_miss=((0.0, 0.1),))  # n_workers = 4
    with pytest.raises(ValueError):
        tc.CurveConfig(p_miss=(0.0, (0.1, 0.2, 0.3, 1.5)))
    with pytest.raises(ValueError):
        tc.CurveConfig(backend="scan", p_miss=())
    with pytest.raises(TypeError):            # the legacy python driver
        tc.CurveConfig(engine="python")       # is gone (one release passed)


def test_curve_config_protocol_template():
    proto = TINY.protocol(8)
    assert proto.kind == "ocs" and proto.bits == 8
    assert proto.max_rounds == TINY.max_rounds
    assert proto.backend == TINY.backend
    assert proto.p_miss is None               # lanes bind it per call


def test_curve_per_worker_lanes_broadcast():
    """Scalar and per-worker lanes mix: lane_p_miss broadcasts to (L, N)."""
    cfg = dataclasses.replace(TINY, p_miss=(0.0, (0.0, 0.1, 0.1, 0.3)))
    lanes = cfg.lane_p_miss()
    assert lanes.shape == (2, 4)
    assert np.array_equal(lanes[0], np.zeros(4, np.float32))
    # all-scalar configs keep the historical (L,) lane axis
    assert TINY.lane_p_miss().shape == (2,)


@pytest.mark.slow
def test_curve_pallas_backend_matches_scan_bit_for_bit():
    """The fused contention kernel drives the whole training loop to the
    exact same trajectory as the scan backend (tentpole acceptance at the
    train-curve level), including a heterogeneous near/far lane.  Slow
    tier: the fast tier covers the same contract at the aggregator level
    (test_kernels_contention + bench_contention --smoke)."""
    small = dataclasses.replace(TINY, steps=4, n_train=64, n_val=32,
                                p_miss=(0.0, (0.0, 0.1, 0.1, 0.3)))
    a = tc.run_curves(small)
    b = tc.run_curves(dataclasses.replace(small, backend="pallas"))
    assert np.array_equal(a.acc, b.acc)
    assert np.array_equal(a.nll, b.nll)
    assert np.array_equal(a.loss_history, b.loss_history)
    for x, y in zip(jax.tree.leaves(a.noisy_params[0]),
                    jax.tree.leaves(b.noisy_params[0])):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def _tiny_step_fixture():
    vcfg = VerticalConfig(n_workers=2, input_dim=4, encoder_dims=(4,),
                          embed_dim=4, head_dims=(4,), output_dim=2,
                          task="classification",
                          aggregation=Protocol.ocs(bits=8))
    params = vertical.init(vcfg, jax.random.PRNGKey(0))
    opt = optimizers.adamw(schedules.linear_warmup_cosine(1e-3, 1, 4))
    views = jnp.asarray(np.random.default_rng(0)
                        .standard_normal((2, 8, 4)).astype(np.float32))
    labels = jnp.zeros((8,), jnp.int32)

    def loss(values, batch, chan):
        v, y = batch                 # batch-leading for microbatch splitting
        rng, proto = chan
        return vertical.loss_fn(vcfg, values, jnp.swapaxes(v, 0, 1), y,
                                rng=rng, protocol=proto)

    batch = (jnp.swapaxes(views, 0, 1), labels)      # (B, N, d)
    chan = (jax.random.PRNGKey(3),
            Protocol.ocs(bits=8, p_miss=jnp.float32(0.2)))
    return params, opt, loss, batch, chan


def test_train_step_with_rng_microbatches():
    """with_rng threading: microbatches receive decorrelated keys (the
    Protocol's p_miss leaf passes through untouched) and the accumulated
    path stays consistent with the single-batch contract."""
    params, opt, loss, batch, chan = _tiny_step_fixture()
    step1 = make_train_step(loss, opt, with_rng=True)
    step2 = make_train_step(loss, opt, microbatches=2, with_rng=True)
    state = opt.init(params)
    v1, _, m1 = jax.jit(step1)(params, state, batch, chan)
    v2, _, m2 = jax.jit(step2)(params, state, batch, chan)
    for m in (m1, m2):
        assert np.isfinite(float(m["loss_mean"]))
    # both produce finite updated params of identical structure
    assert jax.tree.structure(v1) == jax.tree.structure(v2)
    for x in jax.tree.leaves(v2):
        assert np.isfinite(np.asarray(x)).all()


def test_train_step_donated_carries():
    """donate=True: same math, but the params/opt-state input buffers are
    consumed by the dispatch (updated in place, no double-buffering)."""
    params, opt, loss, batch, chan = _tiny_step_fixture()
    plain = make_train_step(loss, opt, with_rng=True)
    v0, s0, _ = jax.jit(plain)(params, opt.init(params), batch, chan)

    donated = make_train_step(loss, opt, with_rng=True, donate=True)
    p_in = jax.tree.map(lambda x: jnp.array(x, copy=True), params)
    s_in = opt.init(p_in)
    in_leaves = jax.tree.leaves((p_in, s_in))
    v1, s1, _ = donated(p_in, s_in, batch, chan)
    for x, y in zip(jax.tree.leaves((v0, s0)), jax.tree.leaves((v1, s1))):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    assert all(x.is_deleted() for x in in_leaves)


def test_trainer_channel_rng_hook():
    """trainer.train drives a stochastic (max_noisy) loss via
    channel_rng_seed; the run is reproducible step-for-step (and the donated
    carries never consume the caller's init across repeat runs)."""
    proto = Protocol.ocs(bits=8, p_miss=jnp.float32(0.1))
    vcfg = VerticalConfig(n_workers=2, input_dim=4, encoder_dims=(4,),
                          embed_dim=4, head_dims=(4,), output_dim=2,
                          task="classification", aggregation=proto)
    init = vertical.init(vcfg, jax.random.PRNGKey(0))
    opt = optimizers.adamw(schedules.linear_warmup_cosine(1e-3, 1, 4))
    rng = np.random.default_rng(0)
    views = jnp.asarray(rng.standard_normal((2, 8, 4)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 2, (8,)), jnp.int32)

    def loss(values, batch, key):
        v, y = batch
        return vertical.loss_fn(vcfg, values, v, y, rng=key)

    tcfg = TrainerConfig(steps=4, log_every=2, channel_rng_seed=11)
    runs = [train(loss, init, opt, lambda step: (views, labels), tcfg)
            for _ in range(2)]
    assert runs[0].final_step == 4
    assert all(np.isfinite(row["loss_mean"]) for row in runs[0].history)
    for x, y in zip(jax.tree.leaves(runs[0].values),
                    jax.tree.leaves(runs[1].values)):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    assert all(not x.is_deleted() for x in jax.tree.leaves(init))
