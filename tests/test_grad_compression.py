"""Winner-sparse gradient compression: sparsity + error-feedback convergence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import grad_compression as gc


def test_topk_mask_density():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1024),
                    jnp.float32)
    mask = gc.topk_mask(x, 1 / 16)
    assert int(mask.sum()) == 64


@pytest.mark.parametrize("x", [
    np.ones((256,)),                        # everything tied
    np.zeros((256,)),                       # all-zero gradient
    np.repeat([3.0, -3.0, 1.0, 0.0], 64),   # tied blocks at the threshold
])
def test_topk_mask_exact_k_on_ties(x):
    """Threshold ties must not inflate the payload: exactly k survive."""
    mask = gc.topk_mask(jnp.asarray(x, jnp.float32), 1 / 16)
    assert int(mask.sum()) == 16


def test_topk_mask_quantized_gradient_density():
    """A quantized (few-distinct-values) gradient used to ship near-dense
    payloads through the >= threshold comparison."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(-2, 3, size=512), jnp.float32)
    mask = gc.topk_mask(x, 1 / 8)
    assert int(mask.sum()) == 64


def test_compress_preserves_mass_with_error():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((128,)), jnp.float32)
    err = jnp.zeros_like(g)
    sparse, new_err = gc.compress(g, err, 1 / 8)
    # sparse + residual == original (nothing lost, only deferred)
    assert np.allclose(np.asarray(sparse + new_err), np.asarray(g), atol=1e-6)
    nz = int((np.asarray(sparse) != 0).sum())
    assert nz <= 16


def test_compress_bf16_residual_keeps_cast_error():
    """The EF memory must accumulate the dtype-quantization residual: the
    value applied is sparse in g.dtype, and exactly
    sparse.astype(f32) + new_err == g.astype(f32) + err."""
    rng = np.random.default_rng(4)
    g = jnp.asarray(rng.standard_normal((256,)), jnp.bfloat16)
    err = jnp.asarray(rng.standard_normal((256,)) * 0.1, jnp.float32)
    sparse, new_err = gc.compress(g, err, 1 / 8)
    assert sparse.dtype == jnp.bfloat16
    corrected = g.astype(jnp.float32) + err
    total = np.asarray(sparse.astype(jnp.float32) + new_err)
    assert np.array_equal(total, np.asarray(corrected))
    # bf16 casts genuinely lose bits here, so the residual is nonzero ON the
    # kept coordinates too — the mass the old code silently dropped
    kept = np.asarray(sparse) != 0
    assert np.any(np.asarray(new_err)[kept] != 0)


def test_compress_counted_reports_actual_kept():
    rng = np.random.default_rng(5)
    g = jnp.asarray(rng.standard_normal((128,)), jnp.float32)
    sparse, _err, kept = gc.compress_counted(g, jnp.zeros_like(g), 1 / 8)
    assert int(kept) == 16 == int((np.asarray(sparse) != 0).sum())


def test_error_feedback_convergence_quadratic():
    """ef-top-k SGD still converges on a quadratic (classic EF result).

    Note: EF defers gradient mass, so the stable lr shrinks with sparsity —
    lr=0.05 at k=1/8 converges; lr=0.2 at k=1/16 visibly diverges (that
    regime is exercised by the negative check below)."""
    rng = np.random.default_rng(2)
    target = jnp.asarray(rng.standard_normal((64,)), jnp.float32)

    def run(lr, k_frac, steps):
        def body(carry, _):
            w, e = carry
            g = 2 * (w - target)
            sparse, e = gc.compress(g, e, k_frac)
            return (w - lr * sparse, e), None

        @jax.jit
        def go():
            (w, _), _ = jax.lax.scan(
                body, (jnp.zeros((64,)), jnp.zeros((64,))), None,
                length=steps)
            return jnp.sum((w - target) ** 2)

        return float(go())

    assert run(0.05, 1 / 8, 3000) < 1e-6
    # aggressive lr + heavy sparsity destabilizes EF — document the regime
    assert run(0.2, 1 / 16, 800) > 1.0


def test_error_feedback_convergence_quadratic_bf16():
    """EF convergence survives bf16 gradients BECAUSE the cast residual
    feeds back; bf16 resolution alone (~2^-8 relative) would floor the
    error well above the 1e-4 bound this run reaches."""
    rng = np.random.default_rng(6)
    target = jnp.asarray(rng.standard_normal((64,)), jnp.float32)

    def body(carry, _):
        w, e = carry
        g = (2 * (w - target)).astype(jnp.bfloat16)
        sparse, e = gc.compress(g, e, 1 / 8)
        return (w - 0.05 * sparse.astype(jnp.float32), e), None

    @jax.jit
    def go():
        (w, _), _ = jax.lax.scan(
            body, (jnp.zeros((64,)), jnp.zeros((64,))), None, length=4000)
        return jnp.sum((w - target) ** 2)

    assert float(go()) < 1e-4


def test_payload_fraction_per_leaf_floors():
    # one big leaf: 64 of 1024 kept -> exactly 2 * k_frac
    assert gc.payload_fraction({"w": np.zeros((1024,))}, 1 / 16) == 1 / 8
    # a small bias leaf keeps max(1, int(4/16)) = 1 of 4 elements, so the
    # true ratio exceeds the naive 2*k_frac
    tree = {"w": np.zeros((32, 32)), "b": np.zeros((4,))}
    expected = 2.0 * (64 + 1) / (1024 + 4)
    assert gc.payload_fraction(tree, 1 / 16) == pytest.approx(expected)
    assert gc.payload_fraction(tree, 1 / 16) > 1 / 8
    # dense limit caps at 1
    assert gc.payload_fraction({"w": np.zeros((8,))}, 0.9) == 1.0
    with pytest.raises(ValueError):
        gc.payload_fraction(None, 1 / 16)
