"""Winner-sparse gradient compression: sparsity + error-feedback convergence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import grad_compression as gc
from repro.optim import optimizers, schedules


def test_topk_mask_density():
    x = jnp.asarray(np.random.default_rng(0).standard_normal(1024),
                    jnp.float32)
    mask = gc.topk_mask(x, 1 / 16)
    assert int(mask.sum()) == 64


def test_compress_preserves_mass_with_error():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((128,)), jnp.float32)
    err = jnp.zeros_like(g)
    sparse, new_err = gc.compress(g, err, 1 / 8)
    # sparse + residual == original (nothing lost, only deferred)
    assert np.allclose(np.asarray(sparse + new_err), np.asarray(g), atol=1e-6)
    nz = int((np.asarray(sparse) != 0).sum())
    assert nz <= 16 + 1


def test_error_feedback_convergence_quadratic():
    """ef-top-k SGD still converges on a quadratic (classic EF result).

    Note: EF defers gradient mass, so the stable lr shrinks with sparsity —
    lr=0.05 at k=1/8 converges; lr=0.2 at k=1/16 visibly diverges (that
    regime is exercised by the negative check below)."""
    rng = np.random.default_rng(2)
    target = jnp.asarray(rng.standard_normal((64,)), jnp.float32)

    def run(lr, k_frac, steps):
        def body(carry, _):
            w, e = carry
            g = 2 * (w - target)
            sparse, e = gc.compress(g, e, k_frac)
            return (w - lr * sparse, e), None

        @jax.jit
        def go():
            (w, _), _ = jax.lax.scan(
                body, (jnp.zeros((64,)), jnp.zeros((64,))), None,
                length=steps)
            return jnp.sum((w - target) ** 2)

        return float(go())

    assert run(0.05, 1 / 8, 3000) < 1e-6
    # aggressive lr + heavy sparsity destabilizes EF — document the regime
    assert run(0.2, 1 / 16, 800) > 1.0


def test_payload_fraction():
    assert gc.payload_fraction(None, 1 / 16) == 1 / 8
    assert gc.payload_fraction(None, 0.9) == 1.0
