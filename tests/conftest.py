import os
import sys

# tests import the proptest helper module from this directory
sys.path.insert(0, os.path.dirname(__file__))
