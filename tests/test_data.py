"""Data pipeline: index-derived determinism + structure of synthetic tasks."""

import numpy as np

from repro.data import pipeline
from repro.data.vertical_data import (PatchTaskConfig, multiview_denoising,
                                      patch_classification)


def test_batch_deterministic_per_step():
    cfg = pipeline.PipelineConfig(vocab_size=100, batch=4, seq_len=16, seed=3)
    a = pipeline.batch_for_step(cfg, 7)
    b = pipeline.batch_for_step(cfg, 7)
    c = pipeline.batch_for_step(cfg, 8)
    assert np.array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_targets_are_next_tokens():
    cfg = pipeline.PipelineConfig(vocab_size=50, batch=2, seq_len=8, seed=0,
                                  noise=0.0)
    b = pipeline.batch_for_step(cfg, 0)
    toks = np.asarray(b["tokens"])
    tgts = np.asarray(b["targets"])
    a = 31337 % 50
    assert np.array_equal(tgts[:, :-1], toks[:, 1:])
    assert np.array_equal(tgts, (a * toks + 17) % 50)


def test_encdec_batch_structure():
    cfg = pipeline.PipelineConfig(vocab_size=64, batch=2, seq_len=32,
                                  frontend="audio", frontend_dim=8,
                                  decoder_len=6)
    b = pipeline.batch_for_step(cfg, 1)
    assert b["feats"].shape == (2, 32, 8)
    assert b["tokens"].shape == (2, 6)
    assert b["targets"].shape == (2, 6)


def test_multiview_same_signal_different_noise():
    views, clean = multiview_denoising(8, n_workers=3, hw=8, sigma=2.0)
    assert views.shape == (3, 8, 64) and clean.shape == (8, 64)
    assert clean.min() >= 0 and clean.max() <= 1
    # noise is independent across workers
    assert not np.allclose(views[0], views[1])
    # mean over many hypothetical views approaches clean => same signal
    resid = views - clean[None]
    assert abs(resid.mean()) < 0.2


def test_patch_task_single_patch_uninformative():
    """Construction invariants of the relational patch task:
    (a) the label is the modular sum of per-patch pattern indices;
    (b) each patch's pattern index is ~independent of the label, so any
        single worker is at chance by design (paper Table-I structure)."""
    from repro.data.vertical_data import pattern_bank
    task = PatchTaskConfig(n_classes=4, grid=2, hw=16, sigma=0.3)
    views, labels = patch_classification(task, 2048, seed=0)
    bank = pattern_bank(task).reshape(task.n_classes, -1)

    # recover each patch's pattern by nearest-template matching
    ks = []
    for i in range(views.shape[0]):
        d = ((views[i][:, None, :] - bank[None]) ** 2).sum(-1)
        ks.append(d.argmin(1))
    ks = np.stack(ks)
    assert np.array_equal(np.mod(ks.sum(0), task.n_classes), labels)

    # single-patch pattern index carries ~no label information
    for i in range(views.shape[0]):
        joint = np.zeros((task.n_classes, task.n_classes))
        for k, l in zip(ks[i], labels):
            joint[k, l] += 1
        joint /= joint.sum()
        mi = 0.0
        pk = joint.sum(1, keepdims=True)
        pl = joint.sum(0, keepdims=True)
        nz = joint > 0
        mi = (joint[nz] * np.log(joint[nz] / (pk @ pl)[nz])).sum()
        assert mi < 0.02, (i, mi)
