"""The static-analysis pass (`repro.analysis`).

Four layers of coverage:

* the contract registry is green on the repo itself — every registered
  entry point (protocol aggregate, fused/scheduled curve engines, serve
  tick, sweep, donated train step) passes its declared trace-level checks
  on **abstract avals only**, proving zero-recompile/f64/host-sync hygiene
  without executing a single training or serve step;
* every seeded violation (in-test functions + `tests/analysis_fixtures/`)
  is flagged by **exactly** the intended rule;
* a no-false-positive pass: the AST lint stays silent on
  `src/repro/protocol/` and `src/repro/serve/` (and the whole repo);
* report/waiver plumbing and the `hlo_analysis` strict-dtype behaviour.
"""

import json
import pathlib
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts, lint, registry
from repro.analysis import report as R
from repro.launch import hlo_analysis

REPO = pathlib.Path(__file__).resolve().parents[1]
FIXTURES = pathlib.Path(__file__).parent / "analysis_fixtures"


def _only_rule(findings, rule):
    """Fixtures must be flagged by exactly the intended rule — a second
    rule firing is a false positive, none firing is a false negative."""
    assert findings, f"seeded {rule} violation produced no findings"
    assert {f.rule for f in findings} == {rule}, \
        f"expected only {rule}, got {[f.key for f in findings]}"
    return findings


# ---------------------------------------------------------------------------
# the registry is green on the repo (contracts double as pytest fixtures)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", registry.contract_names())
def test_contract_clean(name):
    # trace-level only: jaxpr-hash recompile stability across perturbed
    # p_miss leaves, f64 hygiene under enable_x64, host-sync freedom and
    # lowered donation — all on ShapeDtypeStruct args, zero executions
    findings = registry.check_contract(registry.get_contract(name),
                                       skip_hlo=True)
    assert findings == [], [f.render() for f in findings]


@pytest.mark.slow
def test_contracts_hlo_clean():
    findings = registry.check_all(skip_hlo=False)
    assert findings == [], [f.render() for f in findings]


# ---------------------------------------------------------------------------
# seeded trace-level violations
# ---------------------------------------------------------------------------

def test_seeded_recompile_hazard_baked_constant():
    state = {}

    def argsf(p):
        state["p"] = float(p)          # host-materialized channel quality
        return (np.zeros((4,), np.float32),)

    def fn(x):
        return x * state["p"]          # baked into the trace as a constant

    fs = _only_rule(contracts.check_trace_stable("seed", fn, argsf),
                    R.RECOMPILE_HAZARD)
    assert {f.detail for f in fs} == {"jaxpr-hash"}


def test_seeded_recompile_hazard_static_leaf():
    def argsf(p):
        # the leaf value lands in the treedef (dict key = static metadata)
        return ({f"p{p:g}": np.zeros((3,), np.float32)},)

    fs = _only_rule(
        contracts.check_trace_stable(
            "seed", lambda d: sum(jax.tree_util.tree_leaves(d)), argsf),
        R.RECOMPILE_HAZARD)
    assert {f.detail for f in fs} == {"treedef"}


def test_seeded_recompile_hazard_shape_unstable():
    def argsf(p):
        return (np.zeros((int(p * 100),), np.float32),)

    fs = _only_rule(
        contracts.check_trace_stable("seed", lambda x: x * 2.0, argsf),
        R.RECOMPILE_HAZARD)
    assert {f.detail for f in fs} == {"aval"}


def test_seeded_recompile_hazard_concretization():
    def fn(x):
        if x[0] > 0:                   # Python branch on a traced value
            return x
        return -x

    fs = _only_rule(
        contracts.check_trace_stable(
            "seed", fn, lambda p: (np.full((2,), p, np.float32),)),
        R.RECOMPILE_HAZARD)
    assert {f.detail for f in fs} == {"trace-error"}


def test_trace_stable_clean():
    def argsf(p):
        return (np.full((4,), p, np.float32),)

    assert contracts.check_trace_stable(
        "seed", lambda x: jnp.tanh(x) * x, argsf) == []


def test_seeded_f64_promotion():
    def argsf(p):
        return (np.zeros((4,), np.float32),)

    def bad(x):
        return x + jnp.zeros((4,))     # unpinned dtype promotes under x64

    fs = _only_rule(contracts.check_no_f64("seed", bad, argsf),
                    R.F64_PROMOTION)
    assert any("float64" in f.detail for f in fs)

    def good(x):
        return x + jnp.zeros((4,), jnp.float32)

    assert contracts.check_no_f64("seed", good, argsf) == []


def test_seeded_host_sync():
    args = (np.zeros((4,), np.float32),)

    def fn(x):
        return jax.pure_callback(
            lambda a: a, jax.ShapeDtypeStruct((4,), np.float32), x)

    fs = _only_rule(contracts.check_no_host_sync("seed", fn, args),
                    R.HOST_SYNC)
    assert fs[0].detail == "pure_callback"
    # an explicit per-contract allowlist admits it
    assert contracts.check_no_host_sync(
        "seed", fn, args, allowlist=("pure_callback",)) == []


def test_seeded_donation_alias():
    args = (np.zeros((4,), np.float32), np.zeros((4,), np.float32))
    undonated = jax.jit(lambda x, y: (x + y, x - y))
    fs = _only_rule(contracts.check_donation("seed", undonated, args, 1),
                    R.DONATION_ALIAS)
    assert fs[0].detail == "lowered"
    donated = jax.jit(lambda x, y: (x + y, x - y), donate_argnums=(0,))
    assert contracts.check_donation("seed", donated, args, 1) == []


# ---------------------------------------------------------------------------
# seeded lint violations (tests/analysis_fixtures/, never imported)
# ---------------------------------------------------------------------------

def _lint_fixture(name, engine=False):
    return lint.lint_file(FIXTURES / name,
                          f"tests/analysis_fixtures/{name}", engine=engine)


def test_fixture_interpret_hardcode():
    fs = _only_rule(_lint_fixture("bad_interpret.py"), R.INTERPRET_HARDCODE)
    assert {f.detail for f in fs} == {"interpret=True", "INTERPRET=True"}
    assert all(f.line for f in fs)


def test_fixture_host_sync_in_jit():
    fs = _only_rule(_lint_fixture("bad_hostsync.py"), R.HOST_SYNC_IN_JIT)
    assert {f.detail.split(":", 1)[1] for f in fs} == \
        {".item()", "float()", "np.asarray()"}


def test_fixture_eager_loop_in_jit():
    fs = _only_rule(_lint_fixture("bad_loop.py"), R.EAGER_LOOP_IN_JIT)
    assert fs[0].detail == "accumulate:loop"


def test_fixture_nondeterminism_engine_only():
    fs = _only_rule(_lint_fixture("bad_nondet.py", engine=True),
                    R.NONDETERMINISM)
    assert {f.detail for f in fs} == \
        {"time.time", "random.random", "np.random.rand"}
    # the same file is legal outside engine dirs (benchmarks time things)
    assert _lint_fixture("bad_nondet.py", engine=False) == []


def test_fixture_silent_except_engine_only():
    fs = _only_rule(_lint_fixture("bad_except.py", engine=True),
                    R.SILENT_EXCEPT)
    assert {f.detail for f in fs} == {"bare", "swallow:ValueError"}
    assert all(f.line for f in fs)
    # scripts/benchmarks may continue past best-effort failures
    assert _lint_fixture("bad_except.py", engine=False) == []


def test_seeded_missing_kernel_ref(tmp_path):
    pkg = tmp_path / "src/repro/kernels/fake_op"
    pkg.mkdir(parents=True)
    (pkg / "ops.py").write_text("def op():\n    pass\n")
    fs = _only_rule(lint.check_kernel_refs(tmp_path), R.MISSING_KERNEL_REF)
    assert {f.detail for f in fs} == {"ref.py", "parity-op"}
    # shipping ref.py + a ParityOp grid registration clears both
    (pkg / "ref.py").write_text("def ref():\n    pass\n")
    (tmp_path / "tests").mkdir()
    (tmp_path / "tests/test_parity.py").write_text(
        "GRID = [ParityOp('fake_op')]\n")
    assert lint.check_kernel_refs(tmp_path) == []


# ---------------------------------------------------------------------------
# no false positives on the real tree
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("subtree", ["src/repro/protocol", "src/repro/serve"])
def test_lint_no_false_positives(subtree):
    findings = []
    for path in sorted((REPO / subtree).rglob("*.py")):
        findings += lint.lint_file(
            path, path.relative_to(REPO).as_posix(), engine=True)
    assert findings == [], [f.render() for f in findings]


def test_repo_lint_clean():
    findings = lint.lint_repo(REPO)
    assert findings == [], [f.render() for f in findings]


def test_cli_lint_only_clean(tmp_path):
    from repro.analysis.__main__ import main
    out = tmp_path / "report.json"
    assert main(["--root", str(REPO), "--skip-contracts",
                 "--json", str(out)]) == 0
    data = json.loads(out.read_text())
    assert data["ok"] is True
    assert data["findings"] == []


# ---------------------------------------------------------------------------
# shared dispatch-count assertions (the bench self-checks call these)
# ---------------------------------------------------------------------------

def test_dispatch_assertions():
    contracts.assert_trace_count(2, 2, "engine")
    with pytest.raises(RuntimeError, match="recompiled"):
        contracts.assert_trace_count(3, 2, "engine")

    assert contracts.fused_dispatch_bound(24, 8) == 5
    contracts.assert_fused_dispatches(5, 24, 8)
    with pytest.raises(RuntimeError, match="fusion bound"):
        contracts.assert_fused_dispatches(6, 24, 8)

    contracts.assert_single_dispatch({"sched": 1}, "sched", "run")
    with pytest.raises(RuntimeError, match="ONE"):
        contracts.assert_single_dispatch({"sched": 2}, "sched", "run")

    contracts.assert_tick_dispatch_bracket("run", 10, 5, 4)
    with pytest.raises(RuntimeError, match="one fused dispatch per"):
        contracts.assert_tick_dispatch_bracket("run", 10, 2, 4)
    with pytest.raises(RuntimeError, match="one fused dispatch per"):
        contracts.assert_tick_dispatch_bracket("run", 10, 11, 4)


# ---------------------------------------------------------------------------
# report / waiver plumbing
# ---------------------------------------------------------------------------

def test_waiver_baseline_roundtrip(tmp_path):
    f1 = R.Finding(R.HOST_SYNC, "contract:x", "pure_callback", "m", line=12)
    f2 = R.Finding(R.NONDETERMINISM, "a.py", "time.time", "m")
    rep = R.Report(waivers=[f1.key, "stale::rule::key"])
    rep.extend([f1, f2])
    assert [f.key for f in rep.unwaived()] == [f2.key]
    assert rep.stale_waivers() == ["stale::rule::key"]
    assert ":12" not in f1.key           # line drift never breaks waivers
    p = tmp_path / "report.json"
    rep.write_json(str(p))
    data = json.loads(p.read_text())
    assert data["ok"] is False
    assert data["waived"] == [f1.key]
    assert data["stale_waivers"] == ["stale::rule::key"]


def test_load_baseline(tmp_path):
    assert R.load_baseline(None) == []
    p = tmp_path / "b.json"
    p.write_text('{"waivers": ["a::b::c"]}\n')
    assert R.load_baseline(str(p)) == ["a::b::c"]
    p.write_text('{"waivers": [1]}\n')
    with pytest.raises(ValueError, match="list of finding keys"):
        R.load_baseline(str(p))


def test_committed_baseline_is_empty():
    # CI is strict: the committed baseline carries no waivers (add one only
    # with a comment-worthy reason in the PR that adds it)
    assert R.load_baseline(str(REPO / "analysis_baseline.json")) == []


# ---------------------------------------------------------------------------
# hlo_analysis: unknown dtypes must not silently corrupt byte totals
# ---------------------------------------------------------------------------

_F4_LINE = ("  %r = f4[8,2]{1,0} all-reduce(f4[8,2] %x), "
            "replica_groups={{0,1}}")


def test_unknown_dtype_strict_raises():
    with pytest.raises(ValueError, match="unknown HLO dtype 'f4'"):
        hlo_analysis.parse_collectives(_F4_LINE)


def test_unknown_dtype_nonstrict_warns_once_and_counts():
    hlo_analysis.reset_unknown_dtype_counts()
    try:
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            stats = hlo_analysis.parse_collectives(
                "\n".join([_F4_LINE] * 2), strict=False)
        assert stats.counts == {}        # f4 shapes excluded from totals
        assert stats.link_bytes == 0.0
        msgs = [x for x in w if "unknown HLO dtype" in str(x.message)]
        assert len(msgs) == 1            # warn once per dtype, not per line
        assert hlo_analysis.unknown_dtype_counts() == {"f4": 2}
    finally:
        hlo_analysis.reset_unknown_dtype_counts()
    assert hlo_analysis.unknown_dtype_counts() == {}
