"""Prefill + decode must reproduce the full-forward logits (teacher forcing).

This validates every cache path: attention KV (incl. GQA + plain layout),
mamba conv/ssm state, mLSTM/sLSTM state, and whisper's cross-attention cache.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import model as M
from repro.parallel.sharding import split_tree

pytestmark = pytest.mark.slow    # end-to-end: excluded from the tier-1 CI job

DECODE_ARCHS = ["glm4-9b", "qwen2.5-32b", "minicpm-2b", "xlstm-125m",
                "jamba-1.5-large-398b", "qwen3-moe-30b-a3b"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    # MoE archs: capacity-based token dropping is seq-length dependent by
    # design (training drops, decode never does); no-drop capacity isolates
    # the cache-path equivalence this test is about.
    cfg = get_reduced(arch, capacity_factor=64.0)
    m = M.build(cfg)
    values, _ = split_tree(m.init(jax.random.PRNGKey(1)))
    rng = np.random.default_rng(3)
    b, s_pre, s_dec = 2, 12, 4
    total = s_pre + s_dec
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, total)),
                         jnp.int32)

    full_logits = m.logits(values, {"tokens": tokens})       # (B, T, V)

    logits, cache = m.prefill(values, {"tokens": tokens[:, :s_pre]},
                              max_seq=total)
    errs = [float(jnp.max(jnp.abs(logits - full_logits[:, s_pre - 1])))]
    for t in range(s_pre, total):
        tok = tokens[:, t:t + 1]
        pos = jnp.full((b,), t, jnp.int32)
        logits, cache = m.decode_step(values, tok, pos, cache)
        errs.append(float(jnp.max(jnp.abs(logits - full_logits[:, t]))))
    worst = max(errs)
    assert worst < 2e-2 if cfg.dtype == jnp.float32 else worst < 1e-1, \
        f"{arch}: teacher-forced decode diverged, max err {worst} ({errs})"


def test_whisper_prefill_decode_consistency():
    cfg = get_reduced("whisper-base")
    m = M.build(cfg)
    values, _ = split_tree(m.init(jax.random.PRNGKey(2)))
    rng = np.random.default_rng(5)
    b, s_enc, s_pre, s_dec = 2, 16, 6, 3
    feats = jnp.asarray(rng.standard_normal((b, s_enc, cfg.frontend_dim)),
                        jnp.float32)
    total = s_pre + s_dec
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, total)),
                         jnp.int32)
    full = m.logits(values, {"feats": feats, "tokens": tokens})

    logits, cache = m.prefill(values,
                              {"feats": feats, "tokens": tokens[:, :s_pre]},
                              max_seq=total)
    errs = [float(jnp.max(jnp.abs(logits - full[:, s_pre - 1])))]
    for t in range(s_pre, total):
        pos = jnp.full((b,), t, jnp.int32)
        logits, cache = m.decode_step(values, tokens[:, t:t + 1], pos, cache)
        errs.append(float(jnp.max(jnp.abs(logits - full[:, t]))))
    assert max(errs) < 2e-2, f"whisper decode err {errs}"
