"""The 2-D compressed-comms curve engine (ISSUE 8 tentpole).

Contracts under test:
  * ``run_curves_dp`` trains p_miss lanes x DP shards in ONE fused dispatch
    per ``bits`` value (trace/dispatch counters via the shared
    ``repro.analysis`` assertions) and is deterministic run-to-run;
  * the MEASURED per-step DP payload bits (kept-element counts billed
    through ``CompressedAllReduce.reduce`` inside the scan) equal the
    analytic exact-k bill — the accounting acceptance that the fixed
    ``topk_mask`` makes possible;
  * the 2-D mesh placement (forced host devices, subprocess) is bit-for-bit
    the single-device vmap path, mirroring the 1-D lane-sharding property;
  * ``summarize_dp_curves`` emits the unified uplink + DP report with
    ``total_comm_bits`` per accuracy point;
  * config validation for the DP axis.
"""

import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.analysis.contracts import (assert_fused_dispatches,
                                      assert_trace_count)
from repro.optim.compressed_allreduce import CompressedAllReduce
from repro.sim import results as sim_results
from repro.sim import train_curves as tc

TINY_DP = tc.CurveConfig(bits=(8,), p_miss=(0.0, 0.3), steps=6, batch=16,
                         n_train=128, n_val=64, hw=8, encoder_dims=(8,),
                         embed_dim=8, head_dims=(8,), log_every=3,
                         dp_shards=2)
CAR = CompressedAllReduce.topk(1 / 8)


def test_dp_config_validation():
    with pytest.raises(ValueError):
        dataclasses.replace(TINY_DP, dp_shards=0)
    with pytest.raises(ValueError):         # 16 % 3 != 0
        dataclasses.replace(TINY_DP, dp_shards=3)


def test_dp_engine_one_dispatch_per_bits_value():
    cfg = dataclasses.replace(TINY_DP, bits=(8, 16))
    tc.reset_trace_counts()
    tc.reset_dispatch_counts()
    tc.run_curves_dp(cfg, CAR, n_devices=1)
    traces, disp = tc.trace_counts(), tc.dispatch_counts()
    assert_trace_count(traces["fused_dp"], len(cfg.bits), "dp curve engine")
    assert_fused_dispatches(disp["fused_dp"] / len(cfg.bits), cfg.steps,
                            cfg.log_every)
    # nothing fell back to another driver
    assert all(v == 0 for k, v in disp.items() if k != "fused_dp"), disp


def test_dp_run_is_deterministic():
    a = tc.run_curves_dp(TINY_DP, CAR, n_devices=1)
    b = tc.run_curves_dp(TINY_DP, CAR, n_devices=1)
    assert np.array_equal(a.acc, b.acc)
    assert np.array_equal(a.nll, b.nll)
    assert np.array_equal(a.loss_history, b.loss_history)
    assert np.array_equal(a.dp_payload_bits_total, b.dp_payload_bits_total)
    for x, y in zip(jax.tree.leaves(a.params[0]),
                    jax.tree.leaves(b.params[0])):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    # the lanes really saw different channels
    assert not np.array_equal(a.loss_history[0, :, 0],
                              a.loss_history[0, :, 1])


def test_measured_dp_payload_equals_exact_k_bill():
    """The accounting acceptance: every logged step's measured payload ==
    the analytic exact-k bill (all ranks), and the run total is exactly
    steps x per-step.  Only holds because topk_mask keeps exactly k
    entries — tie inflation would overshoot the analytic number."""
    out = tc.run_curves_dp(TINY_DP, CAR, n_devices=1)
    assert out.dp_payload_bits_step > 0
    assert np.all(out.dp_payload_bits == out.dp_payload_bits_step)
    assert np.all(out.dp_payload_bits_total
                  == out.dp_payload_bits_step * TINY_DP.steps)
    # and the analytic bill really is the policy's per-rank bits x ranks
    from repro.core import vertical
    params0 = jax.eval_shape(
        lambda k: vertical.init(tc._make_steps(TINY_DP, 8)[0], k),
        jax.random.PRNGKey(0))
    assert (out.dp_payload_bits_step
            == CAR.payload_bits(params0) * TINY_DP.dp_shards)
    assert out.dp_dense_bits_step == CAR.dense_bits(params0) * TINY_DP.dp_shards
    assert out.dp_payload_bits_step < out.dp_dense_bits_step


def test_dp_shards_change_math_but_keep_accounting_shape():
    """More ranks: different trajectories (per-rank EF + rank-mean grads)
    but proportionally scaled payload."""
    one = tc.run_curves_dp(dataclasses.replace(TINY_DP, dp_shards=1), CAR,
                           n_devices=1)
    two = tc.run_curves_dp(TINY_DP, CAR, n_devices=1)
    assert two.dp_payload_bits_step == 2 * one.dp_payload_bits_step
    assert not np.array_equal(one.loss_history, two.loss_history)


def test_summarize_dp_curves_unifies_uplink_and_dp(tmp_path):
    out = tc.run_curves_dp(TINY_DP, CAR, n_devices=1)
    recs = sim_results.summarize_dp_curves(out)
    assert len(recs) == len(TINY_DP.bits) * len(TINY_DP.p_miss)
    r0 = recs[0]
    # uplink half: the protocol's own analytic load, batch samples per step
    fed = TINY_DP.protocol(8).comm_load(TINY_DP.n_workers, TINY_DP.embed_dim)
    assert r0["uplink_bits_step"] == fed.uplink_bits * TINY_DP.batch
    assert r0["uplink_bits_total"] == r0["uplink_bits_step"] * TINY_DP.steps
    # DP half: the measured totals from the run
    assert r0["dp_payload_bits_total"] == int(out.dp_payload_bits_total[0, 0])
    assert 0 < r0["dp_payload_frac"] < 1
    # THE one number
    assert (r0["total_comm_bits"]
            == r0["uplink_bits_total"] + r0["dp_payload_bits_total"])
    rows = sim_results.dp_curve_rows(recs)
    assert len(rows) == len(recs)
    assert rows[0].startswith("dp_curves/b8_p0,")
    assert "total_bits=" in rows[0]
    sim_results.write_json(recs, str(tmp_path / "dp.json"))
    loaded = json.loads((tmp_path / "dp.json").read_text())
    assert loaded[1]["p_miss"] == 0.3


def test_sharded_dp_curves_match_vmap_path():
    """The 2-D (lanes x DP) mesh over >=2 forced host devices is bit-for-bit
    the single-device vmap path — covering the 2x2 mesh, the dp-only 1x2
    mesh, and lane padding (3 lanes on 2 lane-devices)."""
    import os
    import subprocess
    import sys
    import textwrap

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import jax, numpy as np
        assert jax.local_device_count() == 4, jax.devices()
        from repro.optim.compressed_allreduce import CompressedAllReduce
        from repro.sim import train_curves as tc
        from repro.sim.shard import dp_mesh_shape
        # 3 lanes (indivisible -> padding) incl. a per-worker near/far lane
        cfg = tc.CurveConfig(bits=(8,), p_miss=(0.0, (0.0, 0.1, 0.1, 0.3),
                                                0.3),
                             steps=6, batch=16, n_train=128, n_val=64, hw=8,
                             encoder_dims=(8,), embed_dim=8, head_dims=(8,),
                             log_every=3, dp_shards=2)
        car = CompressedAllReduce.topk(1/8)
        assert dp_mesh_shape(4, 3, 2) == (2, 2)   # full 2-D mesh
        assert dp_mesh_shape(2, 3, 2) == (1, 2)   # dp-only mesh
        assert dp_mesh_shape(1, 3, 2) == (1, 1)   # vmap fallback
        ref = tc.run_curves_dp(cfg, car, n_devices=1)
        for n_dev in (None, 2, 4):     # None = auto-detect (4 devices)
            got = tc.run_curves_dp(cfg, car, n_devices=n_dev)
            assert np.array_equal(ref.acc, got.acc), n_dev
            assert np.array_equal(ref.nll, got.nll), n_dev
            assert np.array_equal(ref.loss_history, got.loss_history), n_dev
            assert np.array_equal(ref.dp_payload_bits,
                                  got.dp_payload_bits), n_dev
            assert np.array_equal(ref.dp_payload_bits_total,
                                  got.dp_payload_bits_total), n_dev
            for x, y in zip(jax.tree.leaves(ref.params[0]),
                            jax.tree.leaves(got.params[0])):
                assert np.array_equal(np.asarray(x), np.asarray(y)), n_dev
        print("SHARDED_DP_CURVES_OK")
    """)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=900)
    assert proc.returncode == 0, f"OUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    assert "SHARDED_DP_CURVES_OK" in proc.stdout
