"""Dry-run machinery tests on a small forced-device mesh (subprocess).

The production 512-device sweep runs via ``launch/dryrun.py --all``; here we
verify the cell-builder produces lowerable programs for each step kind on an
8-device host, and that the scan-cost extrapolation helper is coherent.
"""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    assert proc.returncode == 0, f"OUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


def test_reduced_cells_lower_and_compile_all_step_kinds():
    out = _run("""
        import jax
        from repro.configs import get_reduced
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_debug_mesh, rules_for
        from repro.launch import dryrun
        from repro.models import model as M
        from repro.optim import optimizers, schedules
        from repro.parallel import sharding as sh
        from repro.train.train_step import make_train_step

        mesh = make_debug_mesh(2, 4)
        shapes = [ShapeConfig("t", "train", 32, 8),
                  ShapeConfig("p", "prefill", 64, 4),
                  ShapeConfig("d", "decode", 64, 8)]
        cfg = get_reduced("glm4-9b", n_workers=4)
        m = M.build(cfg)
        import jax.numpy as jnp
        for shape in shapes:
            rules = rules_for(shape.name, shape.global_batch, mesh)
            values_sds, axes = sh.split_tree(
                jax.eval_shape(m.init, jax.random.PRNGKey(0)))
            param_sh = sh.tree_shardings_for_values(axes, values_sds, mesh,
                                                    rules)
            specs, in_axes = m.input_specs(shape)
            batch_sh = sh.tree_shardings_for_values(in_axes, specs, mesh,
                                                    rules)
            with sh.use_mesh(mesh, rules):
                if shape.kind == "train":
                    opt = optimizers.adamw(schedules.constant(1e-4))
                    opt_sds = jax.eval_shape(opt.init, values_sds)
                    step = make_train_step(m.loss, opt)
                    c = jax.jit(step).lower(values_sds, opt_sds,
                                            specs).compile()
                elif shape.kind == "prefill":
                    c = jax.jit(lambda v, b: m.prefill(
                        v, b, max_seq=shape.seq_len)).lower(
                            values_sds, specs).compile()
                else:
                    c = jax.jit(m.decode_step).lower(
                        values_sds, specs["token"], specs["positions"],
                        specs["cache"]).compile()
            ca = c.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            assert float(dict(ca).get("flops", 0)) > 0, shape.kind
            print("OK", shape.kind)
        print("CELLS_OK")
    """)
    assert "CELLS_OK" in out


def test_scaled_variants_logic():
    from repro.configs import get_config
    from repro.launch.dryrun import _scaled_variants

    cfg = get_config("jamba-1.5-large-398b")
    v = _scaled_variants(cfg, microbatches=8)
    assert v["b"]["n_layers"] == 8 and v["c"]["n_layers"] == 16
    assert v["b"]["microbatches"] == 1
    assert v["n_periods"] == 9

    w = _scaled_variants(get_config("whisper-base"), 1)
    assert w["b"]["n_encoder_layers"] == 1
    assert w["c"]["n_encoder_layers"] == 2


def test_model_flops_accounting():
    from repro.configs import get_config, SHAPES
    from repro.launch.dryrun import _model_flops

    cfg = get_config("glm4-9b")
    train = _model_flops(cfg, SHAPES["train_4k"])
    assert train == 6.0 * cfg.param_count(True) * 256 * 4096
    dec = _model_flops(cfg, SHAPES["decode_32k"])
    assert dec == 2.0 * cfg.param_count(True) * 128
