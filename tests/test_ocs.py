"""Protocol-level properties: OCS contention == distributed argmax."""

import jax.numpy as jnp
import numpy as np
import pytest

from proptest import grid, random_floats, sweep
from repro.core import channel, ocs


@pytest.mark.parametrize("bits", [8, 16])
def test_protocol_equals_argmax_oracle(bits):
    def prop(case):
        n, k, seed = case["n"], case["k"], case["seed"]
        h = jnp.asarray(random_floats(seed, (n, k), specials=False))
        res = ocs.ocs_maxpool(h, bits=bits)
        w, v, c = ocs.reference_maxpool(h, bits)
        assert np.array_equal(np.asarray(res.winner), np.asarray(w))
        assert np.array_equal(np.asarray(res.pooled_code), np.asarray(c))
        assert np.array_equal(np.asarray(res.value), np.asarray(v))
    sweep(prop, list(grid(n=[2, 5, 16], k=[1, 7, 33], seed=[0, 1, 2])))


def test_tie_break_lowest_index():
    h0 = jnp.asarray(random_floats(0, (1, 16), specials=False))
    h = jnp.concatenate([h0, h0, h0], axis=0)       # all workers tied
    res = ocs.ocs_maxpool(h, bits=16)
    assert np.all(np.asarray(res.winner) == 0)
    assert np.all(np.asarray(res.ties) == 3)


def test_contention_slot_count():
    """K sub-frames x (D + id bits) sub-slots — paper Alg. 1 accounting."""
    n, k, bits = 4, 10, 8
    h = jnp.asarray(random_floats(1, (n, k), specials=False))
    res = ocs.ocs_maxpool(h, bits=bits)
    id_bits = 2    # ceil(log2(4))
    assert int(res.contention_slots) == k * (bits + id_bits)
    assert int(res.payload_tx) == k
    assert int(res.concat_payload_tx) == n * k


def test_single_payload_per_subframe_independent_of_n():
    """The paper's O(K) claim: payload count does not grow with N."""
    k = 16
    for n in (2, 8, 32):
        h = jnp.asarray(random_floats(n, (n, k), specials=False))
        res = ocs.ocs_maxpool(h, bits=8)
        assert int(res.payload_tx) == k


def test_multichannel_latency_divides():
    h = jnp.asarray(random_floats(2, (4, 32), specials=False))
    r1 = ocs.ocs_maxpool(h, bits=8)
    r4 = ocs.ocs_maxpool_multichannel(h, bits=8, n_channels=4)
    assert int(r4.latency_slots) == -(-int(r1.contention_slots) // 4)
    # striping never changes the protocol outcome or transmission counts:
    # OFDMA latency lives in latency_slots only (docstring contract)
    assert int(r4.result.contention_slots) == int(r1.contention_slots)
    assert int(r4.result.blocking_tx) == int(r1.blocking_tx)
    assert np.array_equal(np.asarray(r1.winner), np.asarray(r4.result.winner))


def test_comm_load_payload_bits():
    """uplink_bits must follow ChannelConfig.payload_bits, not a fixed 32."""
    k, n = 16, 8
    for pb in (8, 16, 32, 64):
        cfg = channel.ChannelConfig(payload_bits=pb)
        f = channel.ocs_load(n, k, bits=8, cfg=cfg)
        c = channel.concat_load(n, k, cfg=cfg)
        m = channel.mean_load(n, k, cfg=cfg)
        a = channel.avg_pred_load(n, k, cfg=cfg)
        assert f.payload_bits == pb
        assert f.uplink_bits == k * pb + f.uplink_overhead_bits
        for load in (c, m, a):
            assert load.uplink_bits == load.uplink_payload_msgs * pb
        # bits accounting consistent with the latency model: fedocs payload
        # slots inside latency use the same width as uplink_bits
        assert f.latency_slots == f.uplink_overhead_bits + k * pb
    # default stays the historical 32-bit float payload
    assert channel.ocs_load(n, k, bits=8).payload_bits == 32


def test_comm_load_scaling():
    """Uplink messages: fedocs O(K) vs concat/mean O(N*K)."""
    k = 64
    for n in (4, 9, 64):
        f = channel.ocs_load(n, k, bits=16)
        c = channel.concat_load(n, k)
        m = channel.mean_load(n, k)
        assert f.uplink_payload_msgs == k
        assert c.uplink_payload_msgs == n * k
        assert m.uplink_payload_msgs == n * k
        assert f.downlink_msgs == k            # single gradient broadcast
        assert c.downlink_msgs == n * k


def test_tp_fusion_bytes_model():
    """ICI analytic model: concat costs ~N x the max/sum all-reduce."""
    k, n = 4096, 16
    ar = channel.tp_fusion_bytes("max", k, n)
    ag = channel.tp_fusion_bytes("concat", k, n)
    q8 = channel.tp_fusion_bytes("max_q8", k, n)
    assert ag / ar == pytest.approx(n / 2, rel=0.1)
    assert q8 == ar // 2
