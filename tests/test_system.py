"""End-to-end behaviour tests for the paper's system.

The headline claims, verified end-to-end at smoke scale:
  1. FedOCS (max-pool) training reaches the fused-information regime: it
     beats the best single worker by a wide margin and is comparable to the
     comm-heavy concat baseline (paper Table I structure).
  2. Its uplink cost is O(K), independent of the worker count (paper §I).
  3. The protocol layer (OCS contention) selects exactly the argmax winners
     that the in-model max-pool backward routes gradients to.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow    # end-to-end: excluded from the tier-1 CI job

from repro.core import aggregators, fedocs, ocs, vertical
from repro.core.vertical import VerticalConfig
from repro.data.vertical_data import PatchTaskConfig, patch_classification
from repro.optim import optimizers, schedules


def _train(cfg, views, labels, steps=150, seed=0):
    params = vertical.init(cfg, jax.random.PRNGKey(seed))
    opt = optimizers.adamw(schedules.linear_warmup_cosine(3e-3, 10, steps))
    state = opt.init(params)

    @jax.jit
    def step(params, state, vb, lb):
        g = jax.grad(lambda p: vertical.loss_fn(cfg, p, vb, lb)[0])(params)
        params, state, _ = opt.update(g, state, params)
        return params, state

    rng = np.random.default_rng(seed)
    n = views.shape[1]
    for _ in range(steps):
        idx = rng.integers(0, n, 64)
        params, state = step(params, state, views[:, idx], labels[idx])
    return params


def test_fedocs_end_to_end_beats_best_worker():
    task = PatchTaskConfig(n_classes=4, grid=2, hw=16, sigma=0.5)
    views, labels = patch_classification(task, 4096, seed=0)
    tv, tl = patch_classification(task, 512, seed=1)
    views_j, labels_j = jnp.asarray(views), jnp.asarray(labels)
    tv_j, tl_j = jnp.asarray(tv), jnp.asarray(tl)

    base = VerticalConfig(n_workers=4, input_dim=views.shape[-1],
                          encoder_dims=(128, 64), embed_dim=32,
                          head_dims=(128, 64), output_dim=task.n_classes,
                          task="classification")
    accs = {}
    for method in ("fedocs", "best_worker_pred"):
        cfg = aggregators.table1_config(method, base)
        params = _train(cfg, views_j, labels_j, steps=500)
        if method == "best_worker_pred":
            preds = vertical.per_worker_predictions(cfg, params, tv_j)
            accs[method] = max(
                float(jnp.mean(jnp.argmax(preds[i], -1) == tl_j))
                for i in range(4))
        else:
            _, m = vertical.loss_fn(cfg, params, tv_j, tl_j)
            accs[method] = float(m["acc"])

    # single workers are at chance BY CONSTRUCTION (relational task);
    # fedocs fusion must decode the cross-patch relation
    assert accs["best_worker_pred"] < 0.45, accs
    assert accs["fedocs"] > accs["best_worker_pred"] + 0.2, accs


def test_uplink_independent_of_workers():
    k = 64
    loads = [vertical.comm_load(VerticalConfig(
        n_workers=n, embed_dim=k)).uplink_payload_msgs for n in (2, 8, 32)]
    assert loads[0] == loads[1] == loads[2] == k


def test_protocol_winners_match_gradient_routing():
    """OCS channel winners == the workers that receive max-pool gradient."""
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.standard_normal((6, 32)).astype(np.float32))
    res = ocs.ocs_maxpool(h, bits=16)
    g = jax.grad(lambda x: jnp.sum(
        fedocs.maxpool_quantized(x, 16, "first")))(h)
    grad_winners = jnp.argmax(jnp.abs(g) > 0, axis=0)
    assert np.array_equal(np.asarray(res.winner), np.asarray(grad_winners))
