"""Trainer: convergence, auto-resume, straggler substitution, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data import pipeline
from repro.models import model as M
from repro.optim import optimizers, schedules
from repro.parallel.sharding import split_tree
from repro.train import trainer
from repro.train.trainer import TrainerConfig

pytestmark = pytest.mark.slow    # end-to-end: excluded from the tier-1 CI job


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("qwen1.5-0.5b", n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=128, n_workers=2)
    m = M.build(cfg)
    values, _ = split_tree(m.init(jax.random.PRNGKey(0)))
    pcfg = pipeline.for_model(cfg, batch=8, seq_len=16, seed=1)
    return m, values, pcfg


def _opt(steps):
    return optimizers.adamw(schedules.linear_warmup_cosine(3e-3, 3, steps))


def test_loss_decreases(setup):
    m, values, pcfg = setup
    res = trainer.train(m.loss, values, _opt(40),
                        lambda s: pipeline.batch_for_step(pcfg, s),
                        TrainerConfig(steps=40, ckpt_dir=None, log_every=5))
    assert res.history[-1]["nll"] < res.history[0]["nll"]


def test_auto_resume(setup, tmp_path):
    m, values, pcfg = setup
    d = str(tmp_path)
    data = lambda s: pipeline.batch_for_step(pcfg, s)
    trainer.train(m.loss, values, _opt(50), data,
                  TrainerConfig(steps=20, ckpt_dir=d, ckpt_every=10,
                                log_every=5))
    res = trainer.train(m.loss, values, _opt(50), data,
                        TrainerConfig(steps=30, ckpt_dir=d, ckpt_every=10,
                                      log_every=5))
    assert res.history[0]["step"] >= 20      # resumed, not restarted


def test_straggler_substitution(setup):
    m, values, pcfg = setup
    res = trainer.train(
        m.loss, values, _opt(6),
        lambda s: pipeline.batch_for_step(pcfg, s),
        TrainerConfig(steps=6, ckpt_dir=None, data_deadline_s=0.1,
                      log_every=2),
        delay_injector=lambda s: 0.5 if s in (2, 4) else 0.0)
    assert res.substituted_steps == [2, 4]


def test_compressed_training_still_converges(setup):
    m, values, pcfg = setup
    res = trainer.train(m.loss, values, _opt(40),
                        lambda s: pipeline.batch_for_step(pcfg, s),
                        TrainerConfig(steps=40, ckpt_dir=None, log_every=5,
                                      compress_k=1 / 16))
    assert res.history[-1]["nll"] < res.history[0]["nll"]


def test_microbatch_equivalence(setup):
    """Grad accumulation over microbatches ~ single big batch step."""
    m, values, pcfg = setup
    from repro.train.train_step import make_train_step
    batch = pipeline.batch_for_step(pcfg, 0)
    opt = optimizers.sgd(schedules.constant(0.1), momentum=0.0)
    s1 = opt.init(values)
    s2 = opt.init(values)
    f1 = jax.jit(make_train_step(m.loss, opt, microbatches=1))
    f2 = jax.jit(make_train_step(m.loss, opt, microbatches=2))
    v1, _, _ = f1(values, s1, batch)
    v2, _, _ = f2(values, s2, batch)
    errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), v1, v2)
    assert max(jax.tree.leaves(errs)) < 5e-3
