"""SSM mixers: mamba/mLSTM/sLSTM step-vs-full consistency and properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import mamba, ssm
from repro.parallel.sharding import split_tree


def _values(init_fn, cfg, seed=0):
    tagged = init_fn(cfg, jax.random.PRNGKey(seed))
    return split_tree(tagged)[0]


def test_mamba_step_matches_full():
    cfg = get_reduced("jamba-1.5-large-398b")
    p = _values(mamba.mamba_init, cfg)
    rng = np.random.default_rng(0)
    b, s = 2, 12
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    y_full = mamba.mamba_full(cfg, p, x)
    cache = mamba.init_cache(cfg, b, jnp.float32)
    ys = []
    for t in range(s):
        y, cache = mamba.mamba_step(cfg, p, x[:, t:t + 1], cache)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    err = float(jnp.max(jnp.abs(y_full - y_step)))
    assert err < 1e-4, err


def test_mamba_assoc_scan_matches_sequential():
    cfg = get_reduced("jamba-1.5-large-398b")
    cfg2 = cfg.with_(mamba_assoc_scan=True)
    p = _values(mamba.mamba_init, cfg)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(
        (2, 16, cfg.d_model)), jnp.float32)
    y_seq = mamba.mamba_full(cfg, p, x)
    y_assoc = mamba.mamba_full(cfg2, p, x)
    err = float(jnp.max(jnp.abs(y_seq - y_assoc)))
    assert err < 1e-3, err


def test_mlstm_step_matches_full():
    cfg = get_reduced("xlstm-125m")
    p = _values(ssm.mlstm_init, cfg)
    rng = np.random.default_rng(2)
    b, s = 2, 10
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    y_full = ssm.mlstm_full(cfg, p, x)
    state = ssm.mlstm_state_init(cfg, b)
    ys = []
    for t in range(s):
        y, state = ssm.mlstm_step(cfg, p, x[:, t:t + 1], state)
        ys.append(y)
    err = float(jnp.max(jnp.abs(y_full - jnp.concatenate(ys, 1))))
    assert err < 1e-4, err


def test_slstm_step_matches_full():
    cfg = get_reduced("xlstm-125m")
    p = _values(ssm.slstm_init, cfg)
    rng = np.random.default_rng(3)
    b, s = 2, 10
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    y_full = ssm.slstm_full(cfg, p, x)
    state = ssm.slstm_state_init(cfg, b)
    ys = []
    for t in range(s):
        y, state = ssm.slstm_step(cfg, p, x[:, t:t + 1], state)
        ys.append(y)
    err = float(jnp.max(jnp.abs(y_full - jnp.concatenate(ys, 1))))
    assert err < 1e-4, err


def test_mamba_state_bounded():
    """|h| stays bounded (A < 0 discretization contracts)."""
    cfg = get_reduced("jamba-1.5-large-398b")
    p = _values(mamba.mamba_init, cfg, seed=5)
    x = jnp.asarray(np.random.default_rng(4).standard_normal(
        (1, 64, cfg.d_model)), jnp.float32)
    _, cache = mamba.mamba_full(cfg, p, x, return_cache=True)
    assert float(jnp.max(jnp.abs(cache["h"]))) < 1e3
