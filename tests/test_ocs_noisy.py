"""Imperfect-sensing OCS extension (beyond the paper's error-free §IV)."""

import jax
import jax.numpy as jnp
import numpy as np

from proptest import random_floats, seeds, sweep
from repro.core import ocs


def test_zero_miss_reduces_to_exact_protocol():
    def prop(seed):
        h = jnp.asarray(random_floats(seed, (6, 24), specials=False))
        clean = ocs.ocs_maxpool(h, bits=12)
        noisy = ocs.ocs_maxpool_noisy(h, jax.random.PRNGKey(seed), bits=12,
                                      p_miss=0.0)
        assert np.array_equal(np.asarray(noisy.winner),
                              np.asarray(clean.winner))
        assert bool(jnp.all(noisy.correct))
        assert int(noisy.collisions) == 0
    sweep(prop, list(seeds(5)), "seed")


def test_miss_detection_degrades_gracefully():
    """A false survivor can eliminate the true winner (it blocks a slot the
    winner is sensing), so corruption scales with N*D*p_miss: measured ~5%
    winner loss at p=0.01 and ~20% at p=0.05 for N=16, D=12 — graceful, and
    the transmitted value is always a real observation (never corrupted)."""
    h = jnp.asarray(random_floats(0, (16, 64), specials=False))
    res = ocs.ocs_maxpool_noisy(h, jax.random.PRNGKey(1), bits=12,
                                p_miss=0.02, max_rounds=3)
    frac_correct = float(jnp.mean(res.correct))
    assert frac_correct > 0.8
    # an incorrect winner still transmits a real (<= max) value:
    codes_win = jnp.take_along_axis(
        jnp.asarray(np.asarray(h)), res.winner[None, :], axis=0)[0]
    assert bool(jnp.all(codes_win <= jnp.max(h, axis=0) + 1e-6))


def test_zero_miss_rounds_and_slots_match_clean_protocol():
    """p_miss=0 resolves in ONE round and consumes exactly the clean-protocol
    slot budget — the historical accounting reported rounds=max_rounds and
    re-billed all K sub-frames every round."""
    def prop(seed):
        h = jnp.asarray(random_floats(seed, (6, 24), specials=False))
        clean = ocs.ocs_maxpool(h, bits=12)
        noisy = ocs.ocs_maxpool_noisy(h, jax.random.PRNGKey(seed), bits=12,
                                      p_miss=0.0, max_rounds=3)
        assert int(noisy.rounds) == 1
        assert int(noisy.contention_slots) == int(clean.contention_slots)
    sweep(prop, list(seeds(4)), "seed")


def test_certain_miss_rounds_and_slots_hand_computed():
    """p_miss ~= 1: nobody ever hears a blocking signal, so every worker
    survives every sub-slot — all max_rounds rounds re-contend with ALL K
    sub-frames unresolved, then the lowest index captures.  Every quantity
    is hand-computable: rounds == max_rounds, slots == max_rounds * (D +
    id_bits) * K, collisions == max_rounds * K, winner == worker 0."""
    n, k, bits, max_rounds = 5, 7, 10, 3
    h = jnp.asarray(random_floats(11, (n, k), specials=False))
    res = ocs.ocs_maxpool_noisy(h, jax.random.PRNGKey(0), bits=bits,
                                p_miss=1.0 - 1e-12, max_rounds=max_rounds)
    total_bits = bits + ocs.host_id_bits(n)
    assert int(res.rounds) == max_rounds
    assert int(res.contention_slots) == max_rounds * total_bits * k
    assert int(res.collisions) == max_rounds * k
    assert np.all(np.asarray(res.winner) == 0)


def test_partial_resolution_bills_only_unresolved_subframes():
    """Re-contention slots scale with the sub-frames still contending: the
    total must sit strictly between one full round and max_rounds full
    rounds whenever some (but not all) sub-frames resolve in round one, and
    must satisfy slots == total_bits * (K + sum of per-round unresolved)."""
    h = jnp.asarray(random_floats(0, (16, 64), specials=False))
    total_bits = 12 + ocs.host_id_bits(16)
    res = ocs.ocs_maxpool_noisy(h, jax.random.PRNGKey(1), bits=12,
                                p_miss=0.3, max_rounds=4)
    slots = int(res.contention_slots)
    rounds = int(res.rounds)
    assert 1 <= rounds <= 4
    full_round = total_bits * 64
    assert slots >= full_round                  # round 1 bills all K
    if rounds > 1:
        # later rounds bill strictly fewer than all K sub-frames each
        # unless literally nothing resolved (astronomically unlikely here)
        assert slots < rounds * full_round
    # slot total is a multiple of the per-sub-frame contention length
    assert slots % total_bits == 0


def test_higher_miss_rate_more_collisions():
    h = jnp.asarray(random_floats(2, (16, 64), specials=False))
    lo = ocs.ocs_maxpool_noisy(h, jax.random.PRNGKey(0), bits=12,
                               p_miss=0.05)
    hi = ocs.ocs_maxpool_noisy(h, jax.random.PRNGKey(0), bits=12,
                               p_miss=0.5)
    assert int(hi.collisions) >= int(lo.collisions)
    assert float(jnp.mean(hi.correct)) <= float(jnp.mean(lo.correct)) + 0.05


# ---------------------------------------------------------------------------
# heterogeneous per-worker p_miss (near/far users)
# ---------------------------------------------------------------------------

def test_per_worker_p_miss_broadcast_equals_scalar():
    """An (N,) p_miss with every entry equal must be bit-for-bit the scalar
    path (the uniform sensing draw is threshold-independent), through both
    contention backends."""
    def prop(case):
        n, p = 6, case["p"]
        h = jnp.asarray(random_floats(case["seed"], (n, 24), specials=False))
        key = jax.random.PRNGKey(case["seed"])
        pv = jnp.full((n,), p, jnp.float32)
        for backend in ("scan", "pallas"):
            a = ocs.ocs_maxpool_noisy(h, key, bits=12, p_miss=p,
                                      backend=backend)
            b = ocs.ocs_maxpool_noisy(h, key, bits=12, p_miss=pv,
                                      backend=backend)
            for f in ("winner", "correct", "collisions", "rounds",
                      "contention_slots"):
                assert np.array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f))), \
                    f"{backend}/{f}"
    sweep(prop, [{"p": p, "seed": s} for p in (0.0, 0.1, 0.4)
                 for s in (0, 1)], "case")


def test_per_worker_p_miss_monotone_win_rate():
    """Raising one worker's own p_miss never decreases its win rate.

    Direction matters: ``p_miss`` is *receiver-side* — a worker that misses
    others' blocking signals survives sub-slots it should have conceded, so
    a deafer worker becomes an aggressive false survivor and (with
    lowest-index capture) wins weakly MORE often, not less.  The draws are
    coupled (same rng => same uniforms, only the threshold moves), so the
    effect is monotone up to rare second-order chains; a small epsilon
    absorbs those."""
    def prop(seed):
        n, k = 8, 256
        h = jnp.asarray(random_floats(seed, (n, k), specials=False))
        key = jax.random.PRNGKey(seed)
        target = 3
        rates = []
        for p_t in (0.05, 0.2, 0.5, 0.8):
            pv = jnp.full((n,), 0.05, jnp.float32).at[target].set(p_t)
            res = ocs.ocs_maxpool_noisy(h, key, bits=10, p_miss=pv)
            rates.append(float(np.mean(np.asarray(res.winner) == target)))
        for lo, hi in zip(rates, rates[1:]):
            assert hi >= lo - 0.02, rates
        # and the effect is substantial end to end
        assert rates[-1] > rates[0], rates
    sweep(prop, list(seeds(3)), "seed")


def test_per_worker_p_miss_degrades_far_users_detection():
    """In a near/far cell the far (deaf) half causes more collisions than a
    uniformly-near cell, and correctness degrades."""
    from repro.sim.scenarios import near_far_p_miss
    h = jnp.asarray(random_floats(3, (8, 64), specials=False))
    key = jax.random.PRNGKey(0)
    near = ocs.ocs_maxpool_noisy(h, key, bits=12,
                                 p_miss=jnp.zeros((8,), jnp.float32))
    mixed = ocs.ocs_maxpool_noisy(
        h, key, bits=12,
        p_miss=jnp.asarray(near_far_p_miss(8, 0.0, 0.5), jnp.float32))
    assert int(mixed.collisions) > int(near.collisions)
    assert float(jnp.mean(mixed.correct)) <= float(jnp.mean(near.correct))
