"""Imperfect-sensing OCS extension (beyond the paper's error-free §IV)."""

import jax
import jax.numpy as jnp
import numpy as np

from proptest import random_floats, seeds, sweep
from repro.core import ocs


def test_zero_miss_reduces_to_exact_protocol():
    def prop(seed):
        h = jnp.asarray(random_floats(seed, (6, 24), specials=False))
        clean = ocs.ocs_maxpool(h, bits=12)
        noisy = ocs.ocs_maxpool_noisy(h, jax.random.PRNGKey(seed), bits=12,
                                      p_miss=0.0)
        assert np.array_equal(np.asarray(noisy.winner),
                              np.asarray(clean.winner))
        assert bool(jnp.all(noisy.correct))
        assert int(noisy.collisions) == 0
    sweep(prop, list(seeds(5)), "seed")


def test_miss_detection_degrades_gracefully():
    """A false survivor can eliminate the true winner (it blocks a slot the
    winner is sensing), so corruption scales with N*D*p_miss: measured ~5%
    winner loss at p=0.01 and ~20% at p=0.05 for N=16, D=12 — graceful, and
    the transmitted value is always a real observation (never corrupted)."""
    h = jnp.asarray(random_floats(0, (16, 64), specials=False))
    res = ocs.ocs_maxpool_noisy(h, jax.random.PRNGKey(1), bits=12,
                                p_miss=0.02, max_rounds=3)
    frac_correct = float(jnp.mean(res.correct))
    assert frac_correct > 0.8
    # an incorrect winner still transmits a real (<= max) value:
    codes_win = jnp.take_along_axis(
        jnp.asarray(np.asarray(h)), res.winner[None, :], axis=0)[0]
    assert bool(jnp.all(codes_win <= jnp.max(h, axis=0) + 1e-6))


def test_higher_miss_rate_more_collisions():
    h = jnp.asarray(random_floats(2, (16, 64), specials=False))
    lo = ocs.ocs_maxpool_noisy(h, jax.random.PRNGKey(0), bits=12,
                               p_miss=0.05)
    hi = ocs.ocs_maxpool_noisy(h, jax.random.PRNGKey(0), bits=12,
                               p_miss=0.5)
    assert int(hi.collisions) >= int(lo.collisions)
    assert float(jnp.mean(hi.correct)) <= float(jnp.mean(lo.correct)) + 0.05
