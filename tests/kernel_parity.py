"""Unified kernel-parity harness: any Pallas op vs its ``ref.py`` oracle.

Every kernel package (``repro.kernels.{maxpool, ocs_quant, flash_attention,
ocs_contention}``) ships a pure-jnp/lax reference; this module is the single
place that compares the two, replacing the hand-rolled comparison loops the
per-kernel test files used to carry.  A :class:`ParityOp` binds

  * ``make``      — a case dict -> the positional inputs both sides take,
  * ``kernel``    — the Pallas entry point (interpret mode on CPU CI),
  * ``reference`` — the oracle with the identical signature,
  * ``cases``     — a ``proptest.grid``-style case list (dtype/shape/seed),

and :func:`check` sweeps the grid via ``proptest.sweep`` (failures are
annotated with the offending case), asserting

  * **forward parity** on the full output pytree — bit-for-bit
    (``atol=0``: equal shapes, dtypes, and every bit of every leaf) or
    within an absolute tolerance for accumulation-order-sensitive kernels
    (flash attention); a per-case ``atol`` key overrides the op default;
  * **vjp parity** when ``diff_argnums`` is set: both sides are pulled back
    through ``jax.vjp`` with the same cotangent (``cotangent(case, primal)``
    or ones) and every input cotangent must agree to ``grad_atol``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from proptest import sweep


@dataclasses.dataclass(frozen=True)
class ParityOp:
    """One kernel-vs-reference binding for the parity sweep."""

    name: str
    make: Callable[[dict], Tuple]            # case -> positional inputs
    kernel: Callable[..., Any]               # Pallas side
    reference: Callable[..., Any]            # jnp/lax oracle
    cases: Sequence[dict] = ()
    atol: float = 0.0                        # 0.0 => bit-for-bit
    diff_argnums: Tuple[int, ...] = ()       # nonempty => assert vjp parity
    grad_atol: Optional[float] = None        # defaults to ``atol``
    cotangent: Optional[Callable[[dict, Any], Any]] = None


def assert_trees_match(got, want, *, atol: float = 0.0, what: str = "output",
                       name: str = "op"):
    """Structure + shape + dtype always; values bit-for-bit iff atol==0."""
    got_l, got_tree = jax.tree.flatten(got)
    want_l, want_tree = jax.tree.flatten(want)
    assert got_tree == want_tree, \
        f"{name} {what}: tree {got_tree} != {want_tree}"
    for i, (a, b) in enumerate(zip(got_l, want_l)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape, \
            f"{name} {what} leaf {i}: shape {a.shape} != {b.shape}"
        assert a.dtype == b.dtype, \
            f"{name} {what} leaf {i}: dtype {a.dtype} != {b.dtype}"
        if atol == 0.0:
            assert np.array_equal(a, b), \
                f"{name} {what} leaf {i}: kernel != reference (bit-for-bit)"
        else:
            err = float(np.max(np.abs(a.astype(np.float64)
                                      - b.astype(np.float64))))
            assert err <= atol, \
                f"{name} {what} leaf {i}: max err {err} > atol {atol}"


def _vjp_through(fn, args, diff_argnums, cotangent):
    """Pull ``cotangent`` back through ``fn`` w.r.t. ``diff_argnums``."""
    args = list(args)

    def closed(*diff_args):
        full = list(args)
        for pos, val in zip(diff_argnums, diff_args):
            full[pos] = val
        return fn(*full)

    primal, vjp_fn = jax.vjp(closed, *[args[i] for i in diff_argnums])
    return primal, vjp_fn(cotangent)


def check_case(op: ParityOp, case: dict):
    """Assert forward (and configured vjp) parity for one case."""
    args = op.make(case)
    atol = case.get("atol", op.atol)
    out_k = op.kernel(*args)
    out_r = op.reference(*args)
    assert_trees_match(out_k, out_r, atol=atol, what="forward", name=op.name)
    if op.diff_argnums:
        ct = (op.cotangent(case, out_r) if op.cotangent is not None
              else jax.tree.map(jnp.ones_like, out_r))
        prim_k, grads_k = _vjp_through(op.kernel, args, op.diff_argnums, ct)
        prim_r, grads_r = _vjp_through(op.reference, args, op.diff_argnums,
                                       ct)
        gatol = case.get("grad_atol",
                         op.grad_atol if op.grad_atol is not None else atol)
        assert_trees_match(prim_k, prim_r, atol=atol, what="vjp primal",
                           name=op.name)
        assert_trees_match(grads_k, grads_r, atol=gatol, what="vjp grads",
                           name=op.name)


def check(op: ParityOp):
    """Sweep every case of ``op`` (the per-kernel test entry point)."""
    assert op.cases, f"{op.name}: empty case grid"
    sweep(functools.partial(check_case, op), list(op.cases), label=op.name)
