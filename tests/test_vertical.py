"""Paper's vertical learner: shapes, losses, Table-I method registry."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregators, vertical
from repro.core.vertical import VerticalConfig
from repro.protocol import Protocol


def _cfg(**kw):
    base = dict(n_workers=4, input_dim=32, encoder_dims=(16,), embed_dim=8,
                head_dims=(16,), output_dim=10, task="classification")
    base.update(kw)
    return VerticalConfig(**base)


def _data(cfg, b=6, seed=0):
    rng = np.random.default_rng(seed)
    views = jnp.asarray(rng.standard_normal(
        (cfg.n_workers, b, cfg.input_dim)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, cfg.output_dim, (b,)), jnp.int32)
    return views, labels


@pytest.mark.parametrize("agg", ["max", "mean", "concat", "sum", "max_q8",
                                 Protocol.max(), Protocol.ideal_max(16),
                                 Protocol.concat()])
def test_forward_shapes_all_aggregations(agg):
    """String sugar and first-class Protocol values are interchangeable."""
    cfg = _cfg(aggregation=agg)
    params = vertical.init(cfg, jax.random.PRNGKey(0))
    views, labels = _data(cfg)
    pred = vertical.forward(cfg, params, views)
    assert pred.shape == (6, 10)
    loss, m = vertical.loss_fn(cfg, params, views, labels)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: vertical.loss_fn(cfg, p, views, labels)[0])(params)
    assert all(np.isfinite(np.asarray(t)).all() for t in jax.tree.leaves(g))


def test_forward_ocs_protocol_channel_in_the_loop():
    """An OCS protocol config trains through the simulated channel: rng is
    threaded per call, metrics surface the channel telemetry, and the
    string-sugar config ("max_noisy" + noise_* fields) resolves to the
    identical computation."""
    proto = Protocol.ocs(bits=8, p_miss=jnp.float32(0.2))
    cfg = _cfg(aggregation=proto)
    params = vertical.init(cfg, jax.random.PRNGKey(0))
    views, labels = _data(cfg)
    key = jax.random.PRNGKey(5)
    loss, m = vertical.loss_fn(cfg, params, views, labels, rng=key)
    assert np.isfinite(float(loss))
    assert {"chan_rounds", "chan_collision_frac",
            "chan_correct_frac"} <= set(m)
    # legacy string sugar resolves to the same protocol semantics
    sugar = _cfg(aggregation="max_noisy", noise_bits=8)
    loss2, _ = vertical.loss_fn(
        sugar, params, views, labels, rng=key,
        protocol=sugar.resolve_protocol().with_p_miss(jnp.float32(0.2)))
    assert float(loss) == float(loss2)
    # per-call protocol override: the p_miss=0 lane is the ideal pool
    l0, _ = vertical.loss_fn(cfg, params, views, labels, rng=key,
                             protocol=proto.with_p_miss(jnp.float32(0.0)))
    li, _ = vertical.loss_fn(
        _cfg(aggregation=Protocol.ideal_max(8, tie_break="first")),
        params, views, labels)
    assert float(l0) == float(li)


def test_prediction_level_baselines():
    cfg = _cfg(prediction_level=True)
    params = vertical.init(cfg, jax.random.PRNGKey(1))
    views, labels = _data(cfg)
    pred = vertical.forward(cfg, params, views)        # avg worker preds
    assert pred.shape == (6, 10)
    assert np.allclose(np.asarray(pred.sum(-1)), 1.0, atol=1e-5)
    per = vertical.per_worker_predictions(cfg, params, views)
    assert per.shape == (4, 6, 10)


def test_reconstruction_loss():
    cfg = _cfg(task="reconstruction", output_dim=32)
    params = vertical.init(cfg, jax.random.PRNGKey(2))
    views, _ = _data(cfg)
    loss, m = vertical.loss_fn(cfg, params, views, views[0])
    assert float(m["nll"]) == pytest.approx(0.5 * float(m["mse"]))


def test_table1_registry_complete():
    base = _cfg()
    cfgs = aggregators.all_configs(base)
    assert set(cfgs) == set(aggregators.TABLE1_METHODS)
    # embedding-level methods carry their fusion law as a Protocol value
    assert cfgs["fedocs"].aggregation.kind == "max"
    assert cfgs["concat_workers_embed"].aggregation.kind == "concat"
    assert cfgs["concat_workers_embed"].head_input_dim() == 4 * 8
    assert cfgs["fedocs"].head_input_dim() == 8
    assert cfgs["avg_workers_preds"].prediction_level


def test_comm_load_per_method():
    base = _cfg()
    f = vertical.comm_load(aggregators.table1_config("fedocs", base))
    c = vertical.comm_load(
        aggregators.table1_config("concat_workers_embed", base))
    assert f.uplink_payload_msgs * base.n_workers == c.uplink_payload_msgs


def test_training_reduces_loss():
    from repro.optim import optimizers, schedules
    cfg = _cfg(task="reconstruction", output_dim=32)
    params = vertical.init(cfg, jax.random.PRNGKey(3))
    views, _ = _data(cfg, b=32, seed=5)
    target = views[0]
    opt = optimizers.adamw(schedules.constant(1e-2))
    state = opt.init(params)

    @jax.jit
    def step(params, state):
        loss, g = jax.value_and_grad(
            lambda p: vertical.loss_fn(cfg, p, views, target)[0])(params)
        params, state, _ = opt.update(g, state, params)
        return params, state, loss

    first = None
    for i in range(60):
        params, state, loss = step(params, state)
        if first is None:
            first = float(loss)
    assert float(loss) < 0.5 * first
