"""Checkpointer: roundtrip, commit semantics, latest resolution."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ck


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "layer": {"w": jnp.asarray(rng.standard_normal((4, 8)), jnp.float32),
                  "b": jnp.asarray(rng.standard_normal((8,)), jnp.float32)},
        "stack": [jnp.asarray(rng.standard_normal((3,)), jnp.float32),
                  jnp.asarray(rng.integers(0, 5, (2,)), jnp.int32)],
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 7, t, extra={"note": "hello"})
    restored, step, extra = ck.restore(str(tmp_path), template=t)
    assert step == 7 and extra["note"] == "hello"
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_latest_points_to_newest_commit(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 5, t)
    ck.save(str(tmp_path), 12, t)
    assert ck.latest_step(str(tmp_path)) == 12


def test_torn_checkpoint_ignored(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 3, t)
    # simulate a torn write: directory without COMMIT
    torn = tmp_path / "step_0000000009"
    torn.mkdir()
    (torn / "index.json").write_text("{}")
    assert ck.latest_step(str(tmp_path)) == 3
    restored, step, _ = ck.restore(str(tmp_path), template=t)
    assert step == 3


def test_restore_missing_key_raises(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    bigger = dict(t)
    bigger["extra_param"] = jnp.zeros((2,))
    with pytest.raises(KeyError):
        ck.restore(str(tmp_path), template=bigger)


def test_no_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ck.restore(str(tmp_path / "empty"), template={})
