"""Serving engine: slot lifecycle, budgets, decode consistency, and the
channel-in-the-loop path (Protocol aggregation inside the fused tick,
airtime accounting, Poisson load generation).

The redesign contracts pinned here:

  * channel-free serving is bit-for-bit the plain prefill+decode loop
    (the fused tick and continuous batching change nothing numerically),
  * refill/retire semantics: slots are reused after EOS, the length cap
    retires at ``max_seq``, a one-slot engine drains the queue FIFO,
  * ``Completion`` latency decomposition: ``latency_ticks`` spans arrival
    to retirement, ``channel_slots`` bills the measured shared-channel
    airtime, ``uplink_bits`` is the analytic per-request uplink — all
    three zero for channel-free serving,
  * sweeping channel quality rebinds only the protocol's traced ``p_miss``
    leaf: ONE compilation serves every point.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import model as M
from repro.parallel.sharding import split_tree
from repro.protocol import Protocol
from repro.serve import engine as se
from repro.serve.engine import (ChannelClock, Completion, Request,
                                ServeConfig, ServeEngine)
from repro.serve.load import near_far_protocol, poisson_requests

N_WORKERS = 2
VOCAB = 64


@pytest.fixture(scope="module")
def model_and_values():
    cfg = get_reduced("qwen1.5-0.5b", n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=VOCAB,
                      n_workers=N_WORKERS)
    m = M.build(cfg)
    values, _ = split_tree(m.init(jax.random.PRNGKey(0)))
    return m, values


def _engine(m, values, **kw):
    return ServeEngine(m, values, ServeConfig(**kw))


def _ocs(p):
    return Protocol.ocs(bits=8,
                        p_miss=np.full((N_WORKERS,), p, np.float32))


def _manual_decode(m, values, prompt, max_new, max_seq, eos=-1):
    logits, cache = m.prefill(values, {"tokens": jnp.asarray(prompt)[None]},
                              max_seq=max_seq)
    tok = int(jnp.argmax(logits, -1)[0])
    toks = [tok]
    pos = len(prompt)
    budget = max_new - 1
    while tok != eos and budget > 0 and pos < max_seq - 1:
        logits, cache = m.decode_step(
            values, jnp.asarray([[tok]], jnp.int32),
            jnp.asarray([pos], jnp.int32), cache)
        tok = int(jnp.argmax(logits, -1)[0])
        toks.append(tok)
        pos += 1
        budget -= 1
    return toks


# -- refill / retire semantics ---------------------------------------------

def test_all_requests_complete(model_and_values):
    m, values = model_and_values
    eng = _engine(m, values, batch_slots=2, max_seq=40, eos_id=-1)
    reqs = [Request(rid=i, prompt=np.arange(3 + i, dtype=np.int32) % VOCAB,
                    max_new_tokens=6) for i in range(5)]
    outs = eng.run(reqs)
    assert set(outs) == set(range(5))
    for c in outs.values():
        assert len(c.tokens) == 6


def test_more_requests_than_slots_reuses_slots(model_and_values):
    m, values = model_and_values
    eng = _engine(m, values, batch_slots=1, max_seq=40, eos_id=-1)
    reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=3) for i in range(3)]
    outs = eng.run(reqs)
    assert len(outs) == 3


def test_eos_retires_early_and_slot_is_reused(model_and_values):
    """Pick an actually-generated token as EOS: the request retires at its
    first occurrence and the freed slot still serves the queue behind it."""
    m, values = model_and_values
    prompt = np.arange(5, dtype=np.int32)
    ref = _manual_decode(m, values, prompt, 8, 40)
    eos = ref[2]                      # a token the decode provably emits
    # the first *decoded* occurrence retires the slot (the prefill token,
    # index 0, is produced by prefill and is not EOS-checked)
    stop_at = next(i for i in range(1, len(ref)) if ref[i] == eos) + 1
    assert stop_at < 8
    eng = _engine(m, values, batch_slots=1, max_seq=40, eos_id=eos)
    reqs = [Request(rid=i, prompt=prompt, max_new_tokens=8)
            for i in range(3)]
    outs = eng.run(reqs)
    assert set(outs) == {0, 1, 2}     # queue drained through the one slot
    for c in outs.values():
        assert c.tokens[-1] == eos
        assert len(c.tokens) == stop_at   # retired at EOS, not at budget


def test_length_cap_retires_at_max_seq(model_and_values):
    m, values = model_and_values
    prompt = np.arange(5, dtype=np.int32)
    eng = _engine(m, values, batch_slots=1, max_seq=8, eos_id=-1)
    out = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=100)])[0]
    # positions hits max_seq-1 after decoding max_seq - prompt_len tokens
    assert len(out.tokens) == 8 - len(prompt)


def test_one_slot_queue_drains_fifo(model_and_values):
    """With one slot, requests finish strictly in arrival order."""
    m, values = model_and_values
    eng = _engine(m, values, batch_slots=1, max_seq=40, eos_id=-1)
    reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=4, arrival_tick=0) for i in range(4)]
    outs = eng.run(reqs)
    finish = [reqs[i].arrival_tick + outs[i].latency_ticks
              for i in range(4)]
    assert finish == sorted(finish)
    assert len(set(finish)) == 4      # strictly one-after-another


# -- channel-free parity ----------------------------------------------------

def test_greedy_serving_matches_manual_decode(model_and_values):
    """Engine output == direct prefill+argmax-decode, request by request,
    even when slots are shared (continuous batching is invisible)."""
    m, values = model_and_values
    eng = _engine(m, values, batch_slots=2, max_seq=32, eos_id=-1)
    prompts = [np.arange(5, dtype=np.int32),
               (np.arange(7, dtype=np.int32) * 3) % VOCAB,
               np.arange(4, dtype=np.int32) + 9]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    outs = eng.run(reqs)
    for i, p in enumerate(prompts):
        assert outs[i].tokens == _manual_decode(m, values, p, 4, 32)


def test_channel_free_completion_has_zero_channel_fields(model_and_values):
    m, values = model_and_values
    eng = _engine(m, values, batch_slots=2, max_seq=32, eos_id=-1)
    outs = eng.run([Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                            max_new_tokens=4)])
    c = outs[0]
    assert c.latency_ticks > 0
    assert c.channel_slots == 0 and c.uplink_bits == 0
    clock = ChannelClock(tick_us=50.0, slot_us=1.0)
    assert c.latency_us(clock) == c.latency_ticks * 50.0


# -- channel-in-the-loop ----------------------------------------------------

def test_channel_serving_bills_airtime_and_uplink(model_and_values):
    m, values = model_and_values
    eng = _engine(m, values, batch_slots=2, max_seq=32, eos_id=-1,
                  protocol=_ocs(0.05))
    reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=4) for i in range(2)]
    outs = eng.run(reqs)
    sites = m.channel_sites()
    per_tok = _ocs(0.05).comm_load(N_WORKERS, 32).uplink_bits * sites
    for c in outs.values():
        assert c.channel_slots > 0            # measured airtime
        # analytic uplink: only decode tokens cross the channel (the
        # prefill token comes from the channel-free prefill path)
        assert c.uplink_bits == (len(c.tokens) - 1) * per_tok


def test_error_free_channel_matches_ideal_max(model_and_values):
    """OCS at p_miss=0 serves the same tokens as Protocol.ideal_max."""
    m, values = model_and_values
    eng = _engine(m, values, batch_slots=2, max_seq=32, eos_id=-1)
    reqs = [Request(rid=i, prompt=np.arange(4 + i, dtype=np.int32),
                    max_new_tokens=4) for i in range(2)]
    under_ocs = eng.run(reqs, protocol=_ocs(0.0))
    ideal = eng.run(reqs, protocol=Protocol.ideal_max(8, tie_break="first"))
    for i in under_ocs:
        assert under_ocs[i].tokens == ideal[i].tokens


def test_p_miss_sweep_never_recompiles(model_and_values):
    """Rebinding the traced p_miss leaf reuses the compiled tick."""
    m, values = model_and_values
    eng = _engine(m, values, batch_slots=2, max_seq=32, eos_id=-1)
    reqs = [Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=4)]
    se.reset_trace_counts()
    eng.run(reqs, protocol=_ocs(0.0))
    eng.run(reqs, protocol=_ocs(0.3))
    eng.run(reqs, protocol=near_far_protocol(N_WORKERS, p_far=0.4))
    assert se.trace_counts()["tick"] == 1


def test_channel_serving_deterministic(model_and_values):
    m, values = model_and_values
    eng = _engine(m, values, batch_slots=2, max_seq=32, eos_id=-1,
                  protocol=_ocs(0.2))
    reqs = [Request(rid=i, prompt=np.arange(5, dtype=np.int32),
                    max_new_tokens=5) for i in range(2)]
    a = eng.run(reqs)
    b = eng.run(reqs)
    for i in a:
        assert a[i].tokens == b[i].tokens
        assert a[i].channel_slots == b[i].channel_slots


def test_one_dispatch_per_decode_tick(model_and_values):
    """Every decoded token row is covered by exactly the counted fused
    dispatches: dispatches in [ceil(tokens/B), tokens]."""
    m, values = model_and_values
    eng = _engine(m, values, batch_slots=2, max_seq=32, eos_id=-1)
    reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=5) for i in range(3)]
    se.reset_dispatch_counts()
    outs = eng.run(reqs)
    ticks = se.dispatch_counts()["tick"]
    decode_tokens = sum(len(c.tokens) - 1 for c in outs.values())
    assert -(-decode_tokens // 2) <= ticks <= decode_tokens


# -- load generation --------------------------------------------------------

def test_poisson_requests_shape_and_determinism():
    reqs = poisson_requests(16, 0.5, VOCAB, prompt_len=6,
                            max_new_tokens=4, seed=3)
    assert len(reqs) == 16
    arr = [r.arrival_tick for r in reqs]
    assert arr == sorted(arr) and arr[0] >= 0
    assert all(len(r.prompt) == 6 and r.prompt.dtype == np.int32
               and r.prompt.min() >= 0 and r.prompt.max() < VOCAB
               for r in reqs)
    again = poisson_requests(16, 0.5, VOCAB, prompt_len=6,
                             max_new_tokens=4, seed=3)
    assert [r.arrival_tick for r in again] == arr
    assert all(np.array_equal(a.prompt, b.prompt)
               for a, b in zip(reqs, again))


def test_poisson_requests_validation():
    with pytest.raises(ValueError):
        poisson_requests(0, 1.0, VOCAB)
    with pytest.raises(ValueError):
        poisson_requests(4, 0.0, VOCAB)


def test_late_arrivals_wait_for_their_tick(model_and_values):
    """A request arriving at tick T cannot retire before T."""
    m, values = model_and_values
    eng = _engine(m, values, batch_slots=2, max_seq=32, eos_id=-1)
    reqs = [Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=3, arrival_tick=0),
            Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=3, arrival_tick=10)]
    outs = eng.run(reqs)
    # rid 1 decoded 2 tokens after arriving at tick 10
    assert outs[1].latency_ticks >= 2
    # and its tokens match the solo decode (queueing changes nothing)
    assert outs[1].tokens == _manual_decode(m, values, reqs[1].prompt, 3, 32)


def test_near_far_protocol_p_miss_profile():
    p = near_far_protocol(4, p_near=0.0, p_far=0.25)
    pm = np.asarray(p.p_miss)
    assert pm.shape == (4,) and pm.dtype == np.float32
    assert (pm[:2] == 0.0).all() and (pm[2:] == np.float32(0.25)).all()


# -- config surfaces --------------------------------------------------------

def test_serve_config_validation():
    with pytest.raises(ValueError):
        ServeConfig(batch_slots=0)
    with pytest.raises(ValueError):
        ServeConfig(max_seq=1)
    with pytest.raises(ValueError):
        ServeConfig(protocol=Protocol.concat())
    with pytest.raises(ValueError):
        ChannelClock(tick_us=0.0)
    with pytest.raises(ValueError):
        ChannelClock(slot_us=-1.0)
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg = ServeConfig()
        cfg.batch_slots = 8


def test_completion_latency_decomposition():
    c = Completion(rid=0, tokens=[1, 2], prompt_len=3,
                   latency_ticks=7, channel_slots=120, uplink_bits=640)
    clock = ChannelClock(tick_us=10.0, slot_us=0.5)
    assert c.latency_us(clock) == 7 * 10.0 + 120 * 0.5
