"""Serving engine: slot lifecycle, budgets, decode consistency."""

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import model as M
from repro.parallel.sharding import split_tree
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def model_and_values():
    cfg = get_reduced("qwen1.5-0.5b", n_layers=2, d_model=32, n_heads=2,
                      n_kv_heads=2, d_ff=64, vocab_size=64, n_workers=2)
    m = M.build(cfg)
    values, _ = split_tree(m.init(jax.random.PRNGKey(0)))
    return m, values


def test_all_requests_complete(model_and_values):
    m, values = model_and_values
    eng = ServeEngine(m, values, batch_slots=2, max_seq=40, eos_id=-1)
    reqs = [Request(rid=i, prompt=np.arange(3 + i, dtype=np.int32) % 64,
                    max_new_tokens=6) for i in range(5)]
    outs = eng.run(reqs)
    assert set(outs) == set(range(5))
    for c in outs.values():
        assert len(c.tokens) == 6


def test_more_requests_than_slots_reuses_slots(model_and_values):
    m, values = model_and_values
    eng = ServeEngine(m, values, batch_slots=1, max_seq=40, eos_id=-1)
    reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=3) for i in range(3)]
    outs = eng.run(reqs)
    assert len(outs) == 3


def test_greedy_serving_matches_manual_decode(model_and_values):
    """Engine output == direct prefill+argmax-decode for one request."""
    m, values = model_and_values
    prompt = np.arange(5, dtype=np.int32)
    eng = ServeEngine(m, values, batch_slots=1, max_seq=32, eos_id=-1)
    out = eng.run([Request(rid=0, prompt=prompt, max_new_tokens=4)])[0]

    import jax.numpy as jnp
    logits, cache = m.prefill(values, {"tokens": jnp.asarray(prompt)[None]},
                              max_seq=32)
    toks = [int(jnp.argmax(logits, -1)[0])]
    pos = jnp.asarray([len(prompt)], jnp.int32)
    cur = jnp.asarray([[toks[-1]]], jnp.int32)
    for _ in range(3):
        logits, cache = m.decode_step(values, cur, pos, cache)
        toks.append(int(jnp.argmax(logits, -1)[0]))
        cur = jnp.asarray([[toks[-1]]], jnp.int32)
        pos = pos + 1
    assert out.tokens == toks
