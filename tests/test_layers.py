"""Layer-level unit tests: norms, RoPE, fusion-mode algebra."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import fusion, layers, mlp
from repro.parallel.sharding import split_tree


def _cfg(**kw):
    return get_reduced("glm4-9b", **kw)


def test_rmsnorm_unit_scale():
    cfg = _cfg()
    p = jax.tree.map(lambda t: t.value, layers.norm_init(cfg, jax.random.PRNGKey(0)),
                     is_leaf=lambda x: hasattr(x, "axes"))
    x = jnp.asarray(np.random.default_rng(0).standard_normal((2, 4, 64)) * 7,
                    jnp.float32)
    y = layers.norm_apply(cfg, p, x)
    rms = jnp.sqrt(jnp.mean(y ** 2, axis=-1))
    assert np.allclose(np.asarray(rms), 1.0, atol=1e-3)


def test_layernorm_zero_mean():
    cfg = _cfg(norm="layernorm")
    p = jax.tree.map(lambda t: t.value, layers.norm_init(cfg, jax.random.PRNGKey(0)),
                     is_leaf=lambda x: hasattr(x, "axes"))
    x = jnp.asarray(np.random.default_rng(1).standard_normal((2, 4, 64)) + 3,
                    jnp.float32)
    y = layers.norm_apply(cfg, p, x)
    assert np.allclose(np.asarray(jnp.mean(y, -1)), 0.0, atol=1e-4)


def test_rope_preserves_norm_and_relativity():
    cfg = _cfg(rotary_frac=1.0)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 8, 2, 16)), jnp.float32)
    pos = jnp.arange(8, dtype=jnp.int32)[None]
    y = layers.apply_rope(cfg, x, pos)
    # rotation preserves per-head norms
    assert np.allclose(np.asarray(jnp.linalg.norm(x, axis=-1)),
                       np.asarray(jnp.linalg.norm(y, axis=-1)), atol=1e-4)
    # inner products depend only on relative offset
    q = jnp.asarray(rng.standard_normal((1, 1, 1, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1, 1, 16)), jnp.float32)

    def dot_at(pq, pk):
        qq = layers.apply_rope(cfg, q, jnp.asarray([[pq]], jnp.int32))
        kk = layers.apply_rope(cfg, k, jnp.asarray([[pk]], jnp.int32))
        return float(jnp.sum(qq * kk))

    assert dot_at(3, 1) == pytest.approx(dot_at(7, 5), abs=1e-4)


def test_partial_rotary_leaves_tail_untouched():
    cfg = _cfg(rotary_frac=0.5)
    x = jnp.asarray(np.random.default_rng(3).standard_normal((1, 4, 2, 16)),
                    jnp.float32)
    pos = jnp.arange(4, dtype=jnp.int32)[None]
    y = layers.apply_rope(cfg, x, pos)
    assert np.allclose(np.asarray(y[..., 8:]), np.asarray(x[..., 8:]))


def test_mlp_fusion_sum_equals_unsharded_matmul():
    """sum fusion over the worker axis == one big dense MLP."""
    cfg = _cfg(tp_fusion="sum", n_workers=2)
    p = jax.tree.map(lambda t: t.value, mlp.mlp_init(cfg, jax.random.PRNGKey(0)),
                     is_leaf=lambda x: hasattr(x, "axes"))
    x = jnp.asarray(np.random.default_rng(4).standard_normal((2, 4, 64)),
                    jnp.float32)
    y = mlp.mlp_apply(cfg, p, x)
    # dense reference: concatenate worker slices
    w_up = jnp.concatenate(list(p["w_up"]), axis=-1)       # (d, f)
    w_gate = jnp.concatenate(list(p["w_gate"]), axis=-1)
    w_down = jnp.concatenate(list(p["w_down"]), axis=0)    # (f, d)
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    ref = h @ w_down
    assert float(jnp.max(jnp.abs(y - ref))) < 1e-4


@pytest.mark.parametrize("mode", ["max", "max_q16", "max_q8", "concat"])
def test_mlp_fusion_modes_shapes_and_grads(mode):
    cfg = _cfg(tp_fusion=mode, n_workers=2)
    p = jax.tree.map(lambda t: t.value, mlp.mlp_init(cfg, jax.random.PRNGKey(1)),
                     is_leaf=lambda x: hasattr(x, "axes"))
    x = jnp.asarray(np.random.default_rng(5).standard_normal((2, 4, 64)),
                    jnp.float32)
    y = mlp.mlp_apply(cfg, p, x)
    assert y.shape == (2, 4, 64)
    g = jax.grad(lambda p: jnp.sum(mlp.mlp_apply(cfg, p, x) ** 2))(p)
    assert all(np.isfinite(np.asarray(t)).all() for t in jax.tree.leaves(g))


def test_sinusoidal_positions_shape():
    pe = layers.sinusoidal_positions(16, 32)
    assert pe.shape == (16, 32)
    assert float(jnp.max(jnp.abs(pe))) <= 1.0
