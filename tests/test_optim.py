"""Optimizer correctness vs a NumPy reference; schedules; clipping."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import optimizers, schedules


def _numpy_adamw(params, grads, steps, lr, b1, b2, eps, wd):
    m = {k: np.zeros_like(v) for k, v in params.items()}
    v_ = {k: np.zeros_like(v) for k, v in params.items()}
    p = {k: v.copy() for k, v in params.items()}
    for t in range(1, steps + 1):
        for k in p:
            g = grads[k]
            m[k] = b1 * m[k] + (1 - b1) * g
            v_[k] = b2 * v_[k] + (1 - b2) * g * g
            mh = m[k] / (1 - b1 ** t)
            vh = v_[k] / (1 - b2 ** t)
            p[k] = p[k] - lr * (mh / (np.sqrt(vh) + eps) + wd * p[k])
    return p


def test_adamw_matches_numpy_reference():
    rng = np.random.default_rng(0)
    params = {"a": rng.standard_normal((4, 4)).astype(np.float32),
              "b": rng.standard_normal((8,)).astype(np.float32)}
    grads = {k: rng.standard_normal(v.shape).astype(np.float32)
             for k, v in params.items()}
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1
    opt = optimizers.adamw(schedules.constant(lr), b1=b1, b2=b2, eps=eps,
                           weight_decay=wd, max_grad_norm=None)
    jp = {k: jnp.asarray(v) for k, v in params.items()}
    jg = {k: jnp.asarray(v) for k, v in grads.items()}
    state = opt.init(jp)
    for _ in range(3):
        jp, state, _ = opt.update(jg, state, jp)
    ref = _numpy_adamw(params, grads, 3, lr, b1, b2, eps, wd)
    for k in params:
        assert np.allclose(np.asarray(jp[k]), ref[k], atol=1e-5), k


def test_grad_clipping():
    g = {"w": jnp.full((10,), 10.0)}
    clipped, norm = optimizers.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000.0))
    assert float(optimizers.global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_sgd_momentum_descends():
    opt = optimizers.sgd(schedules.constant(0.05), momentum=0.9)
    p = {"w": jnp.asarray([5.0])}
    s = opt.init(p)
    losses = []
    for _ in range(150):
        g = {"w": 2 * p["w"]}
        p, s, _ = opt.update(g, s, p)
        losses.append(float(p["w"][0] ** 2))
    assert losses[-1] < 1e-3


def test_wsd_schedule_phases():
    f = schedules.wsd(1.0, warmup=10, stable=30, decay=10)
    assert float(f(0)) == 0.0
    assert float(f(5)) == pytest.approx(0.5)
    assert float(f(20)) == pytest.approx(1.0)
    assert float(f(39)) == pytest.approx(1.0)
    assert float(f(50)) < 0.05
    # monotone within phases
    xs = [float(f(s)) for s in range(0, 10)]
    assert all(b >= a for a, b in zip(xs, xs[1:]))


def test_cosine_schedule_endpoints():
    f = schedules.linear_warmup_cosine(2.0, warmup=5, total=50,
                                       final_frac=0.1)
    assert float(f(5)) == pytest.approx(2.0)
    assert float(f(50)) == pytest.approx(0.2, rel=1e-3)


def test_for_arch_minicpm_is_wsd():
    f = schedules.for_arch("minicpm-2b", 1.0, 1000)
    g = schedules.for_arch("glm4-9b", 1.0, 1000)
    # WSD has a flat plateau; cosine doesn't
    mid = [float(f(s)) for s in (400, 500, 600)]
    assert mid[0] == mid[1] == mid[2] == pytest.approx(1.0)
    cm = [float(g(s)) for s in (400, 500, 600)]
    assert cm[0] > cm[1] > cm[2]
