"""Fault-injection layer (ISSUE 9 tentpole): bursty channels, worker
dropout, graceful degradation.

Contracts under test:
  * the pytree contract: every probability of a ``FaultModel`` is a traced
    ``float32`` leaf, the ``DegradePolicy`` is static metadata, and a jitted
    ``faults.aggregate`` serves perturbed transition/miss/dropout
    probabilities AND evolved chain state with ZERO recompiles;
  * the reduction witness: ``FaultModel.iid(p)`` reproduces the plain
    ``Protocol.aggregate`` path bit for bit — forward, vjp and the shared
    accounting fields — on BOTH contention backends, and iid lanes of the
    fused fault engine retrain the ``run_curves`` noisy lanes bitwise;
  * degrade-policy semantics on a total outage: ``zero_fill`` emits zeros,
    ``stale`` replays the carried cache (and routes the pooled cotangent to
    it — degraded steps never invent gradient signal), ``retry`` spends its
    bounded budget and bills ``frame_slots + 2**attempt`` per retry;
  * chain mechanics: burst persistence, dropout/recovery evolution;
  * the full-training-carry checkpoint: resume-equals-uninterrupted
    BITWISE with error-feedback memory and the fault carry in the state,
    and ``ckpt_on_stall`` persists the carry the moment the watchdog fires.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import random_floats
from repro import faults
from repro.core import fedocs, ocs, vertical
from repro.faults import DegradePolicy, FaultModel, FaultState
from repro.optim import optimizers, schedules
from repro.protocol import Protocol
from repro.sim import train_curves as tc
from repro.sim.scenarios import get as get_scenario
from repro.train import trainer
from repro.train.trainer import TrainerConfig

N = 4
H = jnp.asarray(random_floats(3, (N, 9, 3), specials=False))
KEY = jax.random.PRNGKey(7)
PROTO = Protocol.ocs(8, p_miss=jnp.float32(0.3))


def _state(stale=None):
    s = faults.init_state(N, H.shape[1:])
    return s if stale is None else dataclasses.replace(s, stale=stale)


# ---------------------------------------------------------------------------
# pytree contract
# ---------------------------------------------------------------------------

def test_fault_model_leaves_and_static_policy():
    fm = FaultModel.gilbert_elliott(
        p_gb=0.1, p_bg=0.25, p_miss_bad=0.5,
        policy=DegradePolicy.stale()).with_dropout(0.05)
    leaves, treedef = jax.tree_util.tree_flatten(fm)
    assert len(leaves) == 6
    assert all(np.asarray(x).dtype == np.float32 for x in leaves)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert back.policy == DegradePolicy.stale()
    # the policy survives tree_map untouched (static metadata)
    mapped = jax.tree.map(lambda x: x * 0, fm)
    assert mapped.policy.kind == "stale"
    assert float(mapped.p_bg) == 0.0


def test_constructors_and_validation():
    fm = FaultModel.burst(burst_len=4.0, gap_len=8.0)
    assert float(fm.p_bg) == pytest.approx(0.25)
    assert float(fm.p_gb) == pytest.approx(0.125)
    with pytest.raises(ValueError, match="mean sojourns"):
        FaultModel.burst(burst_len=0.5, gap_len=8.0)
    with pytest.raises(ValueError, match="retry_budget >= 1"):
        DegradePolicy(kind="retry")
    with pytest.raises(ValueError, match="only meaningful"):
        DegradePolicy(kind="zero_fill", retry_budget=2)
    with pytest.raises(ValueError, match="unknown degrade policy"):
        DegradePolicy(kind="panic")
    with pytest.raises(ValueError, match="needs an OCS protocol"):
        faults.aggregate(Protocol.mean(), FaultModel.iid(0.1), _state(),
                         H, KEY)


# ---------------------------------------------------------------------------
# the reduction witness: iid == the plain Protocol path, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ocs.NOISY_BACKENDS)
def test_iid_reduces_to_protocol_path_bitwise(backend):
    """Gilbert–Elliott with identical good/bad states and no dropout is
    bit-for-bit the i.i.d. ``p_miss`` channel: forward, vjp AND the shared
    accounting fields, on both contention backends."""
    proto = Protocol.ocs(8, p_miss=jnp.float32(0.3), backend=backend)
    fm = FaultModel.iid(0.3)
    pooled_f, new_state, facct = faults.aggregate(proto, fm, _state(), H, KEY)
    pooled_p, acct = proto.aggregate(H, KEY)
    assert np.array_equal(np.asarray(pooled_f), np.asarray(pooled_p))
    g_f = jax.grad(lambda x: jnp.sum(
        faults.aggregate(proto, fm, _state(), x, KEY)[0]))(H)
    g_p = jax.grad(lambda x: jnp.sum(proto.aggregate(x, KEY)[0]))(H)
    assert np.array_equal(np.asarray(g_f), np.asarray(g_p))
    for f in ("rounds", "collisions", "contention_slots", "correct_frac"):
        assert np.array_equal(np.asarray(getattr(facct, f)),
                              np.asarray(getattr(acct, f))), f
    # a resolved frame: no degradation billed, cache holds this frame
    assert int(facct.dropped_frames) == 0 and int(facct.outage) == 0
    assert int(facct.retry_slots) == 0 and int(facct.stale_age) == 0
    assert np.array_equal(np.asarray(new_state.stale), np.asarray(pooled_p))
    assert not bool(new_state.bad.any()) and not bool(new_state.offline.any())


TINY = tc.CurveConfig(bits=(8,), p_miss=(0.0, 0.05), steps=6, batch=16,
                      n_train=96, n_val=48, hw=8, encoder_dims=(8,),
                      embed_dim=8, head_dims=(8,), log_every=3)


def test_fault_engine_iid_lanes_retrain_run_curves_bitwise():
    """Engine-level witness: iid fault lanes inside the fused fault engine
    train the exact ``run_curves`` noisy-lane trajectories — and the whole
    fault grid is ONE trace per bits value."""
    plain = tc.run_curves(TINY, n_devices=1)
    tc.reset_trace_counts()
    fc = tc.run_fault_curves(TINY, [FaultModel.iid(p) for p in TINY.p_miss])
    assert tc.trace_counts()["fused_faults"] == 1
    assert np.array_equal(fc.acc, plain.acc)
    assert np.array_equal(fc.nll, plain.nll)
    assert np.array_equal(fc.loss_history, plain.loss_history)
    for x, y in zip(jax.tree.leaves(fc.params[0]),
                    jax.tree.leaves(plain.noisy_params[0])):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    # a healthy channel degrades nothing
    assert (fc.dropped_frames == 0).all() and (fc.outage_frames == 0).all()
    assert (fc.stale_age == 0).all() and (fc.retry_slots == 0).all()


def test_fault_engine_rejects_mixed_policies_and_empty_grids():
    with pytest.raises(ValueError, match="one DegradePolicy"):
        tc.run_fault_curves(TINY, [FaultModel.iid(0.0),
                                   FaultModel.iid(0.1,
                                                  policy=DegradePolicy.stale())])
    with pytest.raises(ValueError, match="at least one"):
        tc.run_fault_curves(TINY, [])


# ---------------------------------------------------------------------------
# chain mechanics
# ---------------------------------------------------------------------------

def test_chain_evolution_extremes():
    fm = FaultModel.gilbert_elliott(p_gb=1.0, p_bg=0.0).with_dropout(1.0, 0.0)
    bad, off = faults.step_chains(fm, _state(), KEY)
    assert bool(bad.all()) and bool(off.all())     # everyone fades + drops
    st = dataclasses.replace(_state(), bad=bad, offline=off)
    bad2, off2 = faults.step_chains(fm, st, jax.random.fold_in(KEY, 1))
    assert bool(bad2.all()) and bool(off2.all())   # ...and stays (p_bg=0)
    # full recovery path
    fm_r = FaultModel.iid(0.0).with_dropout(0.0, 1.0)
    _, off3 = faults.step_chains(fm_r, st, KEY)
    assert not bool(off3.any())


def test_effective_p_miss_follows_chain_state():
    fm = FaultModel.gilbert_elliott(p_gb=0.1, p_bg=0.1, p_miss_good=0.05,
                                    p_miss_bad=0.7)
    bad = jnp.asarray([True, False, True, False])
    p = faults.effective_p_miss(fm, bad)
    assert np.allclose(np.asarray(p), [0.7, 0.05, 0.7, 0.05])


# ---------------------------------------------------------------------------
# degrade policies on a total outage
# ---------------------------------------------------------------------------

def _outage_model(policy):
    # every worker drops this frame and none recovers: a guaranteed outage
    return FaultModel.iid(0.0, policy=policy).with_dropout(1.0, 0.0)


def test_zero_fill_emits_zeros_and_no_gradient():
    fm = _outage_model(DegradePolicy.zero_fill())
    pooled, ns, acct = faults.aggregate(PROTO, fm, _state(), H, KEY)
    assert np.array_equal(np.asarray(pooled), np.zeros(H.shape[1:]))
    assert int(acct.outage) == 1
    assert int(acct.dropped_frames) == int(np.prod(H.shape[1:]))
    assert float(acct.correct_frac) == 0.0
    assert int(acct.offline_workers) == N
    assert int(ns.age) == 1 and int(ns.consec) == 1
    g = jax.grad(lambda x: jnp.sum(
        faults.aggregate(PROTO, fm, _state(), x, KEY)[0]))(H)
    assert np.array_equal(np.asarray(g), np.zeros(H.shape))


def test_stale_replays_cache_and_routes_gradient_to_it():
    cache = jnp.asarray(random_floats(11, H.shape[1:], specials=False))
    fm = _outage_model(DegradePolicy.stale())
    pooled, ns, acct = faults.aggregate(PROTO, fm, _state(cache), H, KEY)
    assert np.array_equal(np.asarray(pooled), np.asarray(cache))
    assert np.array_equal(np.asarray(ns.stale), np.asarray(cache))
    assert int(acct.stale_age) == 1
    # the pooled cotangent reaches the CACHE, never h: degraded steps do
    # not invent gradient signal (paper Eq. 5-6 extended)
    g_cache = jax.grad(lambda s: jnp.sum(faults.aggregate(
        PROTO, fm, _state(s), H, KEY)[0]))(cache)
    assert np.array_equal(np.asarray(g_cache), np.ones(H.shape[1:]))
    g_h = jax.grad(lambda x: jnp.sum(faults.aggregate(
        PROTO, fm, _state(cache), x, KEY)[0]))(H)
    assert np.array_equal(np.asarray(g_h), np.zeros(H.shape))


def test_retry_bills_budget_with_backoff_on_persistent_outage():
    budget = 3
    fm = _outage_model(DegradePolicy.retry(budget))
    pooled, ns, acct = faults.aggregate(PROTO, fm, _state(), H, KEY)
    frame_slots = (PROTO.bits + ocs.host_id_bits(N)) * int(
        np.prod(H.shape[1:]))
    # nobody recovers (p_recover=0): every attempt bills a full frame plus
    # the exponential backoff wait, then the frame degrades to zeros
    expect = budget * frame_slots + sum(2 ** a for a in range(budget))
    assert int(acct.retry_slots) == expect
    assert int(acct.contention_slots) >= expect
    assert int(acct.outage) == 1
    assert np.array_equal(np.asarray(pooled), np.zeros(H.shape[1:]))


def test_retry_recovers_and_resolves_the_frame():
    # everyone drops, but recovery is certain: the first retry attempt
    # brings the cell back and the frame resolves ideally (p_miss=0)
    fm = FaultModel.iid(0.0, policy=DegradePolicy.retry(2)).with_dropout(
        1.0, 1.0)
    pooled, ns, acct = faults.aggregate(PROTO, fm, _state(), H, KEY)
    frame_slots = (PROTO.bits + ocs.host_id_bits(N)) * int(
        np.prod(H.shape[1:]))
    assert int(acct.retry_slots) == frame_slots + 1    # one attempt, 2**0
    assert int(acct.outage) == 0 and int(ns.consec) == 0
    assert np.array_equal(
        np.asarray(pooled),
        np.asarray(fedocs.maxpool_quantized(H, PROTO.bits, "first")))


# ---------------------------------------------------------------------------
# zero recompiles across fault parameters (the trace contract, executed)
# ---------------------------------------------------------------------------

def test_jit_zero_recompiles_across_fault_params_and_state():
    traces = []

    @jax.jit
    def f(proto, fm, fs, h, key):
        traces.append(1)
        pooled, ns, acct = faults.aggregate(proto, fm, fs, h, key)
        return pooled, ns, acct.outage

    base = Protocol.ocs(8)
    fs = _state()
    fm = None
    for p in (0.0, 0.05, 0.4):
        fm = FaultModel.gilbert_elliott(
            p_gb=p, p_bg=0.1 + p, p_miss_good=p,
            p_miss_bad=0.5).with_dropout(p, 1.0 - p)
        _, fs, _ = f(base, fm, fs, H, jax.random.fold_in(KEY, int(p * 100)))
    assert len(traces) == 1       # perturbed probs + evolved state: one trace
    # a policy change IS a new program (static metadata)
    f(base, fm.with_policy(DegradePolicy.stale()), fs, H, KEY)
    assert len(traces) == 2


# ---------------------------------------------------------------------------
# scenario registry entries
# ---------------------------------------------------------------------------

def test_fault_scenarios_registered_and_buildable():
    for name in ("burst_cell", "worker_outage_cell"):
        s = get_scenario(name)
        assert s.fault is not None
        fm = s.fault.model()
        assert isinstance(fm, FaultModel)
        assert float(fm.p_bg) == pytest.approx(1.0 / s.fault.burst_len)
    assert float(get_scenario("worker_outage_cell").fault.p_drop) > 0.0


# ---------------------------------------------------------------------------
# the full training carry: checkpoint round-trip + stall checkpointing
# ---------------------------------------------------------------------------

VCFG = vertical.VerticalConfig(
    n_workers=3, input_dim=6, encoder_dims=(8,), embed_dim=4, head_dims=(8,),
    output_dim=3, task="classification",
    aggregation=Protocol.ocs(8, p_miss=0.0, max_rounds=2))
FM_TRAIN = FaultModel.burst(
    burst_len=3.0, gap_len=3.0, p_miss_bad=0.6, p_miss_good=0.0,
    policy=DegradePolicy.stale()).with_dropout(0.3, 0.5)
BATCH = 16


def _fault_loss(values, batch, rng_aux):
    key, fs = rng_aux
    views, labels = batch
    loss, metrics = vertical.loss_fn(VCFG, values, views, labels, rng=key,
                                     fault=FM_TRAIN, fault_state=fs)
    metrics = dict(metrics)
    metrics["aux_state"] = metrics.pop("fault_state")
    return loss, metrics


def _data(step):
    k = jax.random.PRNGKey(1000 + step)
    views = jax.random.normal(k, (VCFG.n_workers, BATCH, VCFG.input_dim),
                              jnp.float32)
    labels = jax.random.randint(jax.random.fold_in(k, 1), (BATCH,), 0,
                                VCFG.output_dim)
    return views, labels


def _aux0():
    return faults.init_state(VCFG.n_workers, (BATCH, VCFG.embed_dim))


def _tcfg(**kw):
    kw.setdefault("log_every", 4)
    kw.setdefault("channel_rng_seed", 7)
    kw.setdefault("aux_state", _aux0())
    kw.setdefault("compress_k", 0.5)
    return TrainerConfig(**kw)


def _params():
    return vertical.init(VCFG, jax.random.PRNGKey(0))


def _opt(steps):
    return optimizers.adamw(schedules.linear_warmup_cosine(1e-2, 2, steps))


def _assert_trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_resume_equals_uninterrupted_bitwise(tmp_path):
    """Satellite 1 acceptance: the checkpoint carries the FULL training
    state — params, opt state, error-feedback memory AND the fault carry
    (burst chains, dropout mask, stale cache) — so interrupt + resume is
    bitwise indistinguishable from an uninterrupted run."""
    steps = 8
    full = trainer.train(_fault_loss, _params(), _opt(steps), _data,
                         _tcfg(steps=steps, ckpt_dir=None))
    d = str(tmp_path)
    trainer.train(_fault_loss, _params(), _opt(steps), _data,
                  _tcfg(steps=4, ckpt_dir=d, ckpt_every=4))
    resumed = trainer.train(_fault_loss, _params(), _opt(steps), _data,
                            _tcfg(steps=steps, ckpt_dir=d, ckpt_every=8))
    assert resumed.history[0]["step"] >= 4       # resumed, not restarted
    _assert_trees_equal(resumed.values, full.values)
    _assert_trees_equal(resumed.opt_state, full.opt_state)
    _assert_trees_equal(resumed.aux_state, full.aux_state)
    # the evolved carry is a real FaultState (chains actually ran)
    assert isinstance(full.aux_state, FaultState)
    assert int(full.aux_state.age) >= 0


def test_aux_state_validation():
    with pytest.raises(ValueError, match="channel_rng_seed"):
        trainer.train(_fault_loss, _params(), _opt(2), _data,
                      TrainerConfig(steps=2, aux_state=_aux0()))
    with pytest.raises(ValueError, match="microbatches == 1"):
        trainer.train(_fault_loss, _params(), _opt(2), _data,
                      TrainerConfig(steps=2, aux_state=_aux0(),
                                    channel_rng_seed=7, microbatches=2))


def test_ckpt_on_stall_persists_the_carry_immediately(tmp_path):
    """The watchdog's stall flag triggers an immediate full-carry
    checkpoint (driven by the injectable clock — no wall-time sleeping)."""
    durations = [1.0, 1.0, 1.0, 1.0, 9.0, 1.0]     # step 4 stalls: 9 > 3x1
    times, t = [], 0.0
    for dt in durations:
        times.append(t)
        t += dt
        times.append(t)
    clock = iter(times).__next__
    res = trainer.train(
        _fault_loss, _params(), _opt(6), _data,
        _tcfg(steps=6, ckpt_dir=str(tmp_path), ckpt_every=0,
              ckpt_on_stall=True, clock=clock, resume=False))
    assert res.straggler_flags == [4]
    assert (tmp_path / "step_0000000005" / "COMMIT").exists()
