"""Deliverable (f): per-architecture reduced-config smoke tests.

Each assigned arch instantiates a reduced config of the same family and runs
one forward + one train step on CPU, asserting output shapes and no NaNs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import model as M
from repro.parallel.sharding import split_tree

pytestmark = pytest.mark.slow    # end-to-end: excluded from the tier-1 CI job


def _batch_for(cfg, b=2, s=16, sd=8, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.encoder_decoder:
        dec = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, sd)), jnp.int32)
        return {"feats": jnp.asarray(
                    rng.standard_normal((b, s, cfg.frontend_dim)),
                    jnp.float32),
                "tokens": dec, "targets": dec}
    if cfg.frontend != "token":
        return {"feats": jnp.asarray(
                    rng.standard_normal((b, s, cfg.frontend_dim)),
                    jnp.float32),
                "targets": toks}
    return {"tokens": toks, "targets": toks}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_smoke_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    m = M.build(cfg)
    values, axes = split_tree(m.init(jax.random.PRNGKey(0)))
    batch = _batch_for(cfg, seed=hash(arch) % 2**31)

    logits = m.logits(values, batch)
    s_out = batch["tokens"].shape[1] if cfg.encoder_decoder else 16
    assert logits.shape == (2, s_out, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN logits"

    loss, metrics = m.loss(values, batch)
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"

    grads = jax.grad(lambda v: m.loss(v, batch)[0])(values)
    flat = [np.asarray(g, np.float32) for g in jax.tree.leaves(grads)]
    assert all(np.isfinite(g).all() for g in flat), f"{arch}: NaN grads"
    total = sum(float((g ** 2).sum()) for g in flat)
    assert total > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The exact assigned hyperparameters (source-of-truth check)."""
    spec = {
        "glm4-9b": (40, 4096, 32, 2, 13696, 151552),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 768, 151936),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "pixtral-12b": (40, 5120, 32, 8, 14336, 131072),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff if cfg.family != "moe" else cfg.moe_d_ff,
           cfg.vocab_size)
    assert got == spec, f"{arch}: {got} != {spec}"


def test_moe_configs():
    q3 = get_config("qwen3-moe-30b-a3b")
    assert (q3.n_experts, q3.experts_per_token) == (128, 8)
    l4 = get_config("llama4-scout-17b-a16e")
    assert (l4.n_experts, l4.experts_per_token) == (16, 1)
    assert l4.moe_shared_expert
    jb = get_config("jamba-1.5-large-398b")
    assert (jb.n_experts, jb.experts_per_token) == (16, 2)


def test_jamba_interleave_ratio():
    cfg = get_config("jamba-1.5-large-398b")
    plan = [m for m, _ in cfg.layer_plan()]
    assert plan.count("attn") == 1 and plan.count("mamba") == 7
    ffns = [f for _, f in cfg.layer_plan()]
    assert ffns.count("moe") == 4 and ffns.count("mlp") == 4


def test_param_counts_sane():
    """Param counting should land near the nameplate sizes."""
    cases = {
        "glm4-9b": (9e9, 0.5),
        "qwen2.5-32b": (32e9, 0.3),
        "qwen1.5-0.5b": (0.5e9, 0.4),
        "minicpm-2b": (2.7e9, 0.5),
        "jamba-1.5-large-398b": (398e9, 0.3),
        "xlstm-125m": (125e6, 0.8),
    }
    for arch, (target, tol) in cases.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n, target)


def test_active_params_less_than_total_for_moe():
    for arch in ("qwen3-moe-30b-a3b", "llama4-scout-17b-a16e",
                 "jamba-1.5-large-398b"):
        cfg = get_config(arch)
        assert cfg.param_count(active_only=True) < cfg.param_count()
