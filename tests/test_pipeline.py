"""GPipe pipeline (stage axis) vs sequential oracle — subprocess, 4 devices."""

import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, f"OUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


def test_gpipe_matches_sequential():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import gpipe, sequential_reference

        mesh = jax.make_mesh((4,), ("stage",))
        rng = np.random.default_rng(0)
        d = 16
        params = {"w": jnp.asarray(rng.standard_normal((4, d, d)) * 0.3,
                                   jnp.float32),
                  "b": jnp.asarray(rng.standard_normal((4, d)) * 0.1,
                                   jnp.float32)}

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"] + p["b"])

        x = jnp.asarray(rng.standard_normal((6, 8, d)), jnp.float32)
        piped = jax.jit(gpipe(stage_fn, mesh))(params, x)
        ref = sequential_reference(stage_fn, params, x)
        err = float(jnp.max(jnp.abs(piped - ref)))
        print("err", err)
        assert err < 1e-5
        print("PIPELINE_OK")
    """)
    assert "PIPELINE_OK" in out


def test_gpipe_differentiable():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel.pipeline import gpipe, sequential_reference

        mesh = jax.make_mesh((4,), ("stage",))
        rng = np.random.default_rng(1)
        d = 8
        params = {"w": jnp.asarray(rng.standard_normal((4, d, d)) * 0.3,
                                   jnp.float32)}

        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"])

        x = jnp.asarray(rng.standard_normal((5, 4, d)), jnp.float32)
        piped = gpipe(stage_fn, mesh)
        g1 = jax.grad(lambda p: jnp.sum(piped(p, x) ** 2))(params)
        g2 = jax.grad(lambda p: jnp.sum(
            sequential_reference(stage_fn, p, x) ** 2))(params)
        err = float(jnp.max(jnp.abs(g1["w"] - g2["w"])))
        print("grad err", err)
        assert err < 1e-4
        print("PIPELINE_GRAD_OK")
    """)
    assert "PIPELINE_GRAD_OK" in out
