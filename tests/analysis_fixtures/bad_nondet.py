"""Seeded violation fixture for the `nondeterminism` lint rule.

Never imported.  Wall clocks and global-state RNGs are illegal in engine
code (and only there — the same file lints clean with ``engine=False``,
which is how benchmark timing loops stay legal).
"""

import random
import time

import numpy as np


def schedule_jitter():
    t0 = time.time()
    jitter = random.random() + np.random.rand()
    return t0, jitter
