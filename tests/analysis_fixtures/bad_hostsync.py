"""Seeded violation fixture for the `host-sync-in-jit` lint rule.

Never imported.  The jitted scope below concretizes traced values three
ways (`float()`, `np.asarray()`, `.item()`); each must be flagged by
`host-sync-in-jit` and by nothing else.
"""

import jax
import numpy as np


@jax.jit
def step(x):
    lr = float(x[0])
    host = np.asarray(x)
    return x * lr + x.sum().item() + host[0]
