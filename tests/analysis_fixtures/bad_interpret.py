"""Seeded violation fixture for the `interpret-hardcode` lint rule.

Never imported — the lint is purely syntactic.  Every construct in this
file must be flagged by `interpret-hardcode` and by nothing else.
"""

INTERPRET = True


def launch(kernel, x):
    return kernel(x, interpret=True)
