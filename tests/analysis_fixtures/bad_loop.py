"""Seeded violation fixture for the `eager-loop-in-jit` lint rule.

Never imported.  The Python loop below unrolls eight `jnp.sin` calls into
the trace; it must be flagged by `eager-loop-in-jit` and by nothing else.
"""

import jax
import jax.numpy as jnp


@jax.jit
def accumulate(xs):
    total = jnp.zeros((), jnp.float32)
    for i in range(8):
        total = total + jnp.sin(xs[i])
    return total
