"""Seeded silent-except violations (never imported; the lint walks the AST).

Engine code must not swallow failures: a bare ``except:`` hides everything
including ``KeyboardInterrupt``, and a handler whose body is only
``pass``/``...`` silently discards the error.  Outside engine dirs both are
legal (benchmarks and scripts may continue past best-effort failures).
"""


def bad_bare(path):
    try:
        return open(path).read()
    except:                      # noqa: E722  (the seeded violation)
        pass


def bad_swallow(x):
    try:
        return 1 / x
    except ValueError:
        ...
