"""Attention layout/feature equivalences: worker vs plain, padding, GQA."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import attention
from repro.parallel.sharding import split_tree


def _values(cfg, seed=0):
    return split_tree(attention.attn_init(cfg, jax.random.PRNGKey(seed)))[0]


def _x(cfg, b=2, s=8, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    return x, pos


def test_layout_selection():
    assert attention.attn_layout(get_reduced("glm4-9b")) == "worker"
    assert attention.attn_layout(get_reduced("qwen2.5-32b")) == "plain"
    assert attention.attn_layout(
        get_reduced("qwen2.5-32b", pad_heads_to=6)) == "worker"


def test_padded_heads_match_unpadded_when_zero_masked():
    """Padding adds zero-masked heads: same attention output distribution
    structure; verify the pad path yields finite, shape-correct results and
    decode-vs-full consistency is covered in test_models_decode."""
    cfg = get_reduced("qwen2.5-32b", pad_heads_to=6)
    p = _values(cfg)
    x, pos = _x(cfg)
    y = attention.attn_full(cfg, p, x, pos, causal=True)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert p["wq"].shape[1] == 6          # physically padded


def test_causal_mask_blocks_future():
    cfg = get_reduced("glm4-9b")
    p = _values(cfg)
    x, pos = _x(cfg, s=8, seed=1)
    y1 = attention.attn_full(cfg, p, x, pos, causal=True)
    # changing tokens at positions > t must not change output at t
    x2 = x.at[:, 5:].set(0.0)
    y2 = attention.attn_full(cfg, p, x2, pos, causal=True)
    assert float(jnp.max(jnp.abs(y1[:, :5] - y2[:, :5]))) < 1e-5
    # non-causal DOES leak
    z1 = attention.attn_full(cfg, p, x, pos, causal=False)
    z2 = attention.attn_full(cfg, p, x2, pos, causal=False)
    assert float(jnp.max(jnp.abs(z1[:, :5] - z2[:, :5]))) > 1e-4


def test_gqa_groups_share_kv():
    """With n_kv=1, every query head attends over the same single KV head."""
    cfg = get_reduced("glm4-9b", n_heads=4, n_kv_heads=1)
    p = _values(cfg, seed=2)
    x, pos = _x(cfg, seed=3)
    y = attention.attn_full(cfg, p, x, pos, causal=True)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())


def test_scores_dtype_bf16_close():
    cfg32 = get_reduced("glm4-9b")
    cfg16 = get_reduced("glm4-9b", scores_dtype="bf16")
    p = _values(cfg32, seed=4)
    x, pos = _x(cfg32, seed=5)
    y32 = attention.attn_full(cfg32, p, x, pos, causal=True)
    y16 = attention.attn_full(cfg16, p, x, pos, causal=True)
    rel = float(jnp.max(jnp.abs(y32 - y16)) / (jnp.max(jnp.abs(y32)) + 1e-9))
    assert rel < 0.05, rel


def test_qkv_bias_applied():
    cfg = get_reduced("qwen1.5-0.5b")     # qkv_bias=True
    p = _values(cfg, seed=6)
    assert "bq" in p and "bk" in p and "bv" in p
    x, pos = _x(cfg, seed=7)
    y0 = attention.attn_full(cfg, p, x, pos, causal=True)
    p2 = dict(p)
    p2["bq"] = p["bq"] + 1.0
    y1 = attention.attn_full(cfg, p2, x, pos, causal=True)
    assert float(jnp.max(jnp.abs(y0 - y1))) > 1e-6
