"""Sharding substrate: rule resolution, divisibility fallback, ZeRO axes."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_debug_mesh, rules_for
from repro.parallel import sharding as sh


@pytest.fixture(scope="module")
def mesh():
    # single-device "mesh" with both axes size 1 (host has 1 device)
    return jax.make_mesh((1, 1), ("data", "model"))


def test_resolve_axes_drops_absent_mesh_axes(mesh):
    spec = sh.resolve_axes(("batch", "seq", "embed"), mesh)
    assert spec == P("data", None, None)    # pod absent -> dropped


def test_sharding_for_shape_divisibility(mesh):
    # 'model' has size 1 here so everything divides; exercise the logic
    # with an explicit fake-size check instead
    sizes = sh.mesh_axis_sizes(mesh)
    assert sizes == {"data": 1, "model": 1}
    s = sh.sharding_for_shape(("vocab", "embed"), (122753, 64), mesh)
    assert s.spec == P("model", None)       # divisible by 1


def test_zero_axes_picks_largest_unsharded_divisible():
    axes = sh.zero_axes(("worker", None, None), (16, 100, 64), fsdp_size=4)
    assert axes == ("worker", "fsdp", None)
    axes = sh.zero_axes((None, None), (7, 13), fsdp_size=4)
    assert axes == (None, None)             # nothing divisible -> unchanged
    axes = sh.zero_axes(("embed",), (64,), fsdp_size=1)
    assert axes == ("embed",)


def test_split_and_retag():
    tree = {"a": sh.Tagged(jnp.zeros((2, 3)), ("x", "y"))}
    values, axes = sh.split_tree(tree)
    assert values["a"].shape == (2, 3)
    assert axes["a"] == ("x", "y")
    stacked = sh.retag_stacked(tree, "layers")
    assert stacked["a"].axes == ("layers", "x", "y")


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    assert sh.constrain(x, ("batch", "embed")) is x


def test_rules_for_long_context(mesh):
    r = rules_for("long_500k", 1, mesh)
    assert r["batch"] is None
    assert r["kv_seq"] == ("data",)
    r2 = rules_for("train_4k", 256, mesh)
    assert r2["batch"] == ("pod", "data")
    assert r2["kv_seq"] is None


def test_tagged_is_pytree():
    t = sh.Tagged(jnp.ones((2,)), ("embed",))
    leaves = jax.tree.leaves(t)
    assert len(leaves) == 1
    mapped = jax.tree.map(lambda x: x * 2, t)
    assert isinstance(mapped, sh.Tagged)
    assert mapped.axes == ("embed",)
