"""Multi-device SPMD tests (subprocess with 8 forced host devices).

Covers: sharded-vs-single-device numerical equivalence of the FedOCS train
step, presence of all-reduce(max) collectives in the partitioned HLO,
quantized-code collectives (u8), and elastic checkpoint resharding.
"""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=900)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


def test_sharded_train_step_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_reduced
        from repro.models import model as M
        from repro.parallel import sharding as sh
        from repro.launch.mesh import make_debug_mesh, rules_for

        cfg = get_reduced("glm4-9b", n_workers=4, tp_fusion="max")
        m = M.build(cfg)
        tagged = m.init(jax.random.PRNGKey(0))
        values, axes = sh.split_tree(tagged)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, 256, (4, 16)), jnp.int32)}
        batch["targets"] = batch["tokens"]

        # single-device reference
        ref_loss, _ = m.loss(values, batch)
        ref_grad = jax.grad(lambda v: m.loss(v, batch)[0])(values)

        mesh = make_debug_mesh(2, 4)
        rules = rules_for("train_4k", 4, mesh)
        shd = sh.tree_shardings_for_values(axes, values, mesh, rules)
        vs = jax.device_put(values, shd)
        bs = jax.device_put(batch, {
            "tokens": sh.sharding_for_shape(("batch","seq"), (4,16), mesh, rules),
            "targets": sh.sharding_for_shape(("batch","seq"), (4,16), mesh, rules)})
        with sh.use_mesh(mesh, rules):
            f = jax.jit(lambda v, b: m.loss(v, b)[0], in_shardings=(shd, None))
            loss = f(vs, bs)
            g = jax.jit(jax.grad(lambda v: m.loss(v, bs)[0]),
                        in_shardings=(shd,))(vs)
        dl = abs(float(loss) - float(ref_loss))
        print("dloss", dl)
        assert dl < 1e-4, dl
        errs = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                            g, ref_grad)
        worst = max(jax.tree.leaves(errs))
        print("worst grad err", worst)
        assert worst < 1e-3, worst
        print("SHARDED_MATCHES")
    """)
    assert "SHARDED_MATCHES" in out


def test_fedocs_emits_all_reduce_max_collective():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.models import model as M
        from repro.parallel import sharding as sh
        from repro.launch.mesh import make_debug_mesh, rules_for

        for fusion, code_dtype in (("max", "f32"), ("max_q8", "u8")):
            cfg = get_reduced("glm4-9b", n_workers=4, tp_fusion=fusion)
            m = M.build(cfg)
            values, axes = sh.split_tree(
                jax.eval_shape(m.init, jax.random.PRNGKey(0)))
            mesh = make_debug_mesh(2, 4)
            rules = rules_for("train_4k", 4, mesh)
            shd = sh.tree_shardings_for_values(axes, values, mesh, rules)
            batch = {"tokens": jax.ShapeDtypeStruct((4, 16), jnp.int32),
                     "targets": jax.ShapeDtypeStruct((4, 16), jnp.int32)}
            with sh.use_mesh(mesh, rules):
                lowered = jax.jit(lambda v, b: m.loss(v, b)[0],
                                  in_shardings=(shd, None)).lower(values, batch)
                hlo = lowered.compile().as_text()
            has_max_ar = False
            for line in hlo.splitlines():
                if "all-reduce" in line and "maximum" in line.lower():
                    has_max_ar = True
                if " all-reduce(" in line or " all-reduce-start(" in line:
                    pass
            # to_apply=%region with maximum: search module text
            assert "maximum" in hlo, fusion
            assert "all-reduce" in hlo, fusion
            if fusion == "max_q8":
                assert "u8[" in hlo, "u8 code collective missing"
            print("OK", fusion)
        print("COLLECTIVES_PRESENT")
    """)
    assert "COLLECTIVES_PRESENT" in out


def test_elastic_checkpoint_reshard():
    out = _run("""
        import tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.models import model as M
        from repro.parallel import sharding as sh
        from repro.launch.mesh import make_debug_mesh, rules_for
        from repro.checkpoint import checkpointer as ck

        cfg = get_reduced("glm4-9b", n_workers=4)
        m = M.build(cfg)
        values, axes = sh.split_tree(m.init(jax.random.PRNGKey(0)))

        mesh_a = make_debug_mesh(2, 4)     # 8 devices
        rules = rules_for("train_4k", 4, mesh_a)
        shd_a = sh.tree_shardings_for_values(axes, values, mesh_a, rules)
        vs = jax.device_put(values, shd_a)

        with tempfile.TemporaryDirectory() as d:
            ck.save(d, 1, vs, axes_tree=axes)
            # restore onto a DIFFERENT mesh (elastic rescale 8 -> 2 devices)
            mesh_b = make_debug_mesh(1, 2)
            shd_b = sh.tree_shardings_for_values(axes, values, mesh_b, rules)
            restored, step, _ = ck.restore(d, template=values,
                                           shardings=shd_b)
            errs = jax.tree.map(
                lambda a, b: float(jnp.max(jnp.abs(a - b))), restored, values)
            assert max(jax.tree.leaves(errs)) == 0.0
            ndev = {len(x.sharding.device_set)
                    for x in jax.tree.leaves(restored)}
            print("device sets:", ndev)
            assert max(ndev) <= 2
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_long_context_cache_sequence_sharding():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import model as M
        from repro.parallel import sharding as sh
        from repro.launch.mesh import make_debug_mesh, rules_for

        cfg = get_config("xlstm-125m", n_workers=4,
                         n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
                         vocab_size=256)
        m = M.build(cfg)
        mesh = make_debug_mesh(2, 4)
        rules = rules_for("long_500k", 1, mesh)
        assert rules["batch"] is None
        values, axes = sh.split_tree(
            jax.eval_shape(m.init, jax.random.PRNGKey(0)))
        shd = sh.tree_shardings_for_values(axes, values, mesh, rules)
        cache = jax.eval_shape(lambda: m.cache_init(1, 1024))
        cache_axes = m.cache_axes()
        cache_shd = sh.tree_shardings_for_values(cache_axes, cache, mesh,
                                                 rules)
        with sh.use_mesh(mesh, rules):
            lowered = jax.jit(m.decode_step,
                              in_shardings=(shd, None, None, cache_shd)
                              ).lower(values,
                                      jax.ShapeDtypeStruct((1,1), jnp.int32),
                                      jax.ShapeDtypeStruct((1,), jnp.int32),
                                      cache)
            lowered.compile()
        print("LONG_CTX_OK")
    """)
    assert "LONG_CTX_OK" in out
