"""Fused max-pool kernel vs jnp oracle, via the unified parity harness."""

import jax
import jax.numpy as jnp
import numpy as np

from kernel_parity import ParityOp, check
from proptest import grid, random_floats
from repro.kernels.maxpool import maxpool as K
from repro.kernels.maxpool import ops as O
from repro.kernels.maxpool import ref as R


def _h(case):
    return jnp.asarray(
        random_floats(case["seed"], (case["n"], case["m"], case["k"]),
                      specials=False), case["dtype"])


FUSED = ParityOp(
    name="maxpool_fused",
    make=lambda case: (_h(case),),
    kernel=lambda h: K.maxpool_fused(h, block_m=64, block_k=64),
    reference=R.maxpool_fused,
    cases=list(grid(n=[2, 8, 16], m=[64, 192], k=[128], seed=[0, 1],
                    dtype=[jnp.float32, jnp.bfloat16])),
)


def _bwd_args(case):
    h = _h(case)
    _, w = K.maxpool_fused(h)
    g = jnp.asarray(random_floats(case["seed"] + 100,
                                  (case["m"], case["k"]), specials=False))
    return w, g, case["n"]


WINNER_BWD = ParityOp(
    name="maxpool_winner_bwd",
    make=_bwd_args,
    kernel=K.maxpool_winner_bwd,
    reference=R.maxpool_winner_bwd,
    cases=list(grid(n=[8], m=[64, 128], k=[64, 256], seed=[0, 1],
                    dtype=[jnp.float32])),
)

AUTOFIT = ParityOp(
    name="maxpool_block_autofit",
    make=lambda case: (_h(case),),
    kernel=lambda h: K.maxpool_fused(h, block_m=128, block_k=256),
    reference=R.maxpool_fused,
    # odd shapes force fit_block below the requested tile sizes
    cases=list(grid(n=[3], m=[96], k=[384], seed=[2],
                    dtype=[jnp.float32])),
)


def test_fused_maxpool_parity():
    check(FUSED)


def test_winner_bwd_parity():
    check(WINNER_BWD)


def test_block_autofit_odd_shapes():
    check(AUTOFIT)


def test_ops_maxpool_grad_single_winner():
    h = jnp.asarray(random_floats(5, (4, 128, 128), specials=False))
    g = jax.grad(lambda x: jnp.sum(O.maxpool(x)))(h)
    s = np.asarray(g).sum(axis=0)
    assert np.allclose(s, 1.0)
    assert ((np.asarray(g) != 0).sum(axis=0) == 1).all()


def test_ops_matches_core_fedocs():
    from repro.core import fedocs
    h = jnp.asarray(random_floats(9, (8, 128, 256), specials=False))
    assert jnp.array_equal(O.maxpool(h), fedocs.maxpool(h, "all"))
