"""Per-kernel allclose sweep: fused max-pool vs jnp oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import grid, random_floats, sweep
from repro.kernels.maxpool import maxpool as K
from repro.kernels.maxpool import ops as O
from repro.kernels.maxpool import ref as R


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_maxpool_sweep(dtype):
    def prop(case):
        n, m, k = case["n"], case["m"], case["k"]
        h = jnp.asarray(random_floats(case["seed"], (n, m, k),
                                      specials=False), dtype)
        v, w = K.maxpool_fused(h, block_m=64, block_k=64)
        vr, wr = R.maxpool_fused(h)
        assert jnp.array_equal(v, vr), "pooled values"
        assert jnp.array_equal(w, wr), "winner indices"
    sweep(prop, list(grid(n=[2, 8, 16], m=[64, 192], k=[128],
                          seed=[0, 1])))


def test_winner_bwd_sweep():
    def prop(case):
        n, m, k = 8, case["m"], case["k"]
        h = jnp.asarray(random_floats(case["seed"], (n, m, k),
                                      specials=False))
        _, w = K.maxpool_fused(h)
        g = jnp.asarray(random_floats(case["seed"] + 100, (m, k),
                                      specials=False))
        gh = K.maxpool_winner_bwd(w, g, n)
        ghr = R.maxpool_winner_bwd(w, g, n)
        assert jnp.allclose(gh, ghr)
    sweep(prop, list(grid(m=[64, 128], k=[64, 256], seed=[0, 1])))


def test_ops_maxpool_grad_single_winner():
    h = jnp.asarray(random_floats(5, (4, 128, 128), specials=False))
    g = jax.grad(lambda x: jnp.sum(O.maxpool(x)))(h)
    s = np.asarray(g).sum(axis=0)
    assert np.allclose(s, 1.0)
    assert ((np.asarray(g) != 0).sum(axis=0) == 1).all()


def test_ops_matches_core_fedocs():
    from repro.core import fedocs
    h = jnp.asarray(random_floats(9, (8, 128, 256), specials=False))
    assert jnp.array_equal(O.maxpool(h), fedocs.maxpool(h, "all"))


def test_block_autofit_odd_shapes():
    h = jnp.asarray(random_floats(2, (3, 96, 384), specials=False))
    v, w = K.maxpool_fused(h, block_m=128, block_k=256)
    vr, wr = R.maxpool_fused(h)
    assert jnp.array_equal(v, vr) and jnp.array_equal(w, wr)
