"""Collective-parser unit tests + roofline term math."""

import pytest

from repro.launch import hlo_analysis as H

SAMPLE = """
HloModule test
  %all-reduce.1 = bf16[16,128]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %all-gather.2 = f32[64,256]{1,0} all-gather(%y), replica_groups=[8,2]<=[16], dimensions={0}
  %reduce-scatter.3 = bf16[8,128]{1,0} reduce-scatter(%z), replica_groups={{0,1}}, to_apply=%max
  %all-to-all.4 = f32[32]{0} all-to-all(%w), replica_groups={{0,1,2,3}}
  %collective-permute.5 = u8[1024]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %add.6 = bf16[16,128]{1,0} add(%a, %b)
"""


def test_parse_counts_and_payloads():
    st = H.parse_collectives(SAMPLE)
    assert st.counts == {"all-reduce": 1, "all-gather": 1,
                         "reduce-scatter": 1, "all-to-all": 1,
                         "collective-permute": 1}
    assert st.payload_bytes["all-reduce"] == 16 * 128 * 2
    assert st.payload_bytes["all-gather"] == 64 * 256 * 4
    assert st.payload_bytes["collective-permute"] == 1024


def test_link_bytes_ring_model():
    st = H.parse_collectives(SAMPLE)
    expect = (2 * 16 * 128 * 2 * 3 / 4        # AR group 4
              + 64 * 256 * 4 * 1 / 2          # AG iota group size 2
              + 8 * 128 * 2 * 1               # RS group 2 -> (g-1)=1
              + 32 * 4 * 3 / 4                # A2A group 4
              + 1024)                         # permute
    assert st.link_bytes == pytest.approx(expect)


def test_start_ops_not_double_counted():
    txt = """
  %all-reduce-start.1 = bf16[128]{0} all-reduce-start(%x), replica_groups={{0,1}}
  %all-reduce-done.2 = bf16[128]{0} all-reduce-done(%all-reduce-start.1)
"""
    st = H.parse_collectives(txt)
    assert st.counts == {"all-reduce": 1}


def test_roofline_terms_bottleneck():
    t = H.roofline_terms(197e12, 819e9, 0.0)      # 1s compute, 1s memory
    assert t["t_compute_s"] == pytest.approx(1.0)
    assert t["t_memory_s"] == pytest.approx(1.0)
    t2 = H.roofline_terms(1e12, 1e9, 500e9)
    assert t2["bottleneck"] == "collective"
