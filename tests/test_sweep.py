"""Sweep-engine properties: batched grid == per-round protocol, O(1) compiles.

The contract under test (ISSUE: batched OCS scenario-sweep engine):
  * every grid cell of the batched sweep must equal the unbatched per-round
    ``ocs_maxpool`` / ``reference_maxpool`` oracles bit-for-bit — including
    the channel-accounting counters under padded-N masking;
  * ``p_miss=0`` through the noisy engine reduces to the noise-free protocol;
  * a >=24-cell (N x bits x p_miss) grid compiles at most once per ``bits``
    value (trace counters), never once per cell.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import grid, random_floats
from repro.core import ocs
from repro.sim import results as sim_results
from repro.sim import scenarios as sim_scenarios
from repro.sim import sweep as sim_sweep
from repro.sim.scenarios import Scenario, scenario_grid

CLEAN_FIELDS = ("winner", "value", "pooled_code", "ties", "contention_slots",
                "blocking_tx", "payload_tx", "concat_payload_tx")


def _grid_cells():
    return [Scenario(f"t/N{c['n']}_b{c['bits']}", n_workers=c["n"],
                     bits=c["bits"])
            for c in grid(n=[2, 5, 16], bits=[8, 16])]


def test_batched_sweep_equals_per_round_protocol():
    """Every (scenario, round) cell == unbatched ocs_maxpool, all counters."""
    cells = _grid_cells()
    rounds, k = 3, 17
    sw = sim_sweep.run_sweep(cells, k_elems=k, rounds=rounds, seed=3,
                             include_noisy=False)
    assert sw.n_max == 16                       # N=2/5 cells are padded
    for i, s in enumerate(cells):
        for r in range(rounds):
            h = jnp.asarray(sw.scenario_h(i)[r])
            ref = ocs.ocs_maxpool(h, bits=s.bits)
            cell = sw.clean_cell(i, r)
            for f in CLEAN_FIELDS:
                got, want = np.asarray(getattr(cell, f)), np.asarray(getattr(ref, f))
                assert np.array_equal(got, want), \
                    f"{s.name} round {r}: {f} {got} != {want}"


def test_batched_sweep_equals_reference_maxpool():
    """Selection outcome also matches the pure-jnp argmax oracle."""
    cells = _grid_cells()
    sw = sim_sweep.run_sweep(cells, k_elems=33, rounds=2, seed=4,
                             include_noisy=False)
    for i, s in enumerate(cells):
        for r in range(2):
            h = jnp.asarray(sw.scenario_h(i)[r])
            w, v, c = ocs.reference_maxpool(h, s.bits)
            cell = sw.clean_cell(i, r)
            assert np.array_equal(np.asarray(cell.winner), np.asarray(w))
            assert np.array_equal(np.asarray(cell.value), np.asarray(v))
            assert np.array_equal(np.asarray(cell.pooled_code), np.asarray(c))


def test_noisy_core_padding_is_inert():
    """Oversized scans and masked-row contents cannot perturb the noisy core.

    (Bit-exactness vs the *unbatched* noisy wrapper is only possible at equal
    padded shape: `bernoulli` draws an (N_max, K) block, so the per-worker
    noise stream depends on N_max by construction.  What must hold is that
    within one padded shape, the scan-length bound and the padding rows are
    invisible.)
    """
    for seed in range(3):
        h = jnp.asarray(random_floats(seed, (6, 24), specials=False))
        key = jax.random.PRNGKey(seed)
        mask = jnp.arange(16) < 6
        h_pad = jnp.zeros((16, 24), jnp.float32).at[:6].set(h)
        # padding rows filled with garbage that would win any contention
        h_bad = h_pad.at[6:].set(1e9)
        id_bits = ocs.host_id_bits(6)
        a = ocs.ocs_maxpool_noisy_core(h_pad, mask, id_bits, key, 0.07,
                                       bits=12, max_id_bits=id_bits)
        b = ocs.ocs_maxpool_noisy_core(h_pad, mask, id_bits, key, 0.07,
                                       bits=12,
                                       max_id_bits=ocs.host_id_bits(16))
        c = ocs.ocs_maxpool_noisy_core(h_bad, mask, id_bits, key, 0.07,
                                       bits=12,
                                       max_id_bits=ocs.host_id_bits(16))
        for other in (b, c):
            assert np.array_equal(np.asarray(a.winner), np.asarray(other.winner))
            assert np.array_equal(np.asarray(a.correct), np.asarray(other.correct))
            assert int(a.collisions) == int(other.collisions)
            assert int(a.contention_slots) == int(other.contention_slots)
        assert bool(np.all(np.asarray(a.winner) < 6))


def test_zero_miss_noisy_sweep_reduces_to_clean():
    """p_miss=0 grid cells through the noisy engine == clean protocol."""
    cells = scenario_grid(n_workers=(3, 8), bits=(8, 16), p_miss=(0.0,))
    sw = sim_sweep.run_sweep(cells, k_elems=21, rounds=2, seed=5)
    for i in range(len(cells)):
        for r in range(2):
            clean, noisy = sw.clean_cell(i, r), sw.noisy_cell(i, r)
            assert np.array_equal(np.asarray(noisy.winner),
                                  np.asarray(clean.winner))
            assert bool(np.all(np.asarray(noisy.correct)))
            assert int(noisy.collisions) == 0


def test_grid_compiles_once_per_bits_value():
    """>=24 cells (N x bits x p_miss) -> <=2 compilations, cache-hit on rerun."""
    cells = scenario_grid(n_workers=(4, 8, 16), bits=(8, 16),
                          p_miss=(0.0, 0.02, 0.05, 0.1))
    assert len(cells) == 24
    sim_sweep.reset_trace_counts()
    sim_sweep.run_sweep(cells, k_elems=16, rounds=2, include_clean=False)
    traces = sim_sweep.trace_counts()
    assert traces["noisy"] <= 2, traces
    assert traces["clean"] == 0, traces
    # identical grid again: jit cache hit, no new traces
    sim_sweep.run_sweep(cells, k_elems=16, rounds=2, include_clean=False)
    assert sim_sweep.trace_counts() == traces


def test_multichannel_latency_and_results_emitter(tmp_path):
    cells = [Scenario("t/c1", n_workers=4), Scenario("t/c4", n_workers=4,
                                                     n_channels=4)]
    h = np.asarray(random_floats(7, (1, 4, 32), specials=False))
    sw = sim_sweep.run_sweep(cells, k_elems=32, rounds=1,
                             h_by_scenario=[h, h])
    slots = int(np.asarray(sw.clean.contention_slots)[0, 0])
    assert int(sw.clean_latency_slots[0, 0]) == slots
    assert int(sw.clean_latency_slots[1, 0]) == -(-slots // 4)

    recs = sim_results.summarize(sw)
    assert recs[0]["payload_tx"] == 32
    assert recs[0]["concat_payload_tx"] == 4 * 32
    assert recs[0]["uplink_ratio"] == pytest.approx(4.0)
    rows = sim_results.to_rows(recs)
    assert len(rows) == 2 and rows[0].startswith("sweep/t/c1,")
    out = tmp_path / "sweep.json"
    sim_results.write_json(recs, str(out))
    import json
    loaded = json.loads(out.read_text())
    assert loaded[1]["n_channels"] == 4
    assert loaded[1]["latency_slots"] == -(-slots // 4)


def test_near_far_scenario_matches_unbatched_vector_p():
    """A per-worker p_miss scenario through the batched sweep equals the
    unbatched noisy protocol with the same (N,) vector, and a tuple with
    equal entries equals the scalar scenario (broadcast equivalence at the
    sweep level)."""
    from repro.sim.scenarios import near_far_p_miss
    nf = near_far_p_miss(8, 0.0, 0.3)
    cells = [Scenario("t/nf", n_workers=8, bits=12, p_miss=nf),
             Scenario("t/flat_vec", n_workers=8, bits=12,
                      p_miss=(0.05,) * 8),
             Scenario("t/flat", n_workers=8, bits=12, p_miss=0.05)]
    sw = sim_sweep.run_sweep(cells, k_elems=24, rounds=2, rng_seed=9,
                             include_clean=False)
    keys = jax.random.split(jax.random.PRNGKey(9), 3 * 2).reshape(3, 2, -1)
    for i, p in ((0, jnp.asarray(nf, jnp.float32)), (1, 0.05)):
        for r in range(2):
            h = jnp.asarray(sw.scenario_h(i)[r])
            ref = ocs.ocs_maxpool_noisy(h, keys[i, r], bits=12, p_miss=p)
            cell = sw.noisy_cell(i, r)
            assert np.array_equal(np.asarray(cell.winner),
                                  np.asarray(ref.winner)), (i, r)
            assert int(cell.contention_slots) == int(ref.contention_slots)
            assert int(cell.rounds) == int(ref.rounds)
    # equal-entry tuple == scalar scenario, every leaf (single-cell sweeps
    # so both draw the same features and noise keys)
    s_vec = sim_sweep.run_sweep([cells[1]], k_elems=24, rounds=1,
                                rng_seed=3, include_clean=False)
    s_sca = sim_sweep.run_sweep([cells[2]], k_elems=24, rounds=1,
                                rng_seed=3, include_clean=False)
    for x, y in zip(jax.tree.leaves(s_vec.noisy),
                    jax.tree.leaves(s_sca.noisy)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_scenario_registry_and_grid():
    assert "dense_cell" in sim_scenarios.names()
    s = sim_scenarios.get("dense_cell")
    assert s.n_workers == 64
    with pytest.raises(KeyError):
        sim_scenarios.get("no_such_scenario")
    with pytest.raises(ValueError):
        sim_scenarios.register(Scenario("dense_cell", n_workers=2))
    with pytest.raises(ValueError):
        Scenario("bad", n_workers=0)
    with pytest.raises(ValueError):
        Scenario("bad", n_workers=2, p_miss=1.0)
    with pytest.raises(ValueError):              # per-worker length mismatch
        Scenario("bad", n_workers=4, p_miss=(0.0, 0.1))
    with pytest.raises(ValueError):              # per-worker out of range
        Scenario("bad", n_workers=2, p_miss=(0.0, 1.0))
    assert "near_far_cell" in sim_scenarios.names()
    nf = sim_scenarios.get("near_far_cell")
    assert nf.p_miss_per_worker() == nf.p_miss and len(nf.p_miss) == 16
    assert sim_scenarios.get("lab_bench").p_miss_per_worker() == (0.0, 0.0)
    # bits + ceil(log2 N) tie-break bits must fit the 32-bit contention word
    with pytest.raises(ValueError):
        Scenario("bad", n_workers=4, bits=32)
    with pytest.raises(ValueError):
        ocs.ocs_maxpool(jnp.zeros((4, 8), jnp.float32), bits=32)
    cells = scenario_grid(n_workers=(2, 4), bits=(8,), p_miss=(0.0, 0.1),
                          n_channels=(1, 2))
    assert len(cells) == 8
    assert cells[0].name == "grid/N2_b8_p0_c1"
    assert len({c.name for c in cells}) == 8


def test_mixed_bits_grid_uses_per_group_id_bits():
    """A wide-bits cell next to a large-N narrow-bits cell must not overflow.

    Historically ``max_id_bits`` was the max over ALL scenarios while the
    32-bit-word guard fired per bits-group, so bits=24 (id_bits=2) raised on
    the id_bits=9 of an unrelated N=512 bits=8 cell."""
    cells = [Scenario("mix/wide", n_workers=4, bits=24),
             Scenario("mix/huge", n_workers=512, bits=8)]
    sw = sim_sweep.run_sweep(cells, k_elems=8, rounds=1)   # must not raise
    # each cell still matches the unbatched oracle at its own bits depth
    for i, s in enumerate(cells):
        h = jnp.asarray(sw.scenario_h(i)[0])
        ref = ocs.ocs_maxpool(h, bits=s.bits)
        cell = sw.clean_cell(i, 0)
        assert np.array_equal(np.asarray(cell.winner), np.asarray(ref.winner))
        assert int(cell.contention_slots) == int(ref.contention_slots)


def test_sharded_sweep_matches_vmap_path():
    """Scenario-axis shard_map over >=2 forced host devices is bit-for-bit
    identical to the single-device vmap path — including a group size that
    does not divide the device count (padding rows dropped)."""
    import os
    import subprocess
    import sys
    import textwrap

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import jax, numpy as np
        assert jax.local_device_count() == 4, jax.devices()
        from repro.sim.scenarios import scenario_grid
        from repro.sim import sweep as sim_sweep
        # 6 cells per bits group: not divisible by 4 nor by 2 -> padding
        cells = scenario_grid(n_workers=(2, 5, 16), bits=(8, 16),
                              p_miss=(0.0, 0.05))
        ref = sim_sweep.run_sweep(cells, k_elems=16, rounds=2, n_devices=1)
        for n_dev in (None, 2, 4):     # None = auto-detect (4 devices)
            got = sim_sweep.run_sweep(cells, k_elems=16, rounds=2,
                                      n_devices=n_dev)
            for ta, tb in ((ref.clean, got.clean), (ref.noisy, got.noisy)):
                for x, y in zip(jax.tree.leaves(ta), jax.tree.leaves(tb)):
                    assert np.array_equal(np.asarray(x), np.asarray(y)), n_dev
            assert np.array_equal(ref.clean_latency_slots,
                                  got.clean_latency_slots)
            assert np.array_equal(ref.noisy_latency_slots,
                                  got.noisy_latency_slots)
        print("SHARDED_OK")
    """)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=900)
    assert proc.returncode == 0, f"OUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    assert "SHARDED_OK" in proc.stdout


def test_run_sweep_input_validation():
    with pytest.raises(ValueError):
        sim_sweep.run_sweep([])
    with pytest.raises(ValueError):
        sim_sweep.run_sweep([Scenario("t/x", n_workers=4)], k_elems=8,
                            rounds=1,
                            h_by_scenario=[np.zeros((1, 3, 8), np.float32)])
