"""First-class Protocol API (ISSUE 5 tentpole).

Contracts under test:
  * the pytree contract: flatten/unflatten round-trip for every constructor
    (``p_miss`` is the ONLY leaf; everything else is static metadata), jit
    with ZERO recompiles across a ``p_miss`` lane axis, vmap over
    lane-stacked Protocol pytrees;
  * accounting parity with the contention core for every legacy string
    mode (via ``Protocol.from_mode``) on both contention backends;
  * ``Protocol.comm_load`` as the one payload-bits source of truth
    (consolidating the ``channel.py`` loaders) and ``Protocol.output_dim``;
  * the ``BitsSchedule`` policy hook: pure-policy unit behaviour, and the
    fused scheduled curve engine — ``FixedBits(b)`` reproduces
    ``run_curves(bits=(b,))`` bit for bit in ONE dispatch, and a
    ``CollisionAdaptiveBits`` schedule runs end-to-end with its depth
    choices confined to the candidate set.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import random_floats, seeds, sweep
from repro.core import channel, fedocs, ocs, vertical
from repro.protocol import (BitsSchedule, CollisionAdaptiveBits, FixedBits,
                            Protocol)
from repro.sim import train_curves as tc

ALL_PROTOCOLS = (
    Protocol.sum(),
    Protocol.max(bits=16, tie_break="first"),
    Protocol.ideal_max(8),
    Protocol.ocs(8, p_miss=0.1),
    Protocol.mean(),
    Protocol.concat(),
)


# ---------------------------------------------------------------------------
# pytree contract
# ---------------------------------------------------------------------------

def test_flatten_unflatten_round_trip():
    for proto in ALL_PROTOCOLS:
        leaves, treedef = jax.tree_util.tree_flatten(proto)
        back = jax.tree_util.tree_unflatten(treedef, leaves)
        for f in ("kind", "bits", "tie_break", "max_rounds", "backend",
                  "n_channels", "payload_bits"):
            assert getattr(back, f) == getattr(proto, f), (proto.kind, f)
        if proto.kind == "ocs":
            # p_miss is the one traced leaf
            assert len(leaves) == 1
            assert np.asarray(back.p_miss) == np.asarray(proto.p_miss)
        else:
            assert leaves == []


def test_p_miss_is_the_only_leaf_and_metadata_is_static():
    lanes = Protocol.ocs(8, p_miss=jnp.asarray([0.0, 0.1, 0.3], jnp.float32))
    leaves = jax.tree.leaves(lanes)
    assert len(leaves) == 1 and leaves[0].shape == (3,)
    # static fields survive tree_map untouched
    mapped = jax.tree.map(lambda x: x * 0, lanes)
    assert mapped.bits == 8 and mapped.backend == "scan"
    assert np.all(np.asarray(mapped.p_miss) == 0)


def test_jit_zero_recompiles_across_p_miss_lane_axis():
    h = jnp.asarray(random_floats(0, (4, 8, 8), specials=False))
    key = jax.random.PRNGKey(0)
    traces = []

    @jax.jit
    def f(proto, x, k):
        traces.append(1)
        pooled, acct = proto.aggregate(x, k)
        return pooled, acct.collisions

    base = Protocol.ocs(8)
    outs = [np.asarray(f(base.with_p_miss(jnp.float32(p)), h, key)[0])
            for p in (0.0, 0.05, 0.3, 0.9)]
    assert len(traces) == 1
    # the p=0 lane of the SAME compiled function pins to the ideal pool
    assert np.array_equal(outs[0],
                          np.asarray(fedocs.maxpool_quantized(h, 8, "first")))
    # a static-field change (backend) IS a new program
    f(dataclasses.replace(base, backend="pallas",
                          p_miss=jnp.float32(0.1)), h, key)
    assert len(traces) == 2


def test_vmap_over_lane_stacked_protocols():
    h = jnp.asarray(random_floats(1, (4, 6, 5), specials=False))
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    lanes = Protocol.ocs(8, p_miss=jnp.asarray([0.0, 0.1, 0.4], jnp.float32))
    pooled, acct = jax.vmap(lambda pr, k: pr.aggregate(h, k))(lanes, keys)
    assert pooled.shape == (3, 6, 5)
    assert acct.collisions.shape == (3,)
    # lane 0 (p=0) == ideal quantized pool, inside the same vmapped program
    assert np.array_equal(np.asarray(pooled[0]),
                          np.asarray(fedocs.maxpool_quantized(h, 8, "first")))


def test_protocol_validation():
    with pytest.raises(ValueError):
        Protocol(kind="median")
    with pytest.raises(ValueError):
        Protocol.ideal_max(0)
    with pytest.raises(ValueError):
        Protocol.ocs(8, backend="triton")
    with pytest.raises(ValueError):
        Protocol.ocs(8, max_rounds=0)
    with pytest.raises(ValueError):
        Protocol.mean(n_channels=0)
    with pytest.raises(ValueError):
        Protocol.from_mode("median")
    with pytest.raises(ValueError):      # rng is mandatory for ocs
        Protocol.ocs(8, p_miss=0.1).aggregate(jnp.zeros((2, 4)))
    with pytest.raises(ValueError):      # p_miss must be bound
        Protocol.ocs(8).aggregate(jnp.zeros((2, 4)), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# accounting parity with the contention core
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ocs.NOISY_BACKENDS)
def test_ocs_accounting_matches_contention_core(backend):
    """Protocol.aggregate's accounting == the NoisyOCSResult counters of
    the very contention core run it executes (both backends)."""
    h = jnp.asarray(random_floats(3, (4, 9, 3), specials=False))
    key = jax.random.PRNGKey(7)
    p = jnp.float32(0.3)
    proto = Protocol.ocs(8, p_miss=p, backend=backend)
    pooled, acct = proto.aggregate(h, key)

    flat = h.reshape(4, -1)
    id_bits = ocs.host_id_bits(4)
    res = ocs.ocs_maxpool_noisy_core(
        flat, jnp.ones((4,), bool), id_bits, key, p, bits=8,
        max_id_bits=id_bits, max_rounds=3, backend=backend)
    assert int(acct.rounds) == int(res.rounds)
    assert int(acct.collisions) == int(res.collisions)
    assert int(acct.contention_slots) == int(res.contention_slots)
    assert float(acct.correct_frac) == pytest.approx(
        float(jnp.mean(res.correct.astype(jnp.float32))))
    # and the pooled value equals the non-accounting aggregation law
    assert np.array_equal(
        np.asarray(pooled),
        np.asarray(fedocs.maxpool_noisy(h, key, p, 8, 3, backend)))


def test_ocs_backends_bitwise_interchangeable_through_protocol():
    h = jnp.asarray(random_floats(5, (4, 8, 4), specials=False))
    key = jax.random.PRNGKey(2)
    outs = {}
    for backend in ocs.NOISY_BACKENDS:
        proto = Protocol.ocs(8, p_miss=jnp.float32(0.2), backend=backend)
        pooled, acct = proto.aggregate(h, key)
        grad = jax.grad(lambda x: jnp.sum(proto.aggregate(x, key)[0]))(h)
        outs[backend] = (np.asarray(pooled), np.asarray(grad),
                         jax.tree.map(np.asarray, acct))
    a, b = outs["scan"], outs["pallas"]
    assert np.array_equal(a[0], b[0])
    assert np.array_equal(a[1], b[1])
    for x, y in zip(jax.tree.leaves(a[2]), jax.tree.leaves(b[2])):
        assert np.array_equal(x, y)


def test_accounting_zero_for_ideal_kinds():
    h = jnp.asarray(random_floats(0, (3, 5), specials=False))
    for proto in (Protocol.sum(), Protocol.max(), Protocol.ideal_max(8),
                  Protocol.mean(), Protocol.concat()):
        _, acct = proto.aggregate(h)
        assert int(acct.rounds) == 0 and int(acct.collisions) == 0
        assert int(acct.contention_slots) == 0
        assert float(acct.correct_frac) == 1.0


# ---------------------------------------------------------------------------
# comm_load consolidation + output_dim
# ---------------------------------------------------------------------------

def test_comm_load_payload_bits_single_source_of_truth():
    # quantized kinds: the winner transmits its D-bit code
    for bits in (8, 16):
        got = Protocol.ideal_max(bits).comm_load(16, 64)
        ref = channel.ocs_load(
            16, 64, bits=bits, cfg=channel.ChannelConfig(payload_bits=bits))
        assert got == ref
        assert Protocol.ocs(bits, p_miss=0.0).comm_load(16, 64) == ref
    # plain max: D bits drive contention only, payload is a full float
    assert Protocol.max(bits=16).comm_load(16, 64) == channel.ocs_load(
        16, 64, bits=16)
    # explicit override wins (the sweep's §IV float-payload convention)
    assert Protocol.ocs(8, p_miss=0.0, payload_bits=32).comm_load(
        16, 64) == channel.ocs_load(
            16, 64, bits=8, cfg=channel.ChannelConfig(payload_bits=32))
    # baselines
    assert Protocol.mean().comm_load(16, 64) == channel.mean_load(16, 64)
    assert Protocol.sum().comm_load(16, 64) == channel.mean_load(16, 64)
    assert Protocol.concat().comm_load(16, 64) == channel.concat_load(16, 64)
    # n_channels rides the protocol into the latency divider
    ofdma = Protocol.ideal_max(8, n_channels=4).comm_load(16, 64)
    assert ofdma.latency_slots == channel.ocs_load(
        16, 64, bits=8,
        cfg=channel.ChannelConfig(payload_bits=8, n_channels=4)).latency_slots


def test_vertical_comm_load_dispatches_off_protocol():
    base = vertical.VerticalConfig(
        n_workers=4, input_dim=32, encoder_dims=(16,), embed_dim=8,
        head_dims=(16,), output_dim=10, task="classification")
    for agg, ref in (
            ("max", channel.ocs_load(4, 8, bits=16)),
            ("max_q8", channel.ocs_load(
                4, 8, bits=8, cfg=channel.ChannelConfig(payload_bits=8))),
            ("mean", channel.mean_load(4, 8)),
            ("concat", channel.concat_load(4, 8)),
            (Protocol.ocs(8, p_miss=0.0), channel.ocs_load(
                4, 8, bits=8, cfg=channel.ChannelConfig(payload_bits=8))),
    ):
        cfg = dataclasses.replace(base, aggregation=agg)
        assert vertical.comm_load(cfg) == ref, agg


def test_scenario_protocol_round_trip():
    from repro.sim.scenarios import Scenario
    s = Scenario("t/het", n_workers=4, bits=8, p_miss=(0.0, 0.1, 0.1, 0.3),
                 n_channels=2)
    proto = s.protocol(max_rounds=5, backend="scan")
    assert proto.kind == "ocs" and proto.bits == 8
    assert proto.max_rounds == 5 and proto.n_channels == 2
    assert np.array_equal(np.asarray(proto.p_miss),
                          np.asarray(s.p_miss_per_worker(), np.float32))
    # sweep cells keep the paper's float-payload accounting
    assert proto.resolved_payload_bits() == 32
    assert proto.comm_load(4, 64) == channel.ocs_load(
        4, 64, bits=8, cfg=channel.ChannelConfig(n_channels=2))


def test_output_dim():
    assert Protocol.concat().output_dim(4, 8) == 32
    assert Protocol.max().output_dim(4, 8) == 8
    assert Protocol.ocs(8).output_dim(4, 8) == 8


# ---------------------------------------------------------------------------
# BitsSchedule policies
# ---------------------------------------------------------------------------

def test_fixed_bits_policy_is_constant():
    s = FixedBits(8)
    assert s.candidates == (8,)
    st = s.init_state()
    for _ in range(3):
        st, idx = s.update(st, {"collision_frac": jnp.float32(0.9)})
        assert int(idx) == 0


def test_collision_adaptive_policy_escalates_and_deescalates():
    s = CollisionAdaptiveBits((8, 12, 16), escalate=0.2, deescalate=0.05,
                              decay=0.0)     # decay 0: EMA == last reading
    st = s.init_state()
    st, idx = s.update(st, {"collision_frac": jnp.float32(0.5)})
    assert int(idx) == 1                     # hot channel: escalate
    st, idx = s.update(st, {"collision_frac": jnp.float32(0.5)})
    assert int(idx) == 2
    st, idx = s.update(st, {"collision_frac": jnp.float32(0.5)})
    assert int(idx) == 2                     # clamped at the deepest code
    st, idx = s.update(st, {"collision_frac": jnp.float32(0.0)})
    assert int(idx) == 1                     # quiet channel: back off
    st, idx = s.update(st, {"collision_frac": jnp.float32(0.1)})
    assert int(idx) == 1                     # hysteresis band: hold


def test_schedule_validation():
    with pytest.raises(ValueError):
        BitsSchedule(candidates=())
    with pytest.raises(ValueError):
        BitsSchedule(candidates=(8,), init_index=1)
    with pytest.raises(ValueError):
        CollisionAdaptiveBits((8, 64))
    with pytest.raises(ValueError):
        CollisionAdaptiveBits((8, 16), escalate=0.1, deescalate=0.2)
    with pytest.raises(ValueError):
        CollisionAdaptiveBits((8, 16), decay=1.0)


# ---------------------------------------------------------------------------
# the scheduled fused engine
# ---------------------------------------------------------------------------

SCHED_TINY = tc.CurveConfig(bits=(8,), p_miss=(0.0, 0.3), steps=8, batch=16,
                            n_train=128, n_val=64, hw=8, encoder_dims=(8,),
                            embed_dim=8, head_dims=(8,), log_every=4)


def test_fixed_schedule_reproduces_run_curves_bit_for_bit():
    """The scheduled engine is a strict generalization: FixedBits(8) trains
    the exact run_curves(bits=(8,)) noisy-lane trajectory in ONE dispatch."""
    plain = tc.run_curves(SCHED_TINY)
    tc.reset_trace_counts()
    tc.reset_dispatch_counts()
    sched = tc.run_scheduled_curves(SCHED_TINY, FixedBits(8))
    assert tc.trace_counts()["sched"] == 1
    assert tc.dispatch_counts() == {"fused": 0, "sched": 1, "fused_dp": 0,
                                    "fused_faults": 0}
    assert np.array_equal(sched.acc, plain.acc[0])
    assert np.array_equal(sched.nll, plain.nll[0])
    assert np.array_equal(sched.loss_history, plain.loss_history[0])
    assert np.array_equal(sched.bits_per_step, np.full(8, 8))
    for x, y in zip(jax.tree.leaves(sched.params),
                    jax.tree.leaves(plain.noisy_params[0])):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_collision_adaptive_schedule_end_to_end_one_dispatch():
    """Acceptance: CollisionAdaptiveBits runs inside the fused scan engine,
    ONE dispatch for the whole run, every chosen depth a candidate."""
    cfg = dataclasses.replace(SCHED_TINY, bits=(8, 16),
                              p_miss=(0.1, (0.0, 0.1, 0.1, 0.3), 0.4))
    schedule = CollisionAdaptiveBits((8, 16), escalate=0.01, deescalate=0.0,
                                     decay=0.0)
    tc.reset_dispatch_counts()
    out = tc.run_scheduled_curves(cfg, schedule)
    assert tc.dispatch_counts()["sched"] == 1
    assert out.bits_per_step.shape == (cfg.steps,)
    assert set(np.unique(out.bits_per_step)) <= {8, 16}
    assert out.bits_per_step[0] == 8          # starts at the init candidate
    # lossy lanes collide, so the hair-trigger policy must escalate
    assert (out.bits_per_step == 16).any()
    assert np.isfinite(out.acc).all() and np.isfinite(out.nll).all()
    assert out.acc.shape == (3,)
    assert np.isfinite(out.collision_frac).all()
    assert out.loss_history.shape == (len(cfg.logged_steps()), 3)


def test_scheduled_run_is_deterministic():
    s = CollisionAdaptiveBits((8, 16), escalate=0.05, decay=0.5)
    a = tc.run_scheduled_curves(SCHED_TINY, s)
    b = tc.run_scheduled_curves(SCHED_TINY, s)
    assert np.array_equal(a.acc, b.acc)
    assert np.array_equal(a.bits_per_step, b.bits_per_step)
