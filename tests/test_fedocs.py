"""In-model aggregation laws: values, gradients, tie handling."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from proptest import random_floats, seeds, sweep
from repro.core import fedocs, quantize as qz


def test_aggregate_modes_shapes():
    h = jnp.asarray(random_floats(0, (4, 3, 8)))
    assert fedocs.aggregate(h, "max").shape == (3, 8)
    assert fedocs.aggregate(h, "mean").shape == (3, 8)
    assert fedocs.aggregate(h, "sum").shape == (3, 8)
    assert fedocs.aggregate(h, "concat").shape == (3, 32)
    assert fedocs.output_dim("concat", 4, 8) == 32
    assert fedocs.output_dim("max", 4, 8) == 8


def test_maxpool_matches_jnp():
    def prop(seed):
        h = jnp.asarray(random_floats(seed, (5, 7, 11)))
        assert np.allclose(np.asarray(fedocs.maxpool(h, "all")),
                           np.asarray(jnp.max(h, axis=0)))
    sweep(prop, list(seeds(8)), "seed")


def test_winner_routed_gradient_unique_max():
    """Paper Eq. 6: gradient goes only to the argmax worker."""
    h = jnp.asarray(random_floats(3, (6, 4, 4), specials=False))
    g = jax.grad(lambda x: jnp.sum(fedocs.maxpool(x, "all") * 2.0))(h)
    g = np.asarray(g)
    # exactly one worker per element gets gradient 2.0
    assert np.allclose(g.sum(axis=0), 2.0)
    assert ((g != 0).sum(axis=0) == 1).all()


def test_tie_break_first_single_winner():
    base = jnp.asarray(random_floats(0, (1, 8), specials=False))
    h = jnp.concatenate([base, base, base])
    g = jax.grad(lambda x: jnp.sum(fedocs.maxpool(x, "first")))(h)
    g = np.asarray(g)
    assert np.allclose(g[0], 1.0) and np.allclose(g[1:], 0.0)


def test_quantized_maxpool_winner_exact():
    """AR(max) on codes must select a true argmax at D-bit resolution."""
    def prop(seed):
        h = jnp.asarray(random_floats(seed, (8, 32), specials=False))
        for bits in (8, 16):
            v = fedocs.maxpool_quantized(h, bits, "all")
            expect = qz.dequantize(
                jnp.max(qz.quantize(h, bits), axis=0), bits, h.dtype)
            assert np.array_equal(np.asarray(v), np.asarray(expect))
            # value error bounded by one quantization step
            true_max = np.asarray(jnp.max(h, axis=0))
            got = np.asarray(v)
            assert np.all(got <= true_max + 1e-6)
    sweep(prop, list(seeds(8)), "seed")


def test_quantized_maxpool_gradient_routes_to_code_winners():
    h = jnp.asarray(random_floats(1, (4, 16), specials=False))
    g = jax.grad(lambda x: jnp.sum(fedocs.maxpool_quantized(x, 8, "first")))(h)
    g = np.asarray(g)
    assert np.allclose(g.sum(axis=0), 1.0)
    assert ((g != 0).sum(axis=0) == 1).all()


def test_mean_and_sum_grads():
    h = jnp.asarray(random_floats(2, (4, 8)))
    gm = np.asarray(jax.grad(lambda x: jnp.sum(fedocs.meanpool(x)))(h))
    assert np.allclose(gm, 0.25)
    gs = np.asarray(jax.grad(lambda x: jnp.sum(fedocs.aggregate(x, "sum")))(h))
    assert np.allclose(gs, 1.0)


def test_unknown_mode_raises():
    with pytest.raises(ValueError):
        fedocs.aggregate(jnp.zeros((2, 2)), "median")
