"""In-model aggregation laws: values, gradients, tie handling.

The pooling primitives (``maxpool``/``maxpool_quantized``/``maxpool_noisy``)
are first-class and tested directly; the dispatching surface over them is
``repro.protocol.Protocol`` (the string-mode shims finished their
deprecation window and are gone — ``tests/test_protocol.py`` covers the
Protocol entry points).
"""

import jax
import jax.numpy as jnp
import numpy as np

from proptest import random_floats, seeds, sweep
from repro.core import fedocs, quantize as qz
from repro.protocol import Protocol


def test_maxpool_matches_jnp():
    def prop(seed):
        h = jnp.asarray(random_floats(seed, (5, 7, 11)))
        assert np.allclose(np.asarray(fedocs.maxpool(h, "all")),
                           np.asarray(jnp.max(h, axis=0)))
    sweep(prop, list(seeds(8)), "seed")


def test_winner_routed_gradient_unique_max():
    """Paper Eq. 6: gradient goes only to the argmax worker."""
    h = jnp.asarray(random_floats(3, (6, 4, 4), specials=False))
    g = jax.grad(lambda x: jnp.sum(fedocs.maxpool(x, "all") * 2.0))(h)
    g = np.asarray(g)
    # exactly one worker per element gets gradient 2.0
    assert np.allclose(g.sum(axis=0), 2.0)
    assert ((g != 0).sum(axis=0) == 1).all()


def test_tie_break_first_single_winner():
    base = jnp.asarray(random_floats(0, (1, 8), specials=False))
    h = jnp.concatenate([base, base, base])
    g = jax.grad(lambda x: jnp.sum(fedocs.maxpool(x, "first")))(h)
    g = np.asarray(g)
    assert np.allclose(g[0], 1.0) and np.allclose(g[1:], 0.0)


def test_quantized_maxpool_winner_exact():
    """AR(max) on codes must select a true argmax at D-bit resolution."""
    def prop(seed):
        h = jnp.asarray(random_floats(seed, (8, 32), specials=False))
        for bits in (8, 16):
            v = fedocs.maxpool_quantized(h, bits, "all")
            expect = qz.dequantize(
                jnp.max(qz.quantize(h, bits), axis=0), bits, h.dtype)
            assert np.array_equal(np.asarray(v), np.asarray(expect))
            # value error bounded by one quantization step
            true_max = np.asarray(jnp.max(h, axis=0))
            got = np.asarray(v)
            assert np.all(got <= true_max + 1e-6)
    sweep(prop, list(seeds(8)), "seed")


def test_quantized_maxpool_gradient_routes_to_code_winners():
    h = jnp.asarray(random_floats(1, (4, 16), specials=False))
    g = jax.grad(lambda x: jnp.sum(fedocs.maxpool_quantized(x, 8, "first")))(h)
    g = np.asarray(g)
    assert np.allclose(g.sum(axis=0), 1.0)
    assert ((g != 0).sum(axis=0) == 1).all()


def test_maxpool_noisy_zero_miss_pins_to_quantized():
    """ISSUE property: max_noisy at p_miss=0 == maxpool_quantized(tie_break=
    'first') bit for bit — forward AND vjp — for both bit depths."""
    def prop(seed):
        h = jnp.asarray(random_floats(seed, (5, 7, 9), specials=False))
        key = jax.random.PRNGKey(seed)
        g = jnp.asarray(random_floats(seed + 100, (7, 9), specials=False))
        p0 = jnp.float32(0.0)
        for bits in (8, 16):
            out_n, vjp_n = jax.vjp(
                lambda x: fedocs.maxpool_noisy(x, key, p0, bits), h)
            out_q, vjp_q = jax.vjp(
                lambda x: fedocs.maxpool_quantized(x, bits, "first"), h)
            assert np.array_equal(np.asarray(out_n), np.asarray(out_q))
            assert np.array_equal(np.asarray(vjp_n(g)[0]),
                                  np.asarray(vjp_q(g)[0]))
    sweep(prop, list(seeds(6)), "seed")


def test_maxpool_noisy_gradient_routes_to_actual_winner():
    """Under misses the cotangent must follow the worker that actually won
    the contention (and transmitted), never the ideal argmax."""
    h = jnp.asarray(random_floats(2, (6, 24), specials=False))
    key = jax.random.PRNGKey(5)
    p = jnp.float32(0.4)
    pooled = fedocs.maxpool_noisy(h, key, p, 8)
    g = jax.grad(lambda x: jnp.sum(fedocs.maxpool_noisy(x, key, p, 8)))(h)
    g = np.asarray(g)
    # exactly one winner per element receives the full cotangent
    assert ((g != 0).sum(axis=0) == 1).all()
    assert np.allclose(g.sum(axis=0), 1.0)
    # the pooled value is the winner's D-bit payload: recompute it from the
    # gradient's winner mask and the quantizer
    win = np.argmax(g != 0, axis=0)
    codes = np.asarray(qz.quantize(h, 8))
    win_code = np.take_along_axis(codes, win[None], axis=0)[0]
    expect = qz.dequantize(jnp.asarray(win_code), 8, h.dtype)
    assert np.array_equal(np.asarray(pooled), np.asarray(expect))
    # and it never exceeds the ideal quantized max (noisy max-pool is a
    # lower bound; the value is always a real observation)
    ideal = np.asarray(fedocs.maxpool_quantized(h, 8, "first"))
    assert np.all(np.asarray(pooled) <= ideal + 1e-6)


def test_maxpool_noisy_traced_p_miss_single_compilation():
    """One jitted computation must serve the whole p_miss axis."""
    traces = []
    h = jnp.asarray(random_floats(0, (4, 8, 8), specials=False))
    key = jax.random.PRNGKey(0)

    @jax.jit
    def f(x, k, p):
        traces.append(1)
        return fedocs.maxpool_noisy(x, k, p, 8)

    outs = [np.asarray(f(h, key, jnp.float32(p)))
            for p in (0.0, 0.05, 0.3, 0.9)]
    assert len(traces) == 1
    # p=0 lane of the SAME compiled function still pins to the ideal pool
    assert np.array_equal(outs[0],
                          np.asarray(fedocs.maxpool_quantized(h, 8, "first")))


def test_mean_and_sum_grads():
    h = jnp.asarray(random_floats(2, (4, 8)))
    gm = np.asarray(jax.grad(lambda x: jnp.sum(fedocs.meanpool(x)))(h))
    assert np.allclose(gm, 0.25)
    gs = np.asarray(jax.grad(
        lambda x: jnp.sum(Protocol.sum().aggregate(x)[0]))(h))
    assert np.allclose(gs, 1.0)

