"""Properties of the Eq.-7 monotone quantizer."""

import jax.numpy as jnp
import numpy as np
import pytest

from proptest import random_floats, seeds, sweep
from repro.core import quantize as qz


def test_monotone_code_total_order():
    def prop(seed):
        x = np.sort(np.unique(random_floats(seed, (512,))))
        c = np.asarray(qz.monotone_code(jnp.asarray(x)))
        assert np.all(np.diff(c.astype(np.int64)) > 0), \
            "code must be strictly increasing in value"
    sweep(prop, list(seeds(10)), "seed")


def test_monotone_roundtrip():
    def prop(seed):
        x = random_floats(seed, (256,))
        c = qz.monotone_code(jnp.asarray(x))
        back = np.asarray(qz.monotone_decode(c, jnp.float32))
        assert np.array_equal(back, x)
    sweep(prop, list(seeds(10)), "seed")


@pytest.mark.parametrize("bits", [4, 8, 12, 16, 24, 32])
def test_quantize_order_preserving(bits):
    # +0.0 canonicalization: the order embedding ranks -0.0 below +0.0
    # (IEEE comparison treats them equal; harmless since both decode to 0).
    x = np.sort(random_floats(3, (1024,)) + 0.0)
    q = np.asarray(qz.quantize(jnp.asarray(x), bits)).astype(np.int64)
    assert np.all(np.diff(q) >= 0), "D-bit codes must be non-decreasing"


@pytest.mark.parametrize("bits", [8, 16])
def test_dequantize_round_toward_negative(bits):
    x = random_floats(7, (512,))
    q = qz.quantize(jnp.asarray(x), bits)
    d = np.asarray(qz.dequantize(q, bits, jnp.float32))
    assert np.all(d <= x + 1e-30)


@pytest.mark.parametrize("bits", [8, 16])
def test_max_commutes_with_quantization(bits):
    """The core soundness fact behind the quantized max collective."""
    def prop(seed):
        h = random_floats(seed, (8, 64))
        codes = qz.quantize(jnp.asarray(h), bits)
        # argmax on codes is a valid argmax on values at D-bit resolution
        code_win = np.asarray(jnp.max(codes, axis=0))
        val_win_code = np.asarray(
            qz.quantize(jnp.asarray(h.max(axis=0)), bits))
        assert np.array_equal(code_win, val_win_code)
    sweep(prop, list(seeds(10)), "seed")


def test_bf16_paths():
    x = jnp.asarray(random_floats(0, (128,)), jnp.bfloat16)
    c = qz.monotone_code(x)
    assert c.dtype == jnp.uint16
    back = qz.monotone_decode(c, jnp.bfloat16)
    assert jnp.array_equal(back, x)


def test_backoff_strictly_decreasing():
    x = np.sort(np.unique(random_floats(1, (256,))))
    g = np.asarray(qz.backoff_code(jnp.asarray(x), 16)).astype(np.int64)
    assert np.all(np.diff(g) <= 0)
