"""CompressedAllReduce: the DP-comms policy object (ISSUE 8 tentpole).

Contracts under test:
  * pytree discipline mirroring ``Protocol``: the policy is all-static
    metadata (no data leaves), frozen, hashable, and survives jit/tree ops;
  * ``reduce`` with no axis is the degenerate 1-rank all-reduce (bitwise
    the ``grad_compression.compress`` path) and with a named axis sums the
    per-rank sparse trees in the fixed gather order;
  * ``DPAccounting`` bills MEASURED kept-element counts that equal the
    analytic per-rank ``payload_bits`` times the rank count — the property
    the fixed exact-k ``topk_mask`` guarantees;
  * constructor validation + the analytic payload helpers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import grad_compression as gc
from repro.optim.compressed_allreduce import CompressedAllReduce, DPAccounting


def _tree(rng, dtype=np.float32):
    return {"w": jnp.asarray(rng.standard_normal((16, 8)), dtype),
            "b": jnp.asarray(rng.standard_normal((8,)), dtype)}


def test_policy_is_static_pytree():
    car = CompressedAllReduce.topk(1 / 8)
    leaves, treedef = jax.tree.flatten(car)
    assert leaves == []                      # all-static: no data leaves
    assert treedef.unflatten([]) == car
    assert hash(car) == hash(CompressedAllReduce.topk(1 / 8))
    # static-arg friendly: closing over it never adds traced operands
    out = jax.jit(lambda g, e: car.reduce(g, e))(
        _tree(np.random.default_rng(0)), car.init_error(
            _tree(np.random.default_rng(0))))
    assert isinstance(out[2], DPAccounting)


def test_validation():
    with pytest.raises(ValueError):
        CompressedAllReduce.topk(0.0)
    with pytest.raises(ValueError):
        CompressedAllReduce.topk(1.5)
    with pytest.raises(ValueError):
        CompressedAllReduce.topk(0.5, value_bits=0)
    with pytest.raises(ValueError):
        CompressedAllReduce.topk(0.5, index_bits=0)
    with pytest.raises(ValueError):
        CompressedAllReduce.topk(0.5).payload_bits({})


def test_analytic_payload_helpers():
    car = CompressedAllReduce.topk(1 / 16)
    tree = {"w": np.zeros((32, 32)), "b": np.zeros((4,))}
    # per-leaf: 64 of 1024 at ceil(log2(1024))=10 index bits, 1 of 4 at 2
    assert car.leaf_payload_bits(1024) == 64 * (32 + 10)
    assert car.leaf_payload_bits(4) == 1 * (32 + 2)
    assert car.payload_bits(tree) == 64 * 42 + 34
    assert car.dense_bits(tree) == 1028 * 32
    assert car.payload_fraction(tree) == (64 * 42 + 34) / (1028 * 32)
    # a fixed index width reproduces the naive 2x value+index encoding
    naive = CompressedAllReduce.topk(1 / 16, index_bits=32)
    assert (naive.payload_bits(tree) / naive.dense_bits(tree)
            == pytest.approx(gc.payload_fraction(tree, 1 / 16)))


def test_single_rank_reduce_matches_compress():
    rng = np.random.default_rng(1)
    car = CompressedAllReduce.topk(1 / 8)
    grads = _tree(rng)
    err = jax.tree.map(lambda g: jnp.asarray(
        rng.standard_normal(g.shape) * 0.1, jnp.float32), grads)
    reduced, new_err, acct = car.reduce(grads, err)
    ref_s, ref_e = gc.compress_tree(grads, err, 1 / 8)
    for a, b in zip(jax.tree.leaves(reduced), jax.tree.leaves(ref_s)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(new_err), jax.tree.leaves(ref_e)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert int(acct.payload_bits) == car.payload_bits(grads)
    assert int(acct.dense_bits) == car.dense_bits(grads)
    kept = sum(gc.topk_count(int(np.prod(x.shape)), 1 / 8)
               for x in jax.tree.leaves(grads))
    assert int(acct.kept_elems) == kept


def test_vmapped_axis_reduce_sums_ranks_in_gather_order():
    """reduce over a named vmap axis == stacking each rank's own sparse
    tree and summing along the rank axis — every rank sees the same total,
    and the accounting is the per-rank bill times the rank count."""
    rng = np.random.default_rng(2)
    car = CompressedAllReduce.topk(1 / 4)
    D = 3
    grads = {"w": jnp.asarray(rng.standard_normal((D, 16, 8)), jnp.float32),
             "b": jnp.asarray(rng.standard_normal((D, 8)), jnp.float32)}
    err = jax.tree.map(jnp.zeros_like, grads)

    reduced, new_err, acct = jax.vmap(
        lambda g, e: car.reduce(g, e, axis_name="d"), axis_name="d")(
            grads, err)

    # reference: per-rank sparse trees (no collective), stacked in rank
    # order and summed with the same jnp.sum the reduce path uses — the
    # fixed-order contract is about the (D, ...) stacking, not about
    # matching numpy's accumulation order
    per_rank = [gc.compress_tree(
        jax.tree.map(lambda x, r=r: x[r], grads),
        jax.tree.map(lambda x, r=r: x[r], err), 1 / 4) for r in range(D)]
    for key in ("w", "b"):
        stacked = jnp.stack([s[key] for s, _e in per_rank], axis=0)
        total = np.asarray(jnp.sum(stacked, axis=0))
        for r in range(D):
            assert np.array_equal(np.asarray(reduced[key][r]), total)
            assert np.array_equal(np.asarray(new_err[key][r]),
                                  np.asarray(per_rank[r][1][key]))
    one_rank = car.payload_bits(jax.tree.map(lambda x: x[0], grads))
    assert np.all(np.asarray(acct.payload_bits) == one_rank * D)
    assert np.all(np.asarray(acct.dense_bits)
                  == car.dense_bits(jax.tree.map(lambda x: x[0], grads)) * D)


def test_reduce_keeps_grad_dtype_and_accumulates_cast_error():
    rng = np.random.default_rng(3)
    car = CompressedAllReduce.topk(1 / 4)
    grads = _tree(rng, jnp.bfloat16)
    err = car.init_error(grads)
    reduced, new_err, _acct = car.reduce(grads, err)
    for g, r, e in zip(jax.tree.leaves(grads), jax.tree.leaves(reduced),
                       jax.tree.leaves(new_err)):
        assert r.dtype == jnp.bfloat16
        assert e.dtype == jnp.float32
        # nothing lost: transmitted + residual == corrected, exactly
        assert np.array_equal(
            np.asarray(r.astype(jnp.float32) + e),
            np.asarray(g.astype(jnp.float32)))


def test_accounting_zeros_and_pytree():
    z = DPAccounting.zeros()
    assert int(z.payload_bits) == int(z.kept_elems) == int(z.dense_bits) == 0
    leaves = jax.tree.leaves(z)
    assert len(leaves) == 3                  # all counters are data leaves
    doubled = jax.tree.map(lambda x: x * 2, z)
    assert isinstance(doubled, DPAccounting)
