"""Fused noisy-contention kernel: parity with the scan protocol core.

Three layers of evidence, all bit-for-bit:

  * kernel-level: ``ops.contend`` vs ``ref.contend`` on identical packed
    operands (the unified parity harness, masked workers included);
  * core-level: ``ocs_maxpool_noisy_core(backend="pallas")`` vs the
    ``lax.scan`` backend on (p_miss x bits x N incl. padded) grids — every
    ``NoisyOCSResult`` field, so winner selection AND the rounds / slots /
    collision accounting agree exactly;
  * model-level: ``fedocs.maxpool_noisy(backend="pallas")`` at ``p_miss=0``
    reduces to ``maxpool_quantized(tie_break="first")`` in forward and vjp,
    and under vmap lanes (the train-curve usage) matches the scan backend.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from kernel_parity import ParityOp, check
from proptest import grid, random_floats, seeds, sweep
from repro.core import fedocs, ocs
from repro.kernels.ocs_contention import ops as O
from repro.kernels.ocs_contention import ref as R


# ---------------------------------------------------------------------------
# kernel-level parity on packed operands (unified harness)
# ---------------------------------------------------------------------------

def _contend_args(case):
    n, k, n_slots = case["n"], case["k"], case["n_slots"]
    rng = np.random.default_rng(case["seed"])
    # arbitrary contention words within the live bit budget
    word = jnp.asarray(
        rng.integers(0, 1 << case["total_bits"], (n, k), dtype=np.uint32))
    n_real = case.get("n_real", n)
    mask = jnp.arange(n) < n_real
    p_keep = ocs.sensing_keep_prob(case["p_miss"], jnp.float32)
    heard = O.draw_heard_packed(
        jax.random.PRNGKey(case["seed"]), p_keep, n, k,
        n_slots=n_slots, max_rounds=case["max_rounds"])
    return word, heard, mask, jnp.int32(case["total_bits"])


def _check_contend(cases):
    """Drive the unified harness; contend's static kwargs come per case."""
    def one(case):
        kw = dict(n_slots=case["n_slots"], max_rounds=case["max_rounds"])
        if "block_k" in case:
            kkw = dict(kw, block_k=case["block_k"])
        else:
            kkw = kw
        check(ParityOp(
            name="ocs_contention.contend",
            make=_contend_args,
            kernel=lambda *args, _kw=kkw: O.contend(*args, **_kw),
            reference=lambda *args, _kw=kw: R.contend(*args, **_kw),
            cases=[case],
        ))
    sweep(one, list(cases), label="contend")


def test_contend_parity_fast():
    _check_contend(grid(n=[4], k=[96], n_slots=[14], total_bits=[14],
                        max_rounds=[3], p_miss=[0.0, 0.2], seed=[0, 1]))


def test_contend_parity_masked_and_padded_slots():
    # padded workers (mask) + padded scan bound (total_bits < n_slots) +
    # a block size that forces multiple tiles (cross-tile accounting)
    _check_contend(grid(n=[8], n_real=[5], k=[128], n_slots=[16],
                        total_bits=[14], max_rounds=[2], p_miss=[0.15],
                        seed=[0, 3], block_k=[32]))


@pytest.mark.slow
def test_contend_parity_grid():
    _check_contend(grid(n=[2, 8, 16], k=[64, 160], n_slots=[10, 20],
                        total_bits=[10], max_rounds=[1, 3],
                        p_miss=[0.0, 0.05, 0.5, 0.97], seed=[0]))


# ---------------------------------------------------------------------------
# core-level parity: every NoisyOCSResult field, scan vs pallas
# ---------------------------------------------------------------------------

def _core_pair(h, mask, id_bits, key, p_miss, **kw):
    a = ocs.ocs_maxpool_noisy_core(h, mask, id_bits, key, p_miss,
                                   backend="scan", **kw)
    b = ocs.ocs_maxpool_noisy_core(h, mask, id_bits, key, p_miss,
                                   backend="pallas", **kw)
    return a, b


def _assert_results_equal(a, b, ctx=""):
    for f in dataclasses.fields(a):
        x, y = np.asarray(getattr(a, f.name)), np.asarray(getattr(b, f.name))
        assert x.dtype == y.dtype, f"{ctx}{f.name}: {x.dtype} != {y.dtype}"
        assert np.array_equal(x, y), f"{ctx}{f.name}: scan {x} != pallas {y}"


def test_core_backend_parity_fast():
    def prop(case):
        n, bits, p = case["n"], case["bits"], case["p_miss"]
        h = jnp.asarray(random_floats(case["seed"], (n, 48), specials=False))
        key = jax.random.PRNGKey(case["seed"])
        id_bits = ocs.host_id_bits(n)
        a, b = _core_pair(h, jnp.ones((n,), bool), id_bits, key, p,
                          bits=bits, max_id_bits=id_bits, max_rounds=3)
        _assert_results_equal(a, b, f"{case}: ")
    # p_miss=0 core coverage lives in the reduction tests below; the fast
    # tier exercises the re-contention path at one miss rate per shape
    sweep(prop, list(grid(n=[4, 9], bits=[8, 16], p_miss=[0.1],
                          seed=[0])), label="core")


def test_core_backend_parity_padded_workers():
    """Masked/padded rows + oversized scan bound: identical accounting."""
    h = jnp.asarray(random_floats(1, (6, 40), specials=False))
    mask = jnp.arange(16) < 6
    h_pad = jnp.zeros((16, 40), jnp.float32).at[:6].set(h).at[6:].set(1e9)
    id_bits = ocs.host_id_bits(6)
    a, b = _core_pair(h_pad, mask, id_bits, jax.random.PRNGKey(2), 0.12,
                      bits=12, max_id_bits=ocs.host_id_bits(16),
                      max_rounds=3)
    _assert_results_equal(a, b)
    assert bool(np.all(np.asarray(b.winner) < 6))


@pytest.mark.slow
def test_core_backend_parity_grid():
    """Full (p_miss x bits x N incl. padded) grid, scalar AND per-worker."""
    def prop(case):
        n, bits, p, mr = case["n"], case["bits"], case["p_miss"], case["mr"]
        if case["hetero"]:
            rng = np.random.default_rng(case["seed"] + 17)
            p = jnp.asarray(rng.uniform(0.0, max(p, 1e-6), n), jnp.float32)
        h = jnp.asarray(random_floats(case["seed"], (n, 64), specials=False))
        key = jax.random.PRNGKey(case["seed"])
        id_bits = ocs.host_id_bits(n)
        n_pad = case["n_pad"] or n
        mask = jnp.arange(n_pad) < n
        h_use = jnp.zeros((n_pad, 64), jnp.float32).at[:n].set(h)
        if case["hetero"]:
            p = jnp.zeros((n_pad,), jnp.float32).at[:n].set(p)
        a, b = _core_pair(h_use, mask, id_bits, key, p, bits=bits,
                          max_id_bits=ocs.host_id_bits(n_pad),
                          max_rounds=mr)
        _assert_results_equal(a, b, f"{case}: ")
    sweep(prop, list(grid(n=[3, 8], n_pad=[None, 12], bits=[8, 16],
                          p_miss=[0.0, 0.05, 0.4, 0.95], mr=[3],
                          hetero=[False, True], seed=[0])),
          label="core-grid")


def test_core_rounds_and_slots_hand_computed_via_pallas():
    """The p_miss~1 accounting identity holds through the kernel too:
    rounds == max_rounds, slots == max_rounds * total_bits * K,
    collisions == max_rounds * K, winner == worker 0."""
    n, k, bits, max_rounds = 5, 7, 10, 3
    h = jnp.asarray(random_floats(11, (n, k), specials=False))
    res = ocs.ocs_maxpool_noisy(h, jax.random.PRNGKey(0), bits=bits,
                                p_miss=1.0 - 1e-12, max_rounds=max_rounds,
                                backend="pallas")
    total_bits = bits + ocs.host_id_bits(n)
    assert int(res.rounds) == max_rounds
    assert int(res.contention_slots) == max_rounds * total_bits * k
    assert int(res.collisions) == max_rounds * k
    assert np.all(np.asarray(res.winner) == 0)


# ---------------------------------------------------------------------------
# model-level: maxpool_noisy(backend="pallas")
# ---------------------------------------------------------------------------

def test_maxpool_noisy_pallas_zero_miss_pins_to_quantized():
    """p_miss=0 reduction to maxpool_quantized(tie_break='first'), forward
    AND vjp, through the Pallas backend."""
    def prop(seed):
        h = jnp.asarray(random_floats(seed, (5, 7, 9), specials=False))
        key = jax.random.PRNGKey(seed)
        g = jnp.asarray(random_floats(seed + 100, (7, 9), specials=False))
        p0 = jnp.float32(0.0)
        for bits in (8, 16):
            out_n, vjp_n = jax.vjp(
                lambda x: fedocs.maxpool_noisy(x, key, p0, bits, 3,
                                               "pallas"), h)
            out_q, vjp_q = jax.vjp(
                lambda x: fedocs.maxpool_quantized(x, bits, "first"), h)
            assert np.array_equal(np.asarray(out_n), np.asarray(out_q))
            assert np.array_equal(np.asarray(vjp_n(g)[0]),
                                  np.asarray(vjp_q(g)[0]))
    sweep(prop, list(seeds(3)), "seed")


def test_maxpool_noisy_backends_agree_forward_and_vjp():
    """scan and pallas backends: same pooled value, same routed cotangent,
    at a miss rate that exercises re-contention."""
    h = jnp.asarray(random_floats(4, (6, 8, 16), specials=False))
    key = jax.random.PRNGKey(7)
    p = jnp.float32(0.3)
    g = jnp.asarray(random_floats(5, (8, 16), specials=False))
    outs, grads = [], []
    for backend in ("scan", "pallas"):
        out, vjp = jax.vjp(
            lambda x: fedocs.maxpool_noisy(x, key, p, 8, 3, backend), h)
        outs.append(np.asarray(out))
        grads.append(np.asarray(vjp(g)[0]))
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(grads[0], grads[1])


def test_maxpool_noisy_pallas_under_vmap_lanes():
    """The train-curve usage: one jitted step, lanes of traced (rng,
    p_miss), pallas backend — equal to the scan backend lane for lane."""
    h = jnp.asarray(random_floats(0, (4, 6, 8), specials=False))
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    ps = jnp.asarray([0.0, 0.1, 0.5], jnp.float32)
    traces = []

    def lane_fn(backend):
        @jax.jit
        def f(keys, ps):
            traces.append(backend)
            return jax.vmap(
                lambda k, p: fedocs.maxpool_noisy(h, k, p, 8, 3, backend)
            )(keys, ps)
        return f

    out_s = lane_fn("scan")(keys, ps)
    out_p = lane_fn("pallas")(keys, ps)
    assert np.array_equal(np.asarray(out_s), np.asarray(out_p))
    assert len(traces) == 2          # one compilation per backend


def test_core_rejects_unknown_backend():
    h = jnp.zeros((2, 4), jnp.float32)
    with pytest.raises(ValueError):
        ocs.ocs_maxpool_noisy(h, jax.random.PRNGKey(0), backend="triton")
