"""Noisy-contention backend benchmark: lax.scan vs the fused Pallas kernel.

Times ``Protocol.ocs(...).aggregate`` — the channel-in-the-loop aggregation
that dominates the curve runner's step time — on the curve-runner shape (N
workers x the flattened batch*embed element axis), with the miss-probability
axis as vmap lanes of one jitted dispatch per backend (each lane carries its
own traced ``Protocol`` pytree), exactly as ``repro.sim.train_curves``
drives it.  ``Protocol.backend`` is the only static difference between the
two timed programs.  Mirrors ``bench_curves``'s smoke contract: the run
self-checks

  * one compilation per (bits, backend) serving every traced p_miss lane,
  * scan-vs-pallas bit-for-bit parity — forward, vjp AND the
    ``ProtocolAccounting`` counters (rounds/collisions/slots/correctness)
    the new entry point surfaces — on the bench shape,
  * the ``p_miss=0`` lane pinning to ideal ``maxpool_quantized(bits,
    'first')`` through BOTH backends (trajectory unchanged under the
    Protocol API),

and reports per-backend step times plus the pallas/scan speedup (the README
kernels table quotes these numbers).  ``json_path``/a positional JSON
argument additionally persists the numbers (``benchmarks/run.py`` writes the
canonical ``BENCH_contention.json`` at the repo root for trajectory
tracking).

  PYTHONPATH=src python -m benchmarks.bench_contention           # full shape
  PYTHONPATH=src python -m benchmarks.bench_contention --smoke   # CI tier
"""

from __future__ import annotations

import json
import sys
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fedocs
from repro.protocol import Protocol

BACKENDS = ("scan", "pallas")


def _time(fn, *args, iters: int) -> float:
    jax.block_until_ready(fn(*args))             # compile outside the clock
    t0 = time.time()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.time() - t0) / iters * 1e6


def run(smoke: bool = False, json_path: Optional[str] = None) -> List[str]:
    # curve-runner shapes: the protocol aggregate sees (N, batch, embed_dim)
    # and flattens to (N, batch*embed); bench_curves' smoke/full configs
    if smoke:
        n, batch, embed, iters = 4, 32, 16, 5
        p_lanes = (0.0, 0.05, 0.2)
    else:
        n, batch, embed, iters = 4, 64, 32, 20
        p_lanes = (0.0, 0.01, 0.02, 0.05, 0.1)
    h = jnp.asarray(np.random.default_rng(0)
                    .standard_normal((n, batch, embed)).astype(np.float32))
    keys = jax.random.split(jax.random.PRNGKey(0), len(p_lanes))
    ps = jnp.asarray(p_lanes, jnp.float32)
    g = jnp.ones((batch, embed), jnp.float32)

    rows: List[str] = []
    compiles = {b: 0 for b in BACKENDS}
    bench = {"bench": "contention", "smoke": smoke,
             "shape": {"n": n, "elems": batch * embed,
                       "lanes": len(p_lanes), "iters": iters},
             "fwd_vjp_us": {}, "pallas_over_scan": {}}
    for bits in (8, 16):
        outs, grads, accts, times = {}, {}, {}, {}
        for backend in BACKENDS:
            proto = Protocol.ocs(bits=bits, backend=backend)

            def lanes_fn(h, keys, ps, _b=backend, _proto=proto):
                compiles[_b] += 1

                def lane(k, p):
                    lane_proto = _proto.with_p_miss(p)
                    (out, acct), vjp = jax.vjp(
                        lambda x: lane_proto.aggregate(x, k), h,
                        has_aux=False)
                    cot = (g, jax.tree.map(
                        lambda a: (np.zeros(a.shape, jax.dtypes.float0)
                                   if a.dtype.kind in "iu"
                                   else jnp.zeros_like(a)), acct))
                    return out, acct, vjp(cot)[0]   # backward in the timing
                return jax.vmap(lane)(keys, ps)
            lanes = jax.jit(lanes_fn)
            times[backend] = _time(lanes, h, keys, ps, iters=iters)
            out_l, acct_l, grad_l = lanes(h, keys, ps)
            outs[backend] = np.asarray(out_l)
            grads[backend] = np.asarray(grad_l)
            accts[backend] = jax.tree.map(np.asarray, acct_l)

        # self-check 1: scan and pallas agree bit for bit — forward, vjp
        # AND the protocol accounting (the routed cotangent is nonzero by
        # construction — one winner per element receives g — so an all-zero
        # grad means the check went vacuous, not that parity holds)
        if not np.any(grads["scan"]):
            raise RuntimeError(f"bits={bits}: vjp self-check is vacuous")
        if not np.array_equal(outs["scan"], outs["pallas"]):
            raise RuntimeError(f"bits={bits}: backend forward mismatch")
        if not np.array_equal(grads["scan"], grads["pallas"]):
            raise RuntimeError(f"bits={bits}: backend vjp mismatch")
        for x, y in zip(jax.tree.leaves(accts["scan"]),
                        jax.tree.leaves(accts["pallas"])):
            if not np.array_equal(x, y):
                raise RuntimeError(
                    f"bits={bits}: backend accounting mismatch")
        # self-check 2: the p_miss=0 lane pins to the ideal quantized pool
        ideal = np.asarray(fedocs.maxpool_quantized(h, bits, "first"))
        for backend in BACKENDS:
            if not np.array_equal(outs[backend][0], ideal):
                raise RuntimeError(
                    f"bits={bits}/{backend}: p_miss=0 lane != ideal "
                    f"max_q{bits}")

        speedup = times["scan"] / max(times["pallas"], 1e-9)
        for backend in BACKENDS:
            rows.append(
                f"contention/{backend}_b{bits},{times[backend]:.0f},"
                f"N={n};elems={batch * embed};lanes={len(p_lanes)};"
                f"fwd+vjp=1")
            bench["fwd_vjp_us"][f"{backend}_b{bits}"] = round(
                times[backend], 1)
        bench["pallas_over_scan"][f"b{bits}"] = round(speedup, 2)
        rows.append(
            f"contention/speedup_b{bits},0,pallas_over_scan="
            f"{speedup:.2f}x")

    # self-check 3: one trace per (bits, backend) served every p_miss lane
    # (+1 per timing warm-up is impossible: jit caches; the count is exact)
    for backend, count in compiles.items():
        if count != 2:
            raise RuntimeError(
                f"{backend} backend recompiled per lane: {count} traces "
                "for 2 bit depths — traced-(rng, Protocol) regression")
    rows.append(
        "contention/meta,0,"
        f"compiles_scan={compiles['scan']};"
        f"compiles_pallas={compiles['pallas']};"
        "p0_matches_ideal=1;backends_bitwise_equal=1;"
        "accounting_bitwise_equal=1")
    if json_path:
        bench["compiles"] = dict(compiles)
        bench["p0_matches_ideal"] = True
        bench["backends_bitwise_equal"] = True
        bench["accounting_bitwise_equal"] = True
        with open(json_path, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
            f.write("\n")
    return rows


if __name__ == "__main__":
    argv = [a for a in sys.argv[1:] if a != "--smoke"]
    for r in run(smoke="--smoke" in sys.argv,
                 json_path=argv[0] if argv else None):
        print(r)
