"""The committed fault-robustness table: accuracy + staleness vs burst length.

Regenerates ``FAULT_curves.json`` (repo root): a Gilbert–Elliott burst-length
sweep with worker dropout, trained through the fused fault curve engine
(``run_fault_curves``) once per degrade policy — the policy is static
metadata, so each grid is one compiled dispatch per ``bits`` value no matter
how many burst lanes ride the vmap axis.

Lane layout (per policy):

* lane 0 — ``FaultModel.iid(0)``: the clean-channel witness (bit-for-bit
  ``run_curves``'s p=0 lane; anchors the accuracy axis);
* lanes 1..4 — burst lengths 2/4/8/16 frames (mean bad-state sojourn) at a
  fixed 20% bad-state duty cycle (``gap = 4 x burst``), deep fades
  (``p_miss_bad=0.5``) over a nearly clean good state, plus heavy worker
  dropout (``p_drop=0.4``, ``p_recover=0.4``: half the cell offline in
  steady state, so a 4-worker cell hits a total outage on ~6% of frames) —
  outages actually occur, the staleness/dropped-frame columns are nonzero,
  and the ``stale`` vs ``zero_fill`` policies genuinely diverge.

Usage::

    PYTHONPATH=src python benchmarks/fault_sweep.py [--smoke] [OUT.json]
"""

import json
import sys

from repro import faults
from repro.sim import results as sim_results
from repro.sim import train_curves as tc

BURSTS = (2.0, 4.0, 8.0, 16.0)
POLICIES = (faults.DegradePolicy.stale(), faults.DegradePolicy.zero_fill())


def lanes_for(policy):
    out = [faults.FaultModel.iid(0.0, policy=policy)]
    for burst in BURSTS:
        out.append(faults.FaultModel.burst(
            burst_len=burst, gap_len=4.0 * burst, p_miss_bad=0.5,
            p_miss_good=0.01, policy=policy).with_dropout(0.4, 0.4))
    return out


def run(smoke: bool = False):
    ccfg = tc.CurveConfig(
        bits=(8, 16), p_miss=(0.0,),
        steps=12 if smoke else 60, batch=16 if smoke else 64,
        n_train=128 if smoke else 2048, n_val=64 if smoke else 512,
        hw=8 if smoke else 16,
        encoder_dims=(8,) if smoke else (32,),
        embed_dim=8 if smoke else 16,
        head_dims=(8,) if smoke else (32,),
        log_every=4 if smoke else 10)
    records = []
    for policy in POLICIES:
        fc = tc.run_fault_curves(ccfg, lanes_for(policy))
        records += sim_results.summarize_fault_curves(fc)
    return records


def main(argv):
    smoke = "--smoke" in argv
    paths = [a for a in argv if not a.startswith("-")]
    out = paths[0] if paths else "FAULT_curves.json"
    records = run(smoke=smoke)
    with open(out, "w") as f:
        json.dump(records, f, indent=2, sort_keys=True)
        f.write("\n")
    for rec in records:
        print(f"{rec['curve']}: acc={rec['acc']:.4f} nll={rec['nll']:.4f} "
              f"stale_age_max={rec['stale_age_max']} "
              f"dropped={rec['dropped_frames']}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
