"""Paper Table I analogue: patch-grid classification, five aggregation
methods (§IV-B).

Offline container => deterministic synthetic patch task with the paper's
structure (no single patch identifies the class; see data/vertical_data.py).
The claims under validation are the *relative* ones:

  concat ~= fedocs(max) ~= mean  >>  avg-preds  >  best-worker,
  at O(K) uplink for fedocs vs O(N*K) for concat/mean.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregators, vertical
from repro.core.vertical import VerticalConfig
from repro.data.vertical_data import PatchTaskConfig, patch_classification
from repro.optim import optimizers, schedules


def _train_one(cfg: VerticalConfig, views, labels, v_views, v_labels,
               steps: int = 600, batch: int = 64, lr: float = 3e-3,
               seed: int = 0):
    params = vertical.init(cfg, jax.random.PRNGKey(seed))
    opt = optimizers.adamw(schedules.linear_warmup_cosine(lr, 20, steps),
                           weight_decay=0.01)
    state = opt.init(params)
    n = views.shape[1]

    @jax.jit
    def step(params, state, vb, lb):
        def loss(p):
            return vertical.loss_fn(cfg, p, vb, lb)[0]
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
        return params, state

    rng = np.random.default_rng(seed)
    for i in range(steps):
        idx = rng.integers(0, n, batch)
        params, state = step(params, state, views[:, idx], labels[idx])
    _, metrics = vertical.loss_fn(cfg, params, v_views, v_labels)
    return params, float(metrics["acc"])


def _best_worker_acc(cfg, params, v_views, v_labels) -> float:
    preds = vertical.per_worker_predictions(cfg, params, v_views)
    accs = [float(jnp.mean(jnp.argmax(preds[i], -1) == v_labels))
            for i in range(preds.shape[0])]
    return max(accs)


def run(steps: int = 600, n_train: int = 8192, n_val: int = 512,
        seeds=(0,)) -> List[str]:
    task = PatchTaskConfig(n_classes=4, grid=2, hw=32, sigma=0.5)
    views, labels = patch_classification(task, n_train, seed=0)
    v_views, v_labels = patch_classification(task, n_val, seed=1)
    views_j = jnp.asarray(views)
    labels_j = jnp.asarray(labels)
    vv_j = jnp.asarray(v_views)
    vl_j = jnp.asarray(v_labels)

    base = VerticalConfig(
        n_workers=views.shape[0], input_dim=views.shape[-1],
        encoder_dims=(128, 64), embed_dim=32, head_dims=(128, 64),
        output_dim=task.n_classes, task="classification")

    rows = []
    accs: Dict[str, List[float]] = {}
    for method in aggregators.TABLE1_METHODS:
        cfg = aggregators.table1_config(method, base)
        for seed in seeds:
            t0 = time.time()
            params, acc = _train_one(cfg, views_j, labels_j, vv_j, vl_j,
                                     steps=steps, seed=seed)
            if method == "best_worker_pred":
                acc = _best_worker_acc(cfg, params, vv_j, vl_j)
            accs.setdefault(method, []).append(acc)
            dt = (time.time() - t0) * 1e6 / steps
            rows.append(f"table1/{method}/seed{seed},{dt:.0f},acc={acc:.4f}")
    # aggregate row per method
    for method, a in accs.items():
        load = vertical.comm_load(aggregators.table1_config(method, base))
        rows.append(
            f"table1/{method}/mean,0,"
            f"acc={np.mean(a):.4f}±{np.std(a):.4f};"
            f"uplink_msgs={load.uplink_payload_msgs}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
