"""Scenario-sweep benchmark: the named wireless scenarios plus a dense grid.

Every cell — workers x bits x p_miss x n_channels — is evaluated by the
batched engine in ``repro.sim.sweep``; the whole table costs one compiled
dispatch per engine per ``bits`` value, and the final row reports the jit
trace counters so CI can assert compilation stays O(1) in the grid size.

  PYTHONPATH=src python -m benchmarks.bench_sweep           # full sweep
  PYTHONPATH=src python -m benchmarks.bench_sweep --smoke   # CI smoke tier
"""

from __future__ import annotations

import sys
import time
from typing import List

from repro.sim import results as sim_results
from repro.sim import scenarios as sim_scenarios
from repro.sim import sweep as sim_sweep


def run(smoke: bool = False) -> List[str]:
    k_elems = 16 if smoke else 64
    rounds = 2 if smoke else 8

    named = [sim_scenarios.get(n) for n in sim_scenarios.names()]
    grid = sim_scenarios.scenario_grid(
        n_workers=(4, 16) if smoke else (4, 16, 64),
        bits=(8, 16),
        p_miss=(0.0, 0.05) if smoke else (0.0, 0.01, 0.05, 0.1),
        n_channels=(1,) if smoke else (1, 4),
    )
    cells = named + grid

    sim_sweep.reset_trace_counts()
    t0 = time.time()
    sw = sim_sweep.run_sweep(cells, k_elems=k_elems, rounds=rounds)
    records = sim_results.summarize(sw)
    dt_us = (time.time() - t0) * 1e6 / len(cells)
    traces = sim_sweep.trace_counts()

    rows = sim_results.to_rows(records)
    rows.append(
        f"sweep/meta,{dt_us:.0f},"
        f"cells={len(cells)};rounds={rounds};k={k_elems};"
        f"compiles_clean={traces['clean']};compiles_noisy={traces['noisy']}")
    n_bits = len({s.bits for s in cells})
    if traces["clean"] > n_bits or traces["noisy"] > n_bits:
        raise RuntimeError(
            f"sweep engine recompiled per cell: {traces} for {n_bits} bit "
            "depths — batching regression")
    return rows


if __name__ == "__main__":
    for r in run(smoke="--smoke" in sys.argv):
        print(r)
