"""Roofline table from dry-run artifacts (deliverable g).

Reads artifacts/dryrun/*.json and emits the per-cell three-term roofline
with bottleneck + useful-FLOPs ratio.  Run after ``launch/dryrun.py --all``.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ARTIFACT_DIR = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def load(mesh: str = "sp") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ARTIFACT_DIR, "*.json"))):
        rec = json.load(open(path))
        tag = f"__{mesh}__"
        if tag in os.path.basename(path):
            rows.append(rec)
    return rows


def fmt_row(r: Dict) -> str:
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | {r['status']} "
                f"| - | - | - | - | - | {r.get('reason', '')[:40]} |")
    rl = r["roofline"]
    ratio = r.get("useful_flops_ratio")
    t_max = max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])
    frac = rl["t_compute_s"] / t_max if t_max else 0.0
    return ("| {arch} | {shape} | ok | {tc:.2e} | {tm:.2e} | {tl:.2e} "
            "| {bn} | {ratio} | {frac:.1%} |").format(
        arch=r["arch"], shape=r["shape"],
        tc=rl["t_compute_s"], tm=rl["t_memory_s"], tl=rl["t_collective_s"],
        bn=rl["bottleneck"],
        ratio=f"{ratio:.2f}" if ratio else "-",
        frac=frac)


def table(mesh: str = "sp") -> str:
    rows = load(mesh)
    hdr = ("| arch | shape | status | t_compute (s) | t_memory (s) "
           "| t_collective (s) | bottleneck | useful_flops | "
           "compute-roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(fmt_row(r) for r in rows)


def run() -> List[str]:
    """benchmarks/run.py hook: emit CSV rows name,us_per_call,derived."""
    out = []
    for r in load("sp"):
        if r["status"] != "ok":
            continue
        rl = r["roofline"]
        step_s = max(rl["t_compute_s"], rl["t_memory_s"],
                     rl["t_collective_s"])
        out.append(
            f"roofline/{r['arch']}/{r['shape']},{step_s * 1e6:.1f},"
            f"bottleneck={rl['bottleneck']}")
    return out


if __name__ == "__main__":
    print("# single-pod (16x16 = 256 chips)")
    print(table("sp"))
