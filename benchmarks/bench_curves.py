"""Channel-in-the-loop training-curve benchmark: accuracy vs channel quality.

The paper's end-to-end experiment — train the vertical learner with the
noisy-OCS channel *in the forward pass* and report accuracy as a function of
the sensing-miss probability and the backoff depth.  Every ``p_miss`` lane
of a ``bits`` value trains inside ONE jitted train step (``p_miss`` and the
sensing rng are traced); the meta row reports the jit trace counters and the
run self-checks two contracts from the curve engine:

  * exactly one train-step compilation per ``bits`` value, and
  * the ``p_miss=0`` lane matches the ideal ``max_q{bits}`` reference run
    bit for bit (accuracy AND trained parameters).

  PYTHONPATH=src python -m benchmarks.bench_curves           # full curves
  PYTHONPATH=src python -m benchmarks.bench_curves --smoke   # CI smoke tier
"""

from __future__ import annotations

import json
import sys
import time
from typing import List, Optional

import numpy as np

from repro.sim import results as sim_results
from repro.sim import train_curves as tc


def _smoke_config() -> tc.CurveConfig:
    return tc.CurveConfig(bits=(8, 16), p_miss=(0.0, 0.05, 0.2), steps=24,
                          batch=32, n_train=512, n_val=256, log_every=8)


def _full_config() -> tc.CurveConfig:
    # bench_table1's task scale: large enough that embedding-level fusion
    # actually learns the relation, so the curve has headroom to degrade
    return tc.CurveConfig(bits=(8, 16), p_miss=(0.0, 0.01, 0.02, 0.05, 0.1),
                          steps=600, batch=64, n_train=8192, n_val=512,
                          hw=32, encoder_dims=(128, 64), embed_dim=32,
                          head_dims=(128, 64), log_every=25)


def run(smoke: bool = False, json_path: Optional[str] = None) -> List[str]:
    ccfg = _smoke_config() if smoke else _full_config()

    tc.reset_trace_counts()
    t0 = time.time()
    curves = tc.run_curves(ccfg)
    dt_us = (time.time() - t0) * 1e6 / max(1, ccfg.steps)
    traces = tc.trace_counts()

    n_bits = len(ccfg.bits)
    if traces["noisy_step"] != n_bits or traces["ideal_step"] != n_bits:
        raise RuntimeError(
            f"curve engine recompiled per lane: {traces} for {n_bits} bit "
            "depths — traced-(p_miss, rng) batching regression")

    # p_miss lane 0 is 0.0 in both configs: it must reproduce the ideal
    # max_q{bits} run bit for bit (same trained params, same accuracy).
    assert ccfg.p_miss[0] == 0.0
    import jax
    for bi, bits in enumerate(ccfg.bits):
        if curves.acc[bi, 0] != curves.acc_ideal[bi]:
            raise RuntimeError(
                f"bits={bits}: p_miss=0 accuracy {curves.acc[bi, 0]} != "
                f"ideal max_q{bits} accuracy {curves.acc_ideal[bi]}")
        for a, b in zip(jax.tree.leaves(curves.noisy_params[bi]),
                        jax.tree.leaves(curves.ideal_params[bi])):
            if not np.array_equal(np.asarray(a)[0], np.asarray(b)[0]):
                raise RuntimeError(
                    f"bits={bits}: p_miss=0 trained params diverged from "
                    "the ideal reference run")

    records = sim_results.summarize_curves(curves)
    rows = sim_results.curve_rows(records)
    rows.append(
        f"curves/meta,{dt_us:.0f},"
        f"bits={len(ccfg.bits)};lanes={len(ccfg.p_miss)};"
        f"steps={ccfg.steps};"
        f"compiles_noisy={traces['noisy_step']};"
        f"compiles_ideal={traces['ideal_step']};p0_matches_ideal=1")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(records, f, indent=2, sort_keys=True)
            f.write("\n")
    return rows


if __name__ == "__main__":
    argv = [a for a in sys.argv[1:] if a != "--smoke"]
    for r in run(smoke="--smoke" in sys.argv,
                 json_path=argv[0] if argv else None):
        print(r)
