"""Channel-in-the-loop training-curve benchmark: accuracy vs channel quality.

The paper's end-to-end experiment — train the vertical learner with the
noisy-OCS channel *in the forward pass* and report accuracy as a function of
the sensing-miss probability and the backoff depth.  Every ``p_miss`` lane
of a ``bits`` value trains inside ONE compiled train step (each lane carries
its own traced ``repro.protocol.Protocol`` pytree), and the fused scan
driver runs the whole steps loop in ONE dispatch per ``bits`` value.  The
run times the fused engine, times a ``CollisionAdaptiveBits``-scheduled run
(the ``BitsSchedule`` policy hook switching backoff depth per round from
observed collision telemetry, still one dispatch), and self-checks the
engine contracts:

  * exactly one fused compilation AND ``<= ceil(steps/log_every) + 2``
    dispatches per ``bits`` value,
  * the ``p_miss=0`` lane matches the ideal ``Protocol.ideal_max(bits)``
    reference run bit for bit (accuracy AND trained parameters),
  * trajectories unchanged under the Protocol API: a ``FixedBits(bits[0])``
    scheduled run reproduces the plain run's first-bits noisy lanes bit for
    bit (accuracy, nll, loss history AND trained parameters),
  * the adaptive schedule runs end-to-end in ONE ``sched`` dispatch with
    every chosen depth drawn from its candidate set,
  * the 2-D compressed-comms engine (``run_curves_dp``: p_miss lanes x DP
    shards, ``CompressedAllReduce`` inside the fused scan) stays one
    dispatch per ``bits`` value and its MEASURED per-step DP payload bits
    equal the analytic exact-k bill — the unified uplink + DP accounting
    lands in the emitted records (``total_comm_bits``) and BENCH json
    (``dp_payload_bits``),
  * the fault-injection engine (``run_fault_curves``: Gilbert–Elliott burst
    lanes + worker dropout, ``repro.faults``) adds ZERO extra traces —
    one compile and one dispatch per ``bits`` value however many fault
    lanes ride along — and its ``FaultModel.iid`` witness lane reproduces
    the plain engine's lane 0 bit for bit.

``--bench-json PATH`` (or ``bench_json_path=``) additionally emits the
timing/dispatch numbers as ``BENCH_curves.json`` — ``benchmarks/run.py``
writes the canonical copy at the repo root for trajectory tracking.

  PYTHONPATH=src python -m benchmarks.bench_curves           # full curves
  PYTHONPATH=src python -m benchmarks.bench_curves --smoke   # CI smoke tier
"""

from __future__ import annotations

import dataclasses
import json
import sys
import time
from typing import List, Optional

import numpy as np

from repro import analysis, faults
from repro.optim.compressed_allreduce import CompressedAllReduce
from repro.protocol import CollisionAdaptiveBits, FixedBits
from repro.sim import results as sim_results
from repro.sim import train_curves as tc

# the DP compression operating point both tiers bench: 1/8 kept + EF
_DP_K_FRAC = 1 / 8
_DP_SHARDS = 2


def _fault_lanes(ccfg: tc.CurveConfig):
    """The benched fault grid: one i.i.d. witness lane per ``p_miss`` entry
    position, then burst lanes of growing mean length.  Lane 0 is
    ``FaultModel.iid(p_miss[0])`` so it must reproduce the plain engine's
    lane 0 bit for bit (same stream derivation); the burst lanes share one
    ``stale`` policy — the whole grid is ONE compile per bits value."""
    policy = faults.DegradePolicy.stale()
    models = [faults.FaultModel.iid(p, policy=policy) for p in ccfg.p_miss]
    for burst_len in (4.0, 16.0):
        models.append(faults.FaultModel.burst(
            burst_len=burst_len, gap_len=4 * burst_len, p_miss_bad=0.5,
            p_miss_good=0.01, policy=policy))
    return models


def _smoke_config() -> tc.CurveConfig:
    return tc.CurveConfig(bits=(8, 16), p_miss=(0.0, 0.05, 0.2), steps=24,
                          batch=32, n_train=512, n_val=256, log_every=8)


def _full_config() -> tc.CurveConfig:
    # bench_table1's task scale: large enough that embedding-level fusion
    # actually learns the relation, so the curve has headroom to degrade
    return tc.CurveConfig(bits=(8, 16), p_miss=(0.0, 0.01, 0.02, 0.05, 0.1),
                          steps=600, batch=64, n_train=8192, n_val=512,
                          hw=32, encoder_dims=(128, 64), embed_dim=32,
                          head_dims=(128, 64), log_every=25)


def _run_engine(ccfg: tc.CurveConfig):
    tc.reset_trace_counts()
    tc.reset_dispatch_counts()
    t0 = time.perf_counter()
    curves = tc.run_curves(ccfg)
    wall = time.perf_counter() - t0
    return curves, wall, tc.trace_counts(), tc.dispatch_counts()


def _assert_sched_matches_lanes(sched: tc.ScheduledCurveResult,
                                curves: tc.CurveResult, bi: int) -> None:
    """FixedBits(bits[bi]) scheduled run == plain run's bits[bi] noisy lanes."""
    import jax

    if not np.array_equal(sched.acc, curves.acc[bi]):
        raise RuntimeError(
            "scheduled-engine parity broken: FixedBits accuracy diverged "
            "from the plain fused run")
    if not np.array_equal(sched.nll, curves.nll[bi]):
        raise RuntimeError("scheduled-engine parity broken: nll diverged")
    if not np.array_equal(sched.loss_history, curves.loss_history[bi]):
        raise RuntimeError(
            "scheduled-engine parity broken: loss history diverged")
    for x, y in zip(jax.tree.leaves(sched.params),
                    jax.tree.leaves(curves.noisy_params[bi])):
        if not np.array_equal(np.asarray(x), np.asarray(y)):
            raise RuntimeError(
                "scheduled-engine parity broken: trained params diverged")


def run(smoke: bool = False, json_path: Optional[str] = None,
        bench_json_path: Optional[str] = None) -> List[str]:
    ccfg = _smoke_config() if smoke else _full_config()
    n_bits = len(ccfg.bits)
    trained_steps = ccfg.steps * n_bits          # total steps per engine

    # the engine contracts are the shared repro.analysis assertions (the
    # same bounds the contract registry documents)
    curves, wall_scan, traces_s, disp_s = _run_engine(ccfg)
    analysis.assert_trace_count(traces_s["fused"], n_bits, "fused engine")
    per_bits_scan = disp_s["fused"] / n_bits
    bound = analysis.fused_dispatch_bound(ccfg.steps, ccfg.log_every)
    analysis.assert_fused_dispatches(per_bits_scan, ccfg.steps,
                                     ccfg.log_every)

    # p_miss lane 0 is 0.0 in both configs: it must reproduce the ideal
    # Protocol.ideal_max(bits) run bit for bit (params and accuracy).
    assert ccfg.p_miss[0] == 0.0
    import jax
    for bi, bits in enumerate(ccfg.bits):
        if curves.acc[bi, 0] != curves.acc_ideal[bi]:
            raise RuntimeError(
                f"bits={bits}: p_miss=0 accuracy {curves.acc[bi, 0]} != "
                f"ideal max_q{bits} accuracy {curves.acc_ideal[bi]}")
        for a, b in zip(jax.tree.leaves(curves.noisy_params[bi]),
                        jax.tree.leaves(curves.ideal_params[bi])):
            if not np.array_equal(np.asarray(a)[0], np.asarray(b)[0]):
                raise RuntimeError(
                    f"bits={bits}: p_miss=0 trained params diverged from "
                    "the ideal reference run")

    # the BitsSchedule hook: a FixedBits schedule must reproduce the plain
    # engine bit for bit (trajectory unchanged under the scheduled API) ...
    tc.reset_dispatch_counts()
    fixed = tc.run_scheduled_curves(ccfg, FixedBits(ccfg.bits[0]))
    analysis.assert_single_dispatch(tc.dispatch_counts(), "sched",
                                    "FixedBits scheduled run")
    _assert_sched_matches_lanes(fixed, curves, bi=0)

    # ... and the collision-adaptive policy runs end-to-end in ONE dispatch
    schedule = CollisionAdaptiveBits(tuple(ccfg.bits))
    tc.reset_dispatch_counts()
    t0 = time.perf_counter()
    adaptive = tc.run_scheduled_curves(ccfg, schedule)
    wall_sched = time.perf_counter() - t0
    analysis.assert_single_dispatch(tc.dispatch_counts(), "sched",
                                    "adaptive scheduled run")
    if not set(np.unique(adaptive.bits_per_step)) <= set(ccfg.bits):
        raise RuntimeError(
            f"schedule chose depths {np.unique(adaptive.bits_per_step)} "
            f"outside its candidates {ccfg.bits}")
    if not np.isfinite(adaptive.acc).all():
        raise RuntimeError("adaptive scheduled run produced non-finite acc")
    sched_switches = int(np.sum(np.diff(adaptive.bits_per_step) != 0))

    # the 2-D compressed-comms engine: p_miss lanes x DP batch shards with
    # CompressedAllReduce (top-k + EF) inside the fused scan — still one
    # dispatch per bits value, and the payload bits MEASURED on device must
    # equal the analytic exact-k bill every step
    dcfg = dataclasses.replace(ccfg, dp_shards=_DP_SHARDS)
    car = CompressedAllReduce.topk(_DP_K_FRAC)
    tc.reset_trace_counts()
    tc.reset_dispatch_counts()
    t0 = time.perf_counter()
    dp = tc.run_curves_dp(dcfg, car)
    wall_dp = time.perf_counter() - t0
    traces_d, disp_d = tc.trace_counts(), tc.dispatch_counts()
    analysis.assert_trace_count(traces_d["fused_dp"], n_bits,
                                "dp curve engine")
    per_bits_dp = disp_d["fused_dp"] / n_bits
    analysis.assert_fused_dispatches(per_bits_dp, ccfg.steps, ccfg.log_every)
    if not np.all(dp.dp_payload_bits == dp.dp_payload_bits_step):
        raise RuntimeError(
            "dp accounting broken: measured per-step payload bits "
            f"{np.unique(dp.dp_payload_bits)} != analytic exact-k bill "
            f"{dp.dp_payload_bits_step}")
    if not np.all(dp.dp_payload_bits_total
                  == dp.dp_payload_bits_step * ccfg.steps):
        raise RuntimeError("dp accounting broken: run total != steps x bill")
    if not np.isfinite(dp.acc).all():
        raise RuntimeError("dp curve run produced non-finite accuracy")

    # the fault-injection engine: FaultModel lanes (Gilbert–Elliott bursts +
    # i.i.d. witnesses) inside the fused scan — the burst-lane self-check:
    # fault lanes add ZERO extra traces (one compile per bits value, however
    # many fault lanes ride along), and the i.i.d. witness lane reproduces
    # the plain engine's lane 0 bit for bit
    flanes = _fault_lanes(ccfg)
    tc.reset_trace_counts()
    tc.reset_dispatch_counts()
    t0 = time.perf_counter()
    fc = tc.run_fault_curves(ccfg, flanes)
    wall_faults = time.perf_counter() - t0
    traces_f, disp_f = tc.trace_counts(), tc.dispatch_counts()
    analysis.assert_trace_count(traces_f["fused_faults"], n_bits,
                                "fault curve engine")
    if disp_f["fused_faults"] != n_bits:
        raise RuntimeError(
            f"fault engine dispatched {disp_f['fused_faults']} times for "
            f"{n_bits} bits values — fault lanes must ride the one fused "
            f"dispatch")
    if not np.array_equal(fc.acc[:, 0], curves.acc[:, 0]):
        raise RuntimeError(
            "fault-engine parity broken: the FaultModel.iid witness lane "
            f"diverged from the plain run (fault {fc.acc[:, 0]} vs plain "
            f"{curves.acc[:, 0]})")
    if not np.isfinite(fc.acc).all():
        raise RuntimeError("fault curve run produced non-finite accuracy")

    # wall-clock includes the (cacheable) compile
    sps_scan = trained_steps / wall_scan
    sps_sched = ccfg.steps / wall_sched
    sps_dp = trained_steps / wall_dp
    sps_faults = trained_steps / wall_faults

    records = sim_results.summarize_curves(curves)
    dp_records = sim_results.summarize_dp_curves(dp)
    fault_records = sim_results.summarize_fault_curves(fc)
    rows = sim_results.curve_rows(records)
    rows += sim_results.dp_curve_rows(dp_records)
    rows += sim_results.fault_curve_rows(fault_records)
    rows.append(
        f"curves/engine_scan,{wall_scan / trained_steps * 1e6:.0f},"
        f"steps_per_sec={sps_scan:.1f};dispatches_per_bits="
        f"{per_bits_scan:g};compiles={traces_s['fused']}")
    rows.append(
        f"curves/engine_sched,{wall_sched / ccfg.steps * 1e6:.0f},"
        f"steps_per_sec={sps_sched:.1f};dispatches=1;"
        f"candidates={'|'.join(str(b) for b in ccfg.bits)};"
        f"switches={sched_switches};"
        f"final_bits={int(adaptive.bits_per_step[-1])}")
    rows.append(
        f"curves/engine_dp,{wall_dp / trained_steps * 1e6:.0f},"
        f"steps_per_sec={sps_dp:.1f};dispatches_per_bits={per_bits_dp:g};"
        f"compiles={traces_d['fused_dp']};dp_shards={_DP_SHARDS};"
        f"k_frac={_DP_K_FRAC:g};"
        f"dp_payload_bits_step={dp.dp_payload_bits_step};"
        f"dp_payload_frac="
        f"{dp.dp_payload_bits_step / dp.dp_dense_bits_step:.3f}")
    rows.append(
        f"curves/engine_faults,{wall_faults / trained_steps * 1e6:.0f},"
        f"steps_per_sec={sps_faults:.1f};fault_lanes={len(flanes)};"
        f"dispatches_per_bits={disp_f['fused_faults'] / n_bits:g};"
        f"compiles={traces_f['fused_faults']};"
        f"policy={flanes[0].policy.kind};"
        f"iid_witness_bitwise_equal=1")
    rows.append(
        f"curves/dispatch,0,scan_bound={bound};"
        f"dispatches_per_bits={per_bits_scan:g}")
    rows.append(
        f"curves/meta,0,"
        f"bits={n_bits};lanes={len(ccfg.p_miss)};steps={ccfg.steps};"
        f"p0_matches_ideal=1;fixed_schedule_bitwise_equal=1;"
        f"dp_payload_measured_equals_analytic=1")

    if json_path:
        with open(json_path, "w") as f:
            json.dump(records + dp_records + fault_records, f, indent=2,
                      sort_keys=True)
            f.write("\n")
    if bench_json_path:
        bench = {
            "bench": "curves",
            "smoke": smoke,
            "grid": {"bits": list(ccfg.bits), "lanes": len(ccfg.p_miss),
                     "steps": ccfg.steps, "batch": ccfg.batch,
                     "log_every": ccfg.log_every,
                     "n_workers": ccfg.n_workers,
                     "embed_dim": ccfg.embed_dim},
            "engines": {
                "scan": {"wall_s": round(wall_scan, 3),
                         "steps_per_sec": round(sps_scan, 2),
                         "dispatches_per_bits": per_bits_scan,
                         "traces_per_bits": traces_s["fused"] / n_bits},
                "sched": {"wall_s": round(wall_sched, 3),
                          "steps_per_sec": round(sps_sched, 2),
                          "dispatches": 1,
                          "candidates": list(ccfg.bits),
                          "switches": sched_switches,
                          "final_bits": int(adaptive.bits_per_step[-1])},
                "dp": {"wall_s": round(wall_dp, 3),
                       "steps_per_sec": round(sps_dp, 2),
                       "dispatches_per_bits": per_bits_dp,
                       "traces_per_bits": traces_d["fused_dp"] / n_bits,
                       "dp_shards": _DP_SHARDS},
                "faults": {"wall_s": round(wall_faults, 3),
                           "steps_per_sec": round(sps_faults, 2),
                           "fault_lanes": len(flanes),
                           "dispatches_per_bits":
                               disp_f["fused_faults"] / n_bits,
                           "traces_per_bits":
                               traces_f["fused_faults"] / n_bits,
                           "policy": flanes[0].policy.kind,
                           "iid_witness_bitwise_equal": True},
            },
            "dp_payload_bits": {
                "k_frac": _DP_K_FRAC,
                "per_step": dp.dp_payload_bits_step,
                "dense_per_step": dp.dp_dense_bits_step,
                "payload_frac": round(
                    dp.dp_payload_bits_step / dp.dp_dense_bits_step, 4),
                "run_total": int(dp.dp_payload_bits_total.max()),
                "measured_equals_analytic": True,
            },
            "parity_bitwise": True,          # FixedBits sched == plain run
            "p0_matches_ideal": True,
        }
        with open(bench_json_path, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
            f.write("\n")
    return rows


if __name__ == "__main__":
    argv = sys.argv[1:]
    bench_json = None
    if "--bench-json" in argv:
        i = argv.index("--bench-json")
        if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
            sys.exit("usage: bench_curves [--smoke] [--bench-json PATH] "
                     "[records.json]")
        bench_json = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    positional = [a for a in argv if a != "--smoke"]
    for r in run(smoke="--smoke" in argv,
                 json_path=positional[0] if positional else None,
                 bench_json_path=bench_json):
        print(r)
