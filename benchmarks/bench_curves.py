"""Channel-in-the-loop training-curve benchmark: accuracy vs channel quality.

The paper's end-to-end experiment — train the vertical learner with the
noisy-OCS channel *in the forward pass* and report accuracy as a function of
the sensing-miss probability and the backoff depth.  Every ``p_miss`` lane
of a ``bits`` value trains inside ONE compiled train step (``p_miss`` and
the sensing rng are traced), and the fused ``engine="scan"`` driver runs the
whole steps loop in ONE dispatch per ``bits`` value.  The run times BOTH
curve engines (the fused scan engine and the legacy per-step python driver)
and self-checks the engine contracts:

  * exactly one fused compilation AND ``<= ceil(steps/log_every) + 2``
    dispatches per ``bits`` value on the scan engine,
  * >= 3x fewer dispatches per ``bits`` value than the python engine,
  * scan-vs-python bit-for-bit parity (accuracy, nll, loss history AND
    trained parameters),
  * the ``p_miss=0`` lane matches the ideal ``max_q{bits}`` reference run
    bit for bit (accuracy AND trained parameters).

``--bench-json PATH`` (or ``bench_json_path=``) additionally emits the
timing/dispatch numbers as ``BENCH_curves.json`` — ``benchmarks/run.py``
writes the canonical copy at the repo root for trajectory tracking.

  PYTHONPATH=src python -m benchmarks.bench_curves           # full curves
  PYTHONPATH=src python -m benchmarks.bench_curves --smoke   # CI smoke tier
"""

from __future__ import annotations

import dataclasses
import json
import math
import sys
import time
from typing import List, Optional

import numpy as np

from repro.sim import results as sim_results
from repro.sim import train_curves as tc


def _smoke_config() -> tc.CurveConfig:
    return tc.CurveConfig(bits=(8, 16), p_miss=(0.0, 0.05, 0.2), steps=24,
                          batch=32, n_train=512, n_val=256, log_every=8)


def _full_config() -> tc.CurveConfig:
    # bench_table1's task scale: large enough that embedding-level fusion
    # actually learns the relation, so the curve has headroom to degrade
    return tc.CurveConfig(bits=(8, 16), p_miss=(0.0, 0.01, 0.02, 0.05, 0.1),
                          steps=600, batch=64, n_train=8192, n_val=512,
                          hw=32, encoder_dims=(128, 64), embed_dim=32,
                          head_dims=(128, 64), log_every=25)


def _run_engine(ccfg: tc.CurveConfig):
    tc.reset_trace_counts()
    tc.reset_dispatch_counts()
    t0 = time.perf_counter()
    curves = tc.run_curves(ccfg)
    wall = time.perf_counter() - t0
    return curves, wall, tc.trace_counts(), tc.dispatch_counts()


def _assert_bitwise_equal(a: tc.CurveResult, b: tc.CurveResult) -> None:
    import jax

    for name in ("acc", "nll", "acc_ideal", "nll_ideal", "loss_history",
                 "ideal_loss_history"):
        if not np.array_equal(getattr(a, name), getattr(b, name)):
            raise RuntimeError(
                f"engine parity broken: scan vs python disagree on {name}")
    for bi in range(len(a.config.bits)):
        for pa, pb in ((a.noisy_params, b.noisy_params),
                       (a.ideal_params, b.ideal_params)):
            for x, y in zip(jax.tree.leaves(pa[bi]), jax.tree.leaves(pb[bi])):
                if not np.array_equal(np.asarray(x), np.asarray(y)):
                    raise RuntimeError(
                        "engine parity broken: trained params diverged")


def run(smoke: bool = False, json_path: Optional[str] = None,
        bench_json_path: Optional[str] = None) -> List[str]:
    ccfg = _smoke_config() if smoke else _full_config()
    n_bits = len(ccfg.bits)
    trained_steps = ccfg.steps * n_bits          # total steps per engine

    curves, wall_scan, traces_s, disp_s = _run_engine(ccfg)
    if traces_s["fused"] != n_bits:
        raise RuntimeError(
            f"scan engine recompiled per lane: {traces_s} for {n_bits} bit "
            "depths — traced-(p_miss, rng) batching regression")
    per_bits_scan = disp_s["fused"] / n_bits
    bound = math.ceil(ccfg.steps / ccfg.log_every) + 2
    if per_bits_scan > bound:
        raise RuntimeError(
            f"scan engine dispatched {per_bits_scan}/bits — exceeds the "
            f"ceil(steps/log_every)+2 = {bound} fusion bound")

    curves_py, wall_py, traces_p, disp_p = _run_engine(
        dataclasses.replace(ccfg, engine="python"))
    if traces_p["noisy_step"] != n_bits or traces_p["ideal_step"] != n_bits:
        raise RuntimeError(
            f"python engine recompiled per lane: {traces_p} for {n_bits} "
            "bit depths — traced-(p_miss, rng) batching regression")
    per_bits_python = sum(disp_p.values()) / n_bits
    dispatch_ratio = per_bits_python / per_bits_scan
    if dispatch_ratio < 3:
        raise RuntimeError(
            f"scan engine only saves {dispatch_ratio:.1f}x dispatches per "
            "bits value (acceptance floor: 3x)")

    # engine parity: the fused scan trajectory IS the per-step trajectory
    _assert_bitwise_equal(curves, curves_py)

    # p_miss lane 0 is 0.0 in both configs: it must reproduce the ideal
    # max_q{bits} run bit for bit (same trained params, same accuracy).
    assert ccfg.p_miss[0] == 0.0
    import jax
    for bi, bits in enumerate(ccfg.bits):
        if curves.acc[bi, 0] != curves.acc_ideal[bi]:
            raise RuntimeError(
                f"bits={bits}: p_miss=0 accuracy {curves.acc[bi, 0]} != "
                f"ideal max_q{bits} accuracy {curves.acc_ideal[bi]}")
        for a, b in zip(jax.tree.leaves(curves.noisy_params[bi]),
                        jax.tree.leaves(curves.ideal_params[bi])):
            if not np.array_equal(np.asarray(a)[0], np.asarray(b)[0]):
                raise RuntimeError(
                    f"bits={bits}: p_miss=0 trained params diverged from "
                    "the ideal reference run")

    # wall-clock includes the (cacheable) compile; the python engine pays
    # dispatch + host-sync overhead per step, the scan engine does not —
    # their gap is the host-overhead share of the per-step driver
    sps_scan = trained_steps / wall_scan
    sps_python = trained_steps / wall_py
    host_overhead = max(0.0, 1.0 - wall_scan / wall_py)

    records = sim_results.summarize_curves(curves)
    rows = sim_results.curve_rows(records)
    rows.append(
        f"curves/engine_scan,{wall_scan / trained_steps * 1e6:.0f},"
        f"steps_per_sec={sps_scan:.1f};dispatches_per_bits="
        f"{per_bits_scan:g};compiles={traces_s['fused']}")
    rows.append(
        f"curves/engine_python,{wall_py / trained_steps * 1e6:.0f},"
        f"steps_per_sec={sps_python:.1f};dispatches_per_bits="
        f"{per_bits_python:g}")
    rows.append(
        f"curves/dispatch,0,ratio={dispatch_ratio:.0f}x;"
        f"scan_bound={bound};host_overhead_frac={host_overhead:.2f}")
    rows.append(
        f"curves/meta,0,"
        f"bits={n_bits};lanes={len(ccfg.p_miss)};steps={ccfg.steps};"
        f"engines_bitwise_equal=1;p0_matches_ideal=1")

    if json_path:
        with open(json_path, "w") as f:
            json.dump(records, f, indent=2, sort_keys=True)
            f.write("\n")
    if bench_json_path:
        bench = {
            "bench": "curves",
            "smoke": smoke,
            "grid": {"bits": list(ccfg.bits), "lanes": len(ccfg.p_miss),
                     "steps": ccfg.steps, "batch": ccfg.batch,
                     "log_every": ccfg.log_every,
                     "n_workers": ccfg.n_workers,
                     "embed_dim": ccfg.embed_dim},
            "engines": {
                "scan": {"wall_s": round(wall_scan, 3),
                         "steps_per_sec": round(sps_scan, 2),
                         "dispatches_per_bits": per_bits_scan,
                         "traces_per_bits": traces_s["fused"] / n_bits},
                "python": {"wall_s": round(wall_py, 3),
                           "steps_per_sec": round(sps_python, 2),
                           "dispatches_per_bits": per_bits_python},
            },
            "dispatch_ratio": round(dispatch_ratio, 1),
            "speedup_scan_over_python": round(wall_py / wall_scan, 2),
            "host_overhead_frac": round(host_overhead, 3),
            "parity_bitwise": True,
            "p0_matches_ideal": True,
        }
        with open(bench_json_path, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
            f.write("\n")
    return rows


if __name__ == "__main__":
    argv = sys.argv[1:]
    bench_json = None
    if "--bench-json" in argv:
        i = argv.index("--bench-json")
        if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
            sys.exit("usage: bench_curves [--smoke] [--bench-json PATH] "
                     "[records.json]")
        bench_json = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    positional = [a for a in argv if a != "--smoke"]
    for r in run(smoke="--smoke" in argv,
                 json_path=positional[0] if positional else None,
                 bench_json_path=bench_json):
        print(r)
