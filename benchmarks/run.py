"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  bench_table1    paper Table I  (5 aggregation methods, patch classification)
  bench_fig2      paper Fig. 2   (multi-sensor denoising, 1 vs 4 workers)
  bench_comm      paper §I claim (O(K) vs O(N*K) comm; ICI fusion bytes)
  bench_sweep     batched scenario sweep (repro.sim) over N x bits x p_miss
  bench_curves    channel-in-the-loop training: accuracy vs p_miss x bits
  bench_serve     channel-in-the-loop serving: tokens/sec + latency vs p_miss
  bench_contention  noisy-contention backends: lax.scan vs fused Pallas
  bench_kernels   Pallas kernel micro-timings (interpret mode)
  bench_roofline  roofline terms per (arch x shape) from dry-run artifacts

Full (non ``--fast``) runs additionally persist their numbers as canonical
``BENCH_*.json`` files at the repo root (``BENCH_curves.json``,
``BENCH_serve.json``, ``BENCH_contention.json``), so the perf trajectory
is diffable across PRs; ``--fast`` leaves the committed full-scale numbers
untouched.
"""

from __future__ import annotations

import pathlib
import sys
import time

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def main() -> None:
    fast = "--fast" in sys.argv
    from benchmarks import (bench_comm, bench_contention, bench_curves,
                            bench_fig2, bench_kernels, bench_roofline,
                            bench_serve, bench_sweep, bench_table1)
    print("name,us_per_call,derived")
    t0 = time.time()
    for row in bench_comm.run():
        print(row)
    for row in bench_sweep.run(smoke=fast):
        print(row)
    # canonical trajectory files only from full-scale runs: a --fast smoke
    # must not overwrite the committed 600-step numbers with 24-step ones
    for row in bench_curves.run(
            smoke=fast,
            bench_json_path=None if fast
            else str(REPO_ROOT / "BENCH_curves.json")):
        print(row)
    for row in bench_serve.run(
            smoke=fast,
            bench_json_path=None if fast
            else str(REPO_ROOT / "BENCH_serve.json")):
        print(row)
    for row in bench_contention.run(
            smoke=fast,
            json_path=None if fast
            else str(REPO_ROOT / "BENCH_contention.json")):
        print(row)
    for row in bench_kernels.run():
        print(row)
    for row in bench_roofline.run():
        print(row)
    for row in bench_table1.run(steps=120 if fast else 600,
                                seeds=(0,) if fast else (0, 1)):
        print(row)
    for row in bench_fig2.run(steps=60 if fast else 400):
        print(row)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
