"""Communication-load benchmark (the paper's §I O(1/N) claim).

Four layers of evidence:
  1. protocol accounting (channel.py): uplink messages vs N,
  2. the OCS simulator's slot/transmission counters on random features —
     all N in ONE jitted sweep (repro.sim.sweep) instead of per-round
     Python dispatch; the accounting columns are bit-for-bit identical to
     the historical per-call rows (property-tested in tests/test_sweep.py),
  3. noisy-sensing accuracy-degradation curves from the same engine's
     imperfect-carrier-sensing core (one compilation for the whole
     p_miss axis),
  4. ICI collective bytes for the TP fusion modes — analytic ring model
     cross-checked against the dry-run's parsed HLO collectives when the
     artifacts exist (fedocs max/q8 vs concat vs sum).
"""

from __future__ import annotations

import glob
import json
import time
from typing import List

import numpy as np

from repro.core import channel
from repro.protocol import Protocol
from repro.sim import sweep as sim_sweep
from repro.sim.scenarios import Scenario, scenario_grid

SIM_WORKERS = (4, 16, 64)
NOISY_P_MISS = (0.0, 0.01, 0.02, 0.05, 0.1)


def run() -> List[str]:
    rows = []
    k = 64
    # analytic accounting off the Protocol objects (Protocol.max: D bits
    # drive contention, winner transmits its full float payload)
    fedocs_proto, concat_proto = Protocol.max(bits=16), Protocol.concat()
    for n in (2, 4, 9, 16, 64, 256):
        f = fedocs_proto.comm_load(n, k)
        c = concat_proto.comm_load(n, k)
        rows.append(
            f"comm/uplink_msgs/N{n},0,"
            f"fedocs={f.uplink_payload_msgs};concat={c.uplink_payload_msgs};"
            f"ratio={c.uplink_payload_msgs / f.uplink_payload_msgs:.0f}")

    # protocol simulation: measured transmissions on random features.
    # Replays the historical rng stream (default_rng(0), one (n, k) draw per
    # N) through one jitted sweep — same accounting columns, one dispatch.
    rng = np.random.default_rng(0)
    h_by = [rng.standard_normal((n, k)).astype(np.float32)[None]
            for n in SIM_WORKERS]
    scens = [Scenario(f"bench/N{n}", n_workers=n) for n in SIM_WORKERS]
    t0 = time.time()
    sw = sim_sweep.run_sweep(scens, k_elems=k, rounds=1,
                             h_by_scenario=h_by, include_noisy=False)
    dt = (time.time() - t0) * 1e6 / len(SIM_WORKERS)
    for i, n in enumerate(SIM_WORKERS):
        res = sw.clean_cell(i)
        rows.append(
            f"comm/ocs_sim/N{n},{dt:.0f},"
            f"payload_tx={int(res.payload_tx)};"
            f"blocking_tx={int(res.blocking_tx)};"
            f"slots={int(res.contention_slots)};"
            f"concat_tx={int(res.concat_payload_tx)}")

    # noisy-sensing degradation: accuracy/collision curves over the p_miss
    # axis, all cells in one compilation of the noisy engine.
    noisy_grid = scenario_grid(n_workers=(16,), bits=(16,),
                               p_miss=NOISY_P_MISS, name_prefix="bench")
    nsw = sim_sweep.run_sweep(noisy_grid, k_elems=k, rounds=4, seed=1,
                              include_clean=False)
    for i, s in enumerate(noisy_grid):
        correct = float(np.asarray(nsw.noisy.correct)[i].mean())
        coll = float(np.asarray(nsw.noisy.collisions)[i].mean())
        rows.append(
            f"comm/ocs_noisy/N{s.n_workers}_p{s.p_miss:g},0,"
            f"frac_correct={correct:.3f};collisions={coll:.1f}")

    # ICI fusion bytes: analytic ring model
    d_model, n_shards = 4096, 16
    for mode in ("sum", "max", "max_q16", "max_q8", "concat"):
        b = channel.tp_fusion_bytes(mode, d_model, n_shards)
        rows.append(f"comm/ici_fusion/{mode},0,bytes_per_token={b}")

    # cross-check vs dry-run artifacts (glm4 fusion-mode sweep if present)
    for variant in ("max", "sum", "concat", "q8"):
        paths = (glob.glob(f"artifacts/dryrun/glm4-9b__train_4k__sp__{variant}.json")
                 + glob.glob(f"artifacts/hillclimb/glm4-9b__train_4k__sp__{variant}.json"))
        if paths:
            rec = json.load(open(paths[0]))
            if rec.get("status") == "ok":
                lb = rec["collectives"]["link_bytes_per_dev"]
                rows.append(
                    f"comm/dryrun_link_bytes/glm4_{variant},0,"
                    f"GB_per_dev={lb / 1e9:.1f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
