"""Communication-load benchmark (the paper's §I O(1/N) claim).

Three layers of evidence:
  1. protocol accounting (channel.py): uplink messages vs N,
  2. the OCS simulator's slot/transmission counters on random features,
  3. ICI collective bytes for the TP fusion modes — analytic ring model
     cross-checked against the dry-run's parsed HLO collectives when the
     artifacts exist (fedocs max/q8 vs concat vs sum).
"""

from __future__ import annotations

import glob
import json
import os
import time
from typing import List

import jax.numpy as jnp
import numpy as np

from repro.core import channel, ocs


def run() -> List[str]:
    rows = []
    k = 64
    for n in (2, 4, 9, 16, 64, 256):
        f = channel.ocs_load(n, k, bits=16)
        c = channel.concat_load(n, k)
        rows.append(
            f"comm/uplink_msgs/N{n},0,"
            f"fedocs={f.uplink_payload_msgs};concat={c.uplink_payload_msgs};"
            f"ratio={c.uplink_payload_msgs / f.uplink_payload_msgs:.0f}")

    # protocol simulation: measured transmissions on random features
    rng = np.random.default_rng(0)
    for n in (4, 16, 64):
        h = jnp.asarray(rng.standard_normal((n, k)).astype(np.float32))
        t0 = time.time()
        res = ocs.ocs_maxpool(h, bits=16)
        dt = (time.time() - t0) * 1e6
        rows.append(
            f"comm/ocs_sim/N{n},{dt:.0f},"
            f"payload_tx={int(res.payload_tx)};"
            f"blocking_tx={int(res.blocking_tx)};"
            f"slots={int(res.contention_slots)};"
            f"concat_tx={int(res.concat_payload_tx)}")

    # ICI fusion bytes: analytic ring model
    d_model, n_shards = 4096, 16
    for mode in ("sum", "max", "max_q16", "max_q8", "concat"):
        b = channel.tp_fusion_bytes(mode, d_model, n_shards)
        rows.append(f"comm/ici_fusion/{mode},0,bytes_per_token={b}")

    # cross-check vs dry-run artifacts (glm4 fusion-mode sweep if present)
    for variant in ("max", "sum", "concat", "q8"):
        paths = (glob.glob(f"artifacts/dryrun/glm4-9b__train_4k__sp__{variant}.json")
                 + glob.glob(f"artifacts/hillclimb/glm4-9b__train_4k__sp__{variant}.json"))
        if paths:
            rec = json.load(open(paths[0]))
            if rec.get("status") == "ok":
                lb = rec["collectives"]["link_bytes_per_dev"]
                rows.append(
                    f"comm/dryrun_link_bytes/glm4_{variant},0,"
                    f"GB_per_dev={lb / 1e9:.1f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
