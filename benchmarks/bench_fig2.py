"""Paper Fig. 2 analogue: multi-sensor denoising reconstruction (§IV-A).

N=4 sensors observe the same image under independent sigma=2 Gaussian noise;
the fusion center reconstructs the clean image from max-pooled embeddings.
The paper reports NLL 0.13 (4 workers) vs 0.19 (1 worker); the claim under
validation is the multi-sensor fusion gain at equal channel use per sensor.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import vertical
from repro.core.vertical import VerticalConfig
from repro.data.vertical_data import multiview_denoising
from repro.optim import optimizers, schedules


def _train(cfg, views, clean, steps=400, batch=64, seed=0):
    params = vertical.init(cfg, jax.random.PRNGKey(seed))
    opt = optimizers.adamw(
        schedules.linear_warmup_cosine(2e-3, 20, steps), weight_decay=0.0)
    state = opt.init(params)
    n = views.shape[1]

    @jax.jit
    def step(params, state, vb, cb):
        g = jax.grad(lambda p: vertical.loss_fn(cfg, p, vb, cb)[0])(params)
        params, state, _ = opt.update(g, state, params)
        return params, state

    rng = np.random.default_rng(seed)
    for _ in range(steps):
        idx = rng.integers(0, n, batch)
        params, state = step(params, state, views[:, idx], clean[idx])
    return params


def run(steps: int = 400) -> List[str]:
    hw = 28
    views, clean = multiview_denoising(2048, n_workers=4, hw=hw, sigma=2.0,
                                       seed=0)
    v_views, v_clean = multiview_denoising(256, n_workers=4, hw=hw,
                                           sigma=2.0, seed=99)
    rows = []
    nlls = {}
    for n_workers in (1, 4):
        cfg = VerticalConfig(
            n_workers=n_workers, input_dim=hw * hw,
            encoder_dims=(512, 256, 128), embed_dim=64,
            head_dims=(128, 256, 512), output_dim=hw * hw,
            task="reconstruction", aggregation="max")
        t0 = time.time()
        params = _train(cfg, jnp.asarray(views[:n_workers]),
                        jnp.asarray(clean), steps=steps)
        _, m = vertical.loss_fn(cfg, params, jnp.asarray(v_views[:n_workers]),
                                jnp.asarray(v_clean))
        nll = float(m["nll"])
        nlls[n_workers] = nll
        dt = (time.time() - t0) * 1e6 / steps
        rows.append(f"fig2/recon_{n_workers}workers,{dt:.0f},val_nll={nll:.4f}")
    rows.append(
        f"fig2/fusion_gain,0,nll_1w={nlls[1]:.4f};nll_4w={nlls[4]:.4f};"
        f"improved={nlls[4] < nlls[1]}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
