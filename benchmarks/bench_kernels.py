"""Kernel micro-benchmarks (interpret mode on CPU: correctness-side timing
harness; real per-op wins are structural and reported via the roofline)."""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fedocs
from repro.kernels import interpret_default
from repro.kernels.maxpool import ops as mp_ops
from repro.kernels.ocs_quant import ops as q_ops


def _time(fn, *args, iters=5) -> float:
    fn(*args)                      # compile
    t0 = time.time()
    for _ in range(iters):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.time() - t0) / iters * 1e6


def run() -> List[str]:
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.standard_normal((16, 512, 512)).astype(np.float32))
    rows = []
    t_ref = _time(jax.jit(lambda x: jnp.max(x, axis=0)), h)
    t_core = _time(jax.jit(lambda x: fedocs.maxpool(x, "all")), h)
    t_kern = _time(lambda x: mp_ops.maxpool(x), h)
    interp = f"interpret={interpret_default()}"
    rows.append(f"kernel/maxpool_jnp,{t_ref:.0f},baseline")
    rows.append(f"kernel/maxpool_core,{t_core:.0f},custom_vjp")
    rows.append(f"kernel/maxpool_pallas,{t_kern:.0f},{interp}")

    x = jnp.asarray(rng.standard_normal((512, 512)).astype(np.float32))
    t_enc = _time(lambda v: q_ops.encode(v, 8), x)
    rows.append(f"kernel/ocs_quant_encode8,{t_enc:.0f},{interp}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
