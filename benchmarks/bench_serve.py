"""Channel-in-the-loop serving benchmark: tokens/sec and latency vs
channel quality.

Drives the slot-batched :class:`repro.serve.engine.ServeEngine` with a
Poisson request stream (``repro.serve.load``) and sweeps the channel from
error-free to degraded — every OCS point rebinds only the protocol's
traced ``p_miss`` leaf on ONE engine, so the whole quality sweep runs on a
single compiled decode tick.  Reported per channel point: tokens/sec
(generated tokens over wall clock) and p50/p99 end-to-end latency under
the :class:`~repro.serve.engine.ChannelClock` (compute ticks + measured
channel airtime).

Self-checks (RuntimeError on failure):

  * channel-free serving is bit-for-bit the plain decode loop: the
    engine's tokens equal a manual eager ``prefill``+``decode_step``
    reference, request by request,
  * one fused dispatch per decode tick (``dispatch_counts()["tick"]``
    equals the tick count of every run),
  * zero recompiles across channel quality: one trace serves every OCS
    ``p_miss`` point including the near/far mix,
  * the error-free OCS point decodes the same tokens as an ideal
    ``Protocol.ideal_max(bits)`` run (protocol at ``p_miss=0`` == ideal
    max, through the whole serving stack).

``--bench-json PATH`` (or ``bench_json_path=``) emits the numbers as
``BENCH_serve.json``; ``benchmarks/run.py`` writes the canonical copy at
the repo root on full (non ``--fast``) runs.

  PYTHONPATH=src python -m benchmarks.bench_serve           # full
  PYTHONPATH=src python -m benchmarks.bench_serve --smoke   # CI tier-1
"""

from __future__ import annotations

import dataclasses
import json
import sys
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import analysis
from repro.configs import get_reduced
from repro.models import model as M
from repro.parallel import sharding as sh
from repro.protocol import Protocol
from repro.serve import engine as se
from repro.serve.engine import ChannelClock, ServeConfig, ServeEngine
from repro.serve.load import near_far_protocol, poisson_requests


@dataclasses.dataclass(frozen=True)
class BenchConfig:
    d_model: int = 32
    d_ff: int = 64
    vocab_size: int = 64
    n_workers: int = 4
    n_requests: int = 24
    rate_per_tick: float = 2.0
    prompt_len: int = 6
    max_new_tokens: int = 8
    batch_slots: int = 4
    max_seq: int = 48
    bits: int = 8
    ocs_p_miss: tuple = (0.0, 0.05, 0.2)
    p_far: float = 0.2


def _smoke_config() -> BenchConfig:
    return BenchConfig()


def _full_config() -> BenchConfig:
    return BenchConfig(d_model=64, vocab_size=128, n_requests=200,
                       rate_per_tick=1.5, prompt_len=8, max_new_tokens=16,
                       batch_slots=8, max_seq=64,
                       ocs_p_miss=(0.0, 0.02, 0.05, 0.1, 0.2))


def _build(bc: BenchConfig):
    cfg = get_reduced("qwen1.5-0.5b", n_layers=2, d_model=bc.d_model,
                      n_heads=2, n_kv_heads=2, d_ff=bc.d_ff,
                      vocab_size=bc.vocab_size, n_workers=bc.n_workers)
    m = M.build(cfg)
    values, _ = sh.split_tree(m.init(jax.random.PRNGKey(0)))
    return cfg, m, values


def _reference_tokens(m, values, requests, bc: BenchConfig):
    """Manual per-request decode loop — the serving engine's channel-free
    tokens must match this bit for bit (continuous batching and the fused
    tick must not perturb the decode).  The step functions are jitted once
    (an eager ``decode_step`` re-traces its inner scan every call, which
    accumulates a fresh compiled program per decode step)."""
    prefill = jax.jit(
        lambda v, t: m.prefill(v, {"tokens": t}, max_seq=bc.max_seq))
    decode = jax.jit(m.decode_step)
    out = {}
    for req in requests:
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache = prefill(values, tokens)
        tok = int(jnp.argmax(logits, -1)[0])
        toks = [tok]
        pos = len(req.prompt)
        budget = req.max_new_tokens - 1
        while tok != -1 and budget > 0 and pos < bc.max_seq - 1:
            logits, cache = decode(
                values, jnp.asarray([[tok]], jnp.int32),
                jnp.asarray([pos], jnp.int32), cache)
            tok = int(jnp.argmax(logits, -1)[0])
            toks.append(tok)
            pos += 1
            budget -= 1
        out[req.rid] = toks
    return out


def _serve_point(engine: ServeEngine, requests, protocol, clock):
    """One channel point: run to completion, return (outs, stats-dict)."""
    se.reset_dispatch_counts()
    t0 = time.perf_counter()
    outs = engine.run(requests, protocol=protocol)
    wall = time.perf_counter() - t0
    ticks = se.dispatch_counts()["tick"]
    gen_tokens = sum(len(c.tokens) for c in outs.values())
    lat_us = np.array([c.latency_us(clock) for c in outs.values()])
    slots = sum(c.channel_slots for c in outs.values())
    bits = sum(c.uplink_bits for c in outs.values())
    return outs, {
        "wall_s": wall,
        "ticks": ticks,
        "tokens": gen_tokens,
        "tokens_per_sec": gen_tokens / wall,
        "p50_latency_us": float(np.percentile(lat_us, 50)),
        "p99_latency_us": float(np.percentile(lat_us, 99)),
        "channel_slots": int(slots),
        "uplink_bits": int(bits),
    }


def _check_dispatch(name: str, outs, stats: dict, batch_slots: int) -> None:
    """One fused dispatch per decode tick — the shared ``repro.analysis``
    bracket: every dispatch decodes >=1 active slot (the engine never
    dispatches an empty batch) and <= batch_slots tokens."""
    decode_tokens = sum(len(c.tokens) - 1 for c in outs.values())
    analysis.assert_tick_dispatch_bracket(name, decode_tokens,
                                          stats["ticks"], batch_slots)


def run(smoke: bool = False,
        bench_json_path: Optional[str] = None) -> List[str]:
    bc = _smoke_config() if smoke else _full_config()
    cfg, m, values = _build(bc)
    clock = ChannelClock(tick_us=50.0, slot_us=1.0)
    config = ServeConfig(batch_slots=bc.batch_slots, max_seq=bc.max_seq,
                         eos_id=-1, greedy=True, clock=clock, seed=0)
    engine = ServeEngine(m, values, config)
    requests = poisson_requests(bc.n_requests, bc.rate_per_tick,
                                bc.vocab_size, prompt_len=bc.prompt_len,
                                max_new_tokens=bc.max_new_tokens, seed=0)
    n_workers = cfg.n_workers
    sites = m.channel_sites()

    rows: List[str] = []
    points = {}

    # warm the channel-free tick so timed points measure the engine, not
    # one-off compiles (the channel tick warms inside the traced-sweep
    # check below — its first point doubles as the warmup)
    warm = requests[:min(4, len(requests))]
    engine.run(warm, protocol=None)

    # -- channel-free baseline + bitwise reference check -------------------
    free_outs, free_stats = _serve_point(engine, requests, None, clock)
    _check_dispatch("free", free_outs, free_stats, bc.batch_slots)
    ref = _reference_tokens(m, values, requests, bc)
    for rid, toks in ref.items():
        if free_outs[rid].tokens != toks:
            raise RuntimeError(
                f"channel-free serving diverged from the plain decode loop "
                f"for request {rid}: {free_outs[rid].tokens} != {toks}")
    if any(c.channel_slots or c.uplink_bits for c in free_outs.values()):
        raise RuntimeError(
            "channel-free serving billed channel airtime/uplink bits")
    points["free"] = free_stats

    # -- OCS quality sweep: one compiled tick across every p_miss ----------
    # ONE compile serves the whole sweep (warm + every p_miss point + the
    # near/far mix): only the traced p_miss leaf changes between runs
    se.reset_trace_counts()
    engine.run(warm, protocol=Protocol.ocs(
        bits=bc.bits, p_miss=np.zeros((n_workers,), np.float32)))
    ocs_outs = {}
    for p in bc.ocs_p_miss:
        proto = Protocol.ocs(
            bits=bc.bits,
            p_miss=np.full((n_workers,), p, np.float32))
        name = f"ocs_p{p:g}"
        ocs_outs[p], stats = _serve_point(engine, requests, proto, clock)
        _check_dispatch(name, ocs_outs[p], stats, bc.batch_slots)
        points[name] = stats
    nf = near_far_protocol(n_workers, bits=bc.bits, p_near=0.0,
                           p_far=bc.p_far)
    nf_outs, nf_stats = _serve_point(engine, requests, nf, clock)
    _check_dispatch("near_far", nf_outs, nf_stats, bc.batch_slots)
    points[f"near_far_p{bc.p_far:g}"] = nf_stats
    traces = se.trace_counts()["tick"]
    if traces != 1:
        raise RuntimeError(
            f"channel sweep recompiled: {traces} traces across "
            f"{len(bc.ocs_p_miss) + 1} p_miss points — the protocol must "
            "enter the tick as a traced pytree leaf")

    # -- error-free OCS == ideal max through the whole serving stack -------
    assert bc.ocs_p_miss[0] == 0.0
    ideal = Protocol.ideal_max(bc.bits, tie_break="first")
    ideal_outs, _ = _serve_point(engine, requests, ideal, clock)
    for rid in ideal_outs:
        if ocs_outs[0.0][rid].tokens != ideal_outs[rid].tokens:
            raise RuntimeError(
                f"OCS p_miss=0 decoded different tokens than ideal max for "
                f"request {rid} — the protocol-outcome pooling must be "
                "bit-for-bit ideal when nothing is missed")

    # analytic uplink bill: comm_load per aggregate x sites x decoded tokens
    p0 = Protocol.ocs(bits=bc.bits,
                      p_miss=np.zeros((n_workers,), np.float32))
    per_tok = p0.comm_load(n_workers, cfg.d_model).uplink_bits * sites
    want = sum((len(c.tokens) - 1) * per_tok
               for c in ocs_outs[0.0].values())
    got = sum(c.uplink_bits for c in ocs_outs[0.0].values())
    if got != want:
        raise RuntimeError(
            f"uplink accounting off: billed {got} bits, analytic {want}")

    for name, s in points.items():
        rows.append(
            f"serve/{name},{s['wall_s'] / max(s['ticks'], 1) * 1e6:.0f},"
            f"tokens_per_sec={s['tokens_per_sec']:.1f};"
            f"p50_latency_us={s['p50_latency_us']:.0f};"
            f"p99_latency_us={s['p99_latency_us']:.0f};"
            f"ticks={s['ticks']};channel_slots={s['channel_slots']};"
            f"uplink_bits={s['uplink_bits']}")
    rows.append(
        f"serve/meta,0,requests={bc.n_requests};slots={bc.batch_slots};"
        f"points={len(points)};traces={traces};"
        f"free_bitwise_plain_decode=1;p0_matches_ideal=1")

    if bench_json_path:
        bench = {
            "bench": "serve",
            "smoke": smoke,
            "load": {"n_requests": bc.n_requests,
                     "rate_per_tick": bc.rate_per_tick,
                     "prompt_len": bc.prompt_len,
                     "max_new_tokens": bc.max_new_tokens},
            "engine": {"batch_slots": bc.batch_slots,
                       "max_seq": bc.max_seq,
                       "d_model": bc.d_model,
                       "n_workers": n_workers,
                       "channel_sites": sites,
                       "tick_us": clock.tick_us,
                       "slot_us": clock.slot_us},
            "points": {k: {kk: (round(vv, 3) if isinstance(vv, float)
                               else vv) for kk, vv in v.items()}
                       for k, v in points.items()},
            "traces_across_sweep": traces,
            "free_bitwise_plain_decode": True,
            "p0_matches_ideal": True,
        }
        with open(bench_json_path, "w") as f:
            json.dump(bench, f, indent=2, sort_keys=True)
            f.write("\n")
    return rows


if __name__ == "__main__":
    argv = sys.argv[1:]
    bench_json = None
    if "--bench-json" in argv:
        i = argv.index("--bench-json")
        if i + 1 >= len(argv) or argv[i + 1].startswith("--"):
            sys.exit("usage: bench_serve [--smoke] [--bench-json PATH]")
        bench_json = argv[i + 1]
        argv = argv[:i] + argv[i + 2:]
    for r in run(smoke="--smoke" in argv, bench_json_path=bench_json):
        print(r)
