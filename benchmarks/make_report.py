"""Render EXPERIMENTS.md tables from artifacts (dryrun + hillclimb).

Usage: PYTHONPATH=src:. python benchmarks/make_report.py > report_tables.md
"""

from __future__ import annotations

import glob
import json
import os

DRY = "artifacts/dryrun"
HILL = "artifacts/hillclimb"


def _load(pattern):
    out = {}
    for p in sorted(glob.glob(pattern)):
        rec = json.load(open(p))
        out[os.path.basename(p)[:-5]] = rec
    return out


def dryrun_summary():
    recs = _load(f"{DRY}/*.json")
    ok = [r for r in recs.values() if r["status"] == "ok"]
    skipped = [r for r in recs.values() if r["status"] == "skipped"]
    err = [r for r in recs.values() if r["status"] == "error"]
    lines = [f"- cells: {len(recs)} ({len(ok)} compiled ok, "
             f"{len(skipped)} skipped per assignment rules, {len(err)} errors)"]
    comp = [r.get("compile_s", 0) for r in ok]
    if comp:
        lines.append(f"- compile time: median "
                     f"{sorted(comp)[len(comp)//2]:.1f}s, max {max(comp):.1f}s"
                     " (single CPU core, 512-way SPMD partitioning)")
    for r in skipped:
        lines.append(f"  - SKIP {r['arch']} x {r['shape']} ({r['mesh']}): "
                     f"{r['reason']}")
    return "\n".join(lines)


def memory_table():
    rows = ["| arch | shape | mesh | state GB/dev (params+opt+batch) "
            "| XLA:CPU temps GB/dev (upper bound; no TPU remat planner) "
            "| state fits 16GB |",
            "|---|---|---|---|---|---|"]
    for name, r in sorted(_load(f"{DRY}/*.json").items()):
        if r["status"] != "ok" or "train" not in r["shape"]:
            continue
        mem = r.get("memory", {})
        a = mem.get("argument_size_in_bytes", 0) / 2**30
        t = mem.get("temp_size_in_bytes", 0) / 2**30
        fits = "yes" if a < 16 else "**NO** (multi-pod required)"
        rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                    f"| {a:.1f} | {t:.1f} | {fits} |")
    return "\n".join(rows)


def roofline_table():
    import bench_roofline as BR
    return BR.table("sp")


def hillclimb_table():
    rows = ["| cell | variant | t_compute | t_memory | t_collective "
            "| bottleneck | Δ collective | Δ memory |",
            "|---|---|---|---|---|---|---|---|"]
    base = {}
    for name, r in sorted(_load(f"{DRY}/*train_4k__sp__max.json").items()):
        if r["status"] == "ok":
            base[r["arch"]] = r
    order = []
    for name, r in sorted(_load(f"{HILL}/*.json").items()):
        if r["status"] != "ok":
            continue
        order.append(r)
    for r in [*base.values(), *order]:
        rl = r["roofline"]
        arch = r["arch"]
        var = r.get("variant", "baseline(max)")
        b = base.get(arch)
        dc = dm = ""
        if b is not None and "variant" in r:
            bl = b["roofline"]
            dc = (f"{(rl['t_collective_s'] - bl['t_collective_s']) / bl['t_collective_s']:+.0%}")
            dm = (f"{(rl['t_memory_s'] - bl['t_memory_s']) / bl['t_memory_s']:+.0%}")
        rows.append(f"| {arch}/train_4k | {var} | {rl['t_compute_s']:.2f} "
                    f"| {rl['t_memory_s']:.2f} | {rl['t_collective_s']:.2f} "
                    f"| {rl['bottleneck']} | {dc} | {dm} |")
    return "\n".join(rows)


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.dirname(__file__))
    print("### Dry-run summary\n")
    print(dryrun_summary())
    print("\n### Train-cell memory (per device)\n")
    print(memory_table())
    print("\n### Roofline (single-pod)\n")
    print(roofline_table())
    print("\n### Hillclimb variants\n")
    print(hillclimb_table())
