"""Quickstart: FedOCS vertical distributed learning in ~30 lines.

Four workers observe noisy views of the same signal; embeddings are fused by
max-pooling (paper Eq. 4) and only argmax winners would transmit over the
shared channel (O(K) uplink).  Runs in ~20 s on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import vertical
from repro.core.vertical import VerticalConfig
from repro.data.vertical_data import multiview_denoising
from repro.optim import optimizers, schedules
from repro.protocol import Protocol


def main():
    views, clean = multiview_denoising(512, n_workers=4, hw=16, sigma=2.0)
    # the fusion protocol is a first-class value: max-pool over the shared
    # channel (paper Eq. 4); swap in Protocol.ocs(bits, p_miss) to train
    # with the noisy contention channel in the loop
    cfg = VerticalConfig(n_workers=4, input_dim=256, encoder_dims=(128,),
                         embed_dim=32, head_dims=(128,), output_dim=256,
                         task="reconstruction", aggregation=Protocol.max())
    params = vertical.init(cfg, jax.random.PRNGKey(0))
    opt = optimizers.adamw(schedules.constant(2e-3))
    state = opt.init(params)

    views_j, clean_j = jnp.asarray(views), jnp.asarray(clean)

    @jax.jit
    def step(params, state, vb, cb):
        loss, g = jax.value_and_grad(
            lambda p: vertical.loss_fn(cfg, p, vb, cb)[0])(params)
        params, state, _ = opt.update(g, state, params)
        return params, state, loss

    rng = np.random.default_rng(0)
    for i in range(200):
        idx = rng.integers(0, 512, 64)
        params, state, loss = step(params, state, views_j[:, idx],
                                   clean_j[idx])
        if i % 50 == 0:
            print(f"step {i:4d}  mse {float(loss):.4f}")

    load = cfg.resolve_protocol().comm_load(cfg.n_workers, cfg.embed_dim)
    concat_load = Protocol.concat().comm_load(cfg.n_workers, cfg.embed_dim)
    print(f"\nuplink: {load.uplink_payload_msgs} msgs/sample "
          f"(concat would need {concat_load.uplink_payload_msgs})")
    print("done.")


if __name__ == "__main__":
    main()
