"""End-to-end LM training driver through the full production stack:
config -> model -> data pipeline -> AdamW(+schedule) -> trainer with
checkpoint/auto-resume and FedOCS max-pool TP fusion.

Presets:
  demo    ~4M params, 200 steps  — runs in a few minutes on this CPU host
  100m    ~100M params, 300 steps — the deliverable-scale run (use a real
          machine; identical code path, just bigger dims)

  PYTHONPATH=src python examples/lm_train.py --preset demo
  PYTHONPATH=src python examples/lm_train.py --preset 100m --steps 300
"""

import argparse

import jax

from repro.configs import get_reduced
from repro.data import pipeline
from repro.models import model as M
from repro.optim import optimizers, schedules
from repro.parallel.sharding import split_tree
from repro.train import trainer
from repro.train.trainer import TrainerConfig

PRESETS = {
    "demo": dict(n_layers=4, d_model=128, n_heads=8, n_kv_heads=4,
                 d_ff=512, vocab_size=2048, batch=16, seq=64),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_ff=2048, vocab_size=32768, batch=32, seq=512),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=tuple(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fusion", default="max",
                    help="tp_fusion: sum|max|max_q8|concat")
    ap.add_argument("--ckpt-dir", default="/tmp/fedocs_lm_ckpt")
    ap.add_argument("--compress", type=float, default=None,
                    help="top-k gradient compression fraction (e.g. 0.0625)")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = get_reduced(
        "glm4-9b", n_layers=p["n_layers"], d_model=p["d_model"],
        n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"], d_ff=p["d_ff"],
        vocab_size=p["vocab_size"], tp_fusion=args.fusion, n_workers=2)
    m = M.build(cfg)
    print(f"arch=glm4-family preset={args.preset} "
          f"params={cfg.param_count() / 1e6:.1f}M fusion={cfg.tp_fusion}")

    values, _ = split_tree(m.init(jax.random.PRNGKey(0)))
    pcfg = pipeline.for_model(cfg, batch=p["batch"], seq_len=p["seq"])
    opt = optimizers.adamw(
        schedules.for_arch("glm4-9b", 3e-3, args.steps), weight_decay=0.01)
    tcfg = TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=100, log_every=20,
                         compress_k=args.compress)
    res = trainer.train(m.loss, values, opt,
                        lambda s: pipeline.batch_for_step(pcfg, s), tcfg)
    for row in res.history:
        print(f"step {row['step']:5d}  nll {row.get('nll', 0):7.4f}  "
              f"lr {row.get('lr', 0):.2e}  {row['step_time_s']:.2f}s/step")
    print(f"final nll: {res.history[-1]['nll']:.4f} "
          f"(start {res.history[0]['nll']:.4f})")


if __name__ == "__main__":
    main()
