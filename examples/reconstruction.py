"""Paper §IV-A: multi-sensor denoising reconstruction (Fig. 2 analogue).

N sensors observe the same image under independent Gaussian noise (sigma=2);
encoders (512-256-128 -> K=64) + decoder (128-256-512) as in the paper.
Compares 1 worker vs N workers at identical per-sensor channel use.

  PYTHONPATH=src python examples/reconstruction.py --workers 4 --steps 400
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import vertical
from repro.core.vertical import VerticalConfig
from repro.data.vertical_data import multiview_denoising
from repro.optim import optimizers, schedules


def train(n_workers: int, steps: int, hw: int = 28, seed: int = 0):
    views, clean = multiview_denoising(2048, n_workers=n_workers, hw=hw,
                                       sigma=2.0, seed=0)
    v_views, v_clean = multiview_denoising(256, n_workers=n_workers, hw=hw,
                                           sigma=2.0, seed=7)
    cfg = VerticalConfig(
        n_workers=n_workers, input_dim=hw * hw,
        encoder_dims=(512, 256, 128), embed_dim=64,
        head_dims=(128, 256, 512), output_dim=hw * hw,
        task="reconstruction", aggregation="max")
    params = vertical.init(cfg, jax.random.PRNGKey(seed))
    opt = optimizers.adamw(schedules.linear_warmup_cosine(2e-3, 20, steps))
    state = opt.init(params)
    views_j, clean_j = jnp.asarray(views), jnp.asarray(clean)

    @jax.jit
    def step(params, state, vb, cb):
        loss, g = jax.value_and_grad(
            lambda p: vertical.loss_fn(cfg, p, vb, cb)[0])(params)
        params, state, _ = opt.update(g, state, params)
        return params, state, loss

    rng = np.random.default_rng(seed)
    for i in range(steps):
        idx = rng.integers(0, views.shape[1], 64)
        params, state, loss = step(params, state, views_j[:, idx],
                                   clean_j[idx])
        if i % 100 == 0:
            print(f"[N={n_workers}] step {i:4d}  train mse {float(loss):.4f}")
    _, m = vertical.loss_fn(cfg, params, jnp.asarray(v_views),
                            jnp.asarray(v_clean))
    print(f"[N={n_workers}] validation NLL {float(m['nll']):.4f}")
    return float(m["nll"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args()
    nll_1 = train(1, args.steps)
    nll_n = train(args.workers, args.steps)
    print(f"\nfusion gain: NLL {nll_1:.4f} (1 worker) -> {nll_n:.4f} "
          f"({args.workers} workers)  [paper: 0.19 -> 0.13]")


if __name__ == "__main__":
    main()
