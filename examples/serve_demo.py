"""Batched serving demo: train a tiny LM briefly, then serve a stream of
requests through the slot-based continuous-batching engine
(prefill -> decode ticks -> retire/refill).

  PYTHONPATH=src python examples/serve_demo.py --requests 8 --slots 4
"""

import argparse

import jax
import numpy as np

from repro.configs import get_reduced
from repro.data import pipeline
from repro.models import model as M
from repro.optim import optimizers, schedules
from repro.parallel.sharding import split_tree
from repro.serve.engine import Request, ServeEngine
from repro.train import trainer
from repro.train.trainer import TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced("qwen1.5-0.5b", n_layers=2, d_model=128, n_heads=4,
                      n_kv_heads=4, d_ff=256, vocab_size=512, n_workers=2)
    m = M.build(cfg)
    values, _ = split_tree(m.init(jax.random.PRNGKey(0)))

    # brief training so generations follow the synthetic-language structure
    pcfg = pipeline.for_model(cfg, batch=16, seq_len=64)
    opt = optimizers.adamw(schedules.constant(3e-3))
    res = trainer.train(
        m.loss, values, opt, lambda s: pipeline.batch_for_step(pcfg, s),
        TrainerConfig(steps=args.train_steps, ckpt_dir=None, log_every=20))
    print(f"trained {args.train_steps} steps, "
          f"nll {res.history[0]['nll']:.3f} -> {res.history[-1]['nll']:.3f}")

    engine = ServeEngine(m, res.values, batch_slots=args.slots, max_seq=128,
                         eos_id=-1)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, 512, 8).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    outs = engine.run(reqs)
    for rid in sorted(outs):
        c = outs[rid]
        print(f"request {rid}: prompt_len={c.prompt_len} "
              f"generated={c.tokens}")
    print(f"served {len(outs)} requests on {args.slots} slots.")


if __name__ == "__main__":
    main()
