"""Batched serving demo: train a tiny LM briefly, then serve a stream of
requests through the slot-based continuous-batching engine
(prefill -> decode ticks -> retire/refill) — first channel-free, then with
the simulated OCS wireless channel inside every decode tick (same engine,
same compiled tick per structure; the channel run reports the airtime and
uplink bill each completion carries).

  PYTHONPATH=src python examples/serve_demo.py --requests 8 --slots 4
"""

import argparse

import jax
import numpy as np

from repro.configs import get_reduced
from repro.data import pipeline
from repro.models import model as M
from repro.optim import optimizers, schedules
from repro.parallel.sharding import split_tree
from repro.protocol import Protocol
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.train import trainer
from repro.train.trainer import TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--train-steps", type=int, default=60)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--p-miss", type=float, default=0.1,
                    help="sensing-miss probability for the channel run")
    args = ap.parse_args()

    cfg = get_reduced("qwen1.5-0.5b", n_layers=2, d_model=128, n_heads=4,
                      n_kv_heads=4, d_ff=256, vocab_size=512, n_workers=2)
    m = M.build(cfg)
    values, _ = split_tree(m.init(jax.random.PRNGKey(0)))

    # brief training so generations follow the synthetic-language structure
    pcfg = pipeline.for_model(cfg, batch=16, seq_len=64)
    opt = optimizers.adamw(schedules.constant(3e-3))
    res = trainer.train(
        m.loss, values, opt, lambda s: pipeline.batch_for_step(pcfg, s),
        TrainerConfig(steps=args.train_steps, ckpt_dir=None, log_every=20))
    print(f"trained {args.train_steps} steps, "
          f"nll {res.history[0]['nll']:.3f} -> {res.history[-1]['nll']:.3f}")

    config = ServeConfig(batch_slots=args.slots, max_seq=128, eos_id=-1)
    engine = ServeEngine(m, res.values, config)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, 512, 8).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    outs = engine.run(reqs)
    for rid in sorted(outs):
        c = outs[rid]
        print(f"request {rid}: prompt_len={c.prompt_len} "
              f"generated={c.tokens}")
    print(f"served {len(outs)} requests on {args.slots} slots.")

    # same engine, channel in the loop: every mlp-FFN fusion aggregates
    # over the simulated OCS channel, and completions bill the airtime
    proto = Protocol.ocs(bits=8, p_miss=np.full(
        (cfg.n_workers,), args.p_miss, np.float32))
    chan_outs = engine.run(reqs, protocol=proto)
    for rid in sorted(chan_outs):
        c = chan_outs[rid]
        print(f"request {rid} under p_miss={args.p_miss}: "
              f"latency={c.latency_us(config.clock):.0f}us "
              f"({c.latency_ticks} ticks + {c.channel_slots} slots), "
              f"uplink={c.uplink_bits} bits")


if __name__ == "__main__":
    main()
