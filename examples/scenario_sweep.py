"""Sweep the OCS protocol across wireless scenarios in one compiled dispatch.

Evaluates every registered named scenario plus a workers x miss-probability
grid with the batched engine (``repro.sim``), then prints the merged
measured/analytic table and writes it as JSON.  The whole grid costs one
compilation per backoff depth (``bits``) — add as many cells as you like.

  PYTHONPATH=src python examples/scenario_sweep.py [out.json]
"""

import sys

from repro.sim import results, scenarios, sweep


def main():
    cells = [scenarios.get(n) for n in scenarios.names()]
    cells += scenarios.scenario_grid(
        n_workers=(4, 16, 64), bits=(8, 16), p_miss=(0.0, 0.02, 0.1))

    sweep.reset_trace_counts()
    sw = sweep.run_sweep(cells, k_elems=64, rounds=4)
    records = results.summarize(sw)

    for row in results.to_rows(records):
        print(row)
    traces = sweep.trace_counts()
    print(f"# {len(cells)} cells, compilations: clean={traces['clean']} "
          f"noisy={traces['noisy']}")

    if len(sys.argv) > 1:
        results.write_json(records, sys.argv[1])
        print(f"# wrote {sys.argv[1]}")


if __name__ == "__main__":
    main()
