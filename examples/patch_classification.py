"""Paper §IV-B: classification from patch grids (Table I analogue).

Workers observe disjoint cells of a global image; the fusion center
classifies from aggregated embeddings.  ``--method`` selects one of the
paper's five rows.

  PYTHONPATH=src python examples/patch_classification.py --method fedocs
  PYTHONPATH=src python examples/patch_classification.py --method all
"""

import argparse

from benchmarks.bench_table1 import run as bench_run
from repro.core import aggregators


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--method", default="fedocs",
                    choices=aggregators.TABLE1_METHODS + ("all",))
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()
    rows = bench_run(steps=args.steps)
    for r in rows:
        name = r.split(",", 1)[0]
        if args.method == "all" or f"/{args.method}/" in name:
            print(r)


if __name__ == "__main__":
    main()
