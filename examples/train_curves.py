"""Train accuracy-vs-channel-quality curves with the OCS channel in the loop.

The paper's end-to-end claim, produced by one command: the vertical learner's
embeddings are fused through the *simulated* noisy-OCS channel (quantized
D-bit contention, miss detection, lowest-index capture), and the whole
``p_miss`` axis trains as vmap lanes of a single compiled train step per
``bits`` value.  An ideal ``max_q{bits}`` reference trains alongside; the
``p_miss=0`` lane reproduces it bit for bit.

A ``CollisionAdaptiveBits`` schedule then re-trains the same lanes with the
backoff depth re-chosen per round from the protocol's own collision
telemetry (the ``repro.protocol.BitsSchedule`` policy hook) — the whole
scheduled run is still ONE compiled dispatch.

  PYTHONPATH=src python examples/train_curves.py [out.json]
"""

import json
import sys

import numpy as np

from repro.protocol import CollisionAdaptiveBits
from repro.sim import results, train_curves as tc


def main():
    ccfg = tc.CurveConfig(bits=(8, 16), p_miss=(0.0, 0.02, 0.05, 0.1, 0.2),
                          steps=600, batch=64, n_train=8192, n_val=512,
                          hw=32, encoder_dims=(128, 64), embed_dim=32,
                          head_dims=(128, 64))
    tc.reset_trace_counts()
    tc.reset_dispatch_counts()
    curves = tc.run_curves(ccfg)
    records = results.summarize_curves(curves)

    print("# accuracy vs p_miss (channel-in-the-loop training)")
    for row in results.curve_rows(records):
        print(row)
    traces, disp = tc.trace_counts(), tc.dispatch_counts()
    print(f"# {len(ccfg.bits)} bit depths x {len(ccfg.p_miss)} p_miss lanes, "
          f"fused scan engine: {traces['fused']} compilations, "
          f"{disp['fused']} dispatches")

    # channel-aware backoff-depth scheduling: pick D per round from the
    # observed collision fraction, all candidates fused into one dispatch
    sched = tc.run_scheduled_curves(ccfg, CollisionAdaptiveBits(ccfg.bits))
    switches = int((sched.bits_per_step[1:] != sched.bits_per_step[:-1]).sum())
    print(f"# CollisionAdaptiveBits{tuple(ccfg.bits)}: "
          f"start b{sched.bits_per_step[0]}, final b{sched.bits_per_step[-1]}, "
          f"{switches} switches, acc {np.round(sched.acc, 4).tolist()} "
          f"({tc.dispatch_counts()['sched']} dispatch)")

    if len(sys.argv) > 1:
        with open(sys.argv[1], "w") as f:
            json.dump(records, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {sys.argv[1]}")


if __name__ == "__main__":
    main()
