"""Block and stack composition: (mixer x ffn) blocks, scanned over periods.

A config's layer plan is a cyclic pattern of ``(mixer, ffn)`` pairs
(``ModelConfig.layer_plan``); the stack scans over ``n_periods`` repetitions
with one parameter subtree per position in the period.  Heterogeneous
interleaves (jamba's 7:1 mamba:attn with alternating MoE, xlstm's
mLSTM/sLSTM mix) thus still lower to a single compact ``lax.scan`` —
essential for keeping 72-layer HLO small enough to compile 512-way SPMD
on the dry-run host.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, fusion, layers, mamba, mlp, moe, ssm
from repro.parallel.sharding import Tagged, retag_stacked, constrain


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def block_init(cfg, rng, mixer: str, ffn: str, cross: bool = False) -> dict:
    r = layers.rsplit(rng, 5)
    p: Dict[str, Any] = {"norm1": layers.norm_init(cfg, r[0])}
    if mixer in ("attn", "attn_nocausal"):
        p["mixer"] = attention.attn_init(cfg, r[1])
    elif mixer == "mamba":
        p["mixer"] = mamba.mamba_init(cfg, r[1])
    elif mixer == "mlstm":
        p["mixer"] = ssm.mlstm_init(cfg, r[1])
    elif mixer == "slstm":
        p["mixer"] = ssm.slstm_init(cfg, r[1])
    else:
        raise ValueError(mixer)
    if cross:
        p["norm_cross"] = layers.norm_init(cfg, r[2])
        p["cross"] = attention.attn_init(cfg, r[2], cross=True)
    if ffn == "mlp":
        p["norm2"] = layers.norm_init(cfg, r[3])
        p["ffn"] = mlp.mlp_init(cfg, r[4])
    elif ffn == "moe":
        p["norm2"] = layers.norm_init(cfg, r[3])
        p["ffn"] = moe.moe_init(cfg, r[4])
    return p


def _apply_mixer_full(cfg, p, x, positions, mixer, enc_out):
    if mixer == "attn":
        return attention.attn_full(cfg, p, x, positions, causal=True)
    if mixer == "attn_nocausal":
        return attention.attn_full(cfg, p, x, positions, causal=False)
    if mixer == "mamba":
        return mamba.mamba_full(cfg, p, x)
    if mixer == "mlstm":
        return ssm.mlstm_full(cfg, p, x)
    if mixer == "slstm":
        return ssm.slstm_full(cfg, p, x)
    raise ValueError(mixer)


def block_full(cfg, p: dict, x: jax.Array, positions: jax.Array,
               mixer: str, ffn: str,
               enc_out: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, jax.Array]:
    """Training / prefill block. Returns (x, aux_loss)."""
    h = layers.norm_apply(cfg, p["norm1"], x)
    x = x + _apply_mixer_full(cfg, p["mixer"], h, positions, mixer, enc_out)
    if "cross" in p:
        h = layers.norm_apply(cfg, p["norm_cross"], x)
        x = x + attention.attn_full(cfg, p["cross"], h, positions,
                                    causal=False, kv_x=enc_out)
    aux = jnp.zeros((), jnp.float32)
    if ffn == "mlp":
        h = layers.norm_apply(cfg, p["norm2"], x)
        x = x + mlp.mlp_apply(cfg, p["ffn"], h)
    elif ffn == "moe":
        h = layers.norm_apply(cfg, p["norm2"], x)
        y, aux = moe.moe_apply(cfg, p["ffn"], h)
        x = x + y
    return x, aux


def block_cache_init(cfg, mixer: str, batch: int, max_seq: int, dtype,
                     cross_len: int = 0) -> dict:
    c: Dict[str, Any] = {}
    if mixer in ("attn", "attn_nocausal"):
        c["self"] = attention.init_cache(cfg, batch, max_seq, dtype)
    elif mixer == "mamba":
        c["self"] = mamba.init_cache(cfg, batch, dtype)
    elif mixer == "mlstm":
        c["self"] = ssm.mlstm_state_init(cfg, batch)
    elif mixer == "slstm":
        c["self"] = ssm.slstm_state_init(cfg, batch)
    if cross_len:
        c["cross"] = attention.init_cache(cfg, batch, cross_len, dtype)
    return c


def block_cache_axes(cfg, mixer: str, has_cross: bool) -> dict:
    c: Dict[str, Any] = {}
    if mixer in ("attn", "attn_nocausal"):
        c["self"] = dict(attention.CACHE_AXES)
    elif mixer == "mamba":
        c["self"] = dict(mamba.MAMBA_CACHE_AXES)
    elif mixer == "mlstm":
        c["self"] = ssm.MLSTM_CACHE_AXES
    elif mixer == "slstm":
        c["self"] = ssm.SLSTM_CACHE_AXES
    if has_cross:
        c["cross"] = dict(attention.CACHE_AXES)
    return c


def block_step(cfg, p: dict, x: jax.Array, positions: jax.Array,
               cache: dict, mixer: str, ffn: str,
               protocol=None, rng=None):
    """Decode step. x: (B,1,d). Returns (x, cache, aux).

    With a ``protocol`` the FFN's worker-partial fusion routes through the
    simulated channel (``mlp_apply(protocol=, rng=)``) and the return grows
    a fourth element — the channel-accounting dict of this block's fusion
    site (``fusion.chan_zeros()`` for non-mlp FFNs; mixer fusions stay on
    the ideal ``tp_fusion`` collective).  With ``protocol=None`` the ops
    and the 3-tuple return are the historical path, unchanged.
    """
    h = layers.norm_apply(cfg, p["norm1"], x)
    new_cache = dict(cache)
    if mixer in ("attn", "attn_nocausal"):
        out, new_cache["self"] = attention.attn_step(
            cfg, p["mixer"], h, positions, cache["self"])
    elif mixer == "mamba":
        out, new_cache["self"] = mamba.mamba_step(cfg, p["mixer"], h,
                                                  cache["self"])
    elif mixer == "mlstm":
        out, new_cache["self"] = ssm.mlstm_step(cfg, p["mixer"], h,
                                                cache["self"])
    elif mixer == "slstm":
        out, new_cache["self"] = ssm.slstm_step(cfg, p["mixer"], h,
                                                cache["self"])
    else:
        raise ValueError(mixer)
    x = x + out
    if "cross" in p:
        h = layers.norm_apply(cfg, p["norm_cross"], x)
        out, _ = attention.attn_step(cfg, p["cross"], h, positions,
                                     cache["cross"], cross=True)
        x = x + out
    aux = jnp.zeros((), jnp.float32)
    chan = None if protocol is None else fusion.chan_zeros()
    if ffn == "mlp":
        h = layers.norm_apply(cfg, p["norm2"], x)
        if protocol is None:
            x = x + mlp.mlp_apply(cfg, p["ffn"], h)
        else:
            y, acct = mlp.mlp_apply(cfg, p["ffn"], h, protocol=protocol,
                                    rng=rng)
            x = x + y
            chan = fusion.chan_from_acct(acct)
    elif ffn == "moe":
        h = layers.norm_apply(cfg, p["norm2"], x)
        y, aux = moe.moe_apply(cfg, p["ffn"], h)
        x = x + y
    if protocol is None:
        return x, new_cache, aux
    return x, new_cache, aux, chan


def block_prefill(cfg, p: dict, x: jax.Array, positions: jax.Array,
                  mixer: str, ffn: str, max_seq: int,
                  enc_out: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, dict, jax.Array]:
    """Full-sequence forward that also materializes the decode cache."""
    h = layers.norm_apply(cfg, p["norm1"], x)
    cache: Dict[str, Any] = {}
    if mixer in ("attn", "attn_nocausal"):
        out, kv = attention.attn_full(cfg, p["mixer"], h, positions,
                                      causal=(mixer == "attn"),
                                      return_kv=True)
        if max_seq > kv["k"].shape[1]:
            buf = attention.init_cache(cfg, x.shape[0], max_seq, cfg.dtype)
            kv = jax.tree.map(
                lambda b, new: jax.lax.dynamic_update_slice(
                    b, new, (0, 0, 0, 0)), buf, kv)
        cache["self"] = kv
    elif mixer == "mamba":
        out, cache["self"] = mamba.mamba_full(cfg, p["mixer"], h,
                                              return_cache=True)
    elif mixer == "mlstm":
        out, cache["self"] = ssm.mlstm_full(cfg, p["mixer"], h,
                                            return_cache=True)
    elif mixer == "slstm":
        out, cache["self"] = ssm.slstm_full(cfg, p["mixer"], h,
                                            return_cache=True)
    else:
        raise ValueError(mixer)
    x = x + out
    if "cross" in p:
        h = layers.norm_apply(cfg, p["norm_cross"], x)
        out, ckv = attention.attn_full(cfg, p["cross"], h, positions,
                                       causal=False, kv_x=enc_out,
                                       return_kv=True)
        cache["cross"] = ckv
        x = x + out
    aux = jnp.zeros((), jnp.float32)
    if ffn == "mlp":
        h = layers.norm_apply(cfg, p["norm2"], x)
        x = x + mlp.mlp_apply(cfg, p["ffn"], h)
    elif ffn == "moe":
        h = layers.norm_apply(cfg, p["norm2"], x)
        y, aux = moe.moe_apply(cfg, p["ffn"], h)
        x = x + y
    return x, cache, aux


# ---------------------------------------------------------------------------
# stack: scan over periods
# ---------------------------------------------------------------------------

def stack_init(cfg, rng, plan, n_periods: int, cross: bool = False) -> dict:
    def one_period(r):
        rs = layers.rsplit(r, len(plan))
        return {f"pos{i}": block_init(cfg, rs[i], mixer, ffn, cross=cross)
                for i, (mixer, ffn) in enumerate(plan)}

    stacked = jax.vmap(one_period)(jax.random.split(rng, n_periods))
    return retag_stacked(stacked, "layers")


def stack_full(cfg, values: dict, x: jax.Array, positions: jax.Array,
               plan, enc_out: Optional[jax.Array] = None
               ) -> Tuple[jax.Array, jax.Array]:
    """values: stacked plain-array tree; x: (B,S,d). Returns (x, aux)."""

    def body(carry, period_params):
        x, aux = carry
        for i, (mixer, ffn) in enumerate(plan):
            x, a = block_full(cfg, period_params[f"pos{i}"], x, positions,
                              mixer, ffn, enc_out)
            aux = aux + a
        return (x, aux), None

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)
    if cfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                   values)
    else:
        carry = (x, jnp.zeros((), jnp.float32))
        n = jax.tree.leaves(values)[0].shape[0]
        for i in range(n):
            carry, _ = body(carry, jax.tree.map(lambda v: v[i], values))
        x, aux = carry
    return x, aux


def stack_step(cfg, values: dict, x: jax.Array, positions: jax.Array,
               cache: dict, plan, protocol=None, rng=None):
    """Decode step through the whole stack; cache is scanned alongside.

    With a ``protocol`` (+ ``rng``, the tick's sensing key) every mlp-FFN
    fusion site aggregates through the simulated channel: one sensing key
    per period rides the scan as an xs leaf (``jax.random.split`` — a
    fold-in inside the traced body would reuse the key across periods) and
    the per-site accounting dicts accumulate in the carry.  The return then
    grows a fourth element, the summed channel-accounting dict of the whole
    stack; with ``protocol=None`` the scan structure and the 3-tuple return
    are the historical path, unchanged op for op.
    """
    chan_mode = protocol is not None

    def body(carry, xs):
        if chan_mode:
            x, aux, chan = carry
            period_params, period_cache, k = xs
        else:
            x, aux = carry
            period_params, period_cache = xs
        new_cache = {}
        for i, (mixer, ffn) in enumerate(plan):
            key = f"pos{i}"
            if chan_mode:
                x, c, a, ch = block_step(
                    cfg, period_params[key], x, positions, period_cache[key],
                    mixer, ffn, protocol=protocol,
                    rng=jax.random.fold_in(k, i))
                chan = fusion.chan_merge(chan, ch)
            else:
                x, c, a = block_step(cfg, period_params[key], x, positions,
                                     period_cache[key], mixer, ffn)
            new_cache[key] = c
            aux = aux + a
        carry = (x, aux, chan) if chan_mode else (x, aux)
        return carry, new_cache

    n = jax.tree.leaves(values)[0].shape[0]
    init = (x, jnp.zeros((), jnp.float32))
    xs = (values, cache)
    if chan_mode:
        init = init + (fusion.chan_zeros(),)
        xs = xs + (jax.random.split(rng, n),)
    if cfg.scan_layers:
        carry, new_cache = jax.lax.scan(body, init, xs)
    else:
        carry = init
        outs = []
        for i in range(n):
            carry, c = body(carry, jax.tree.map(lambda v: v[i], xs))
            outs.append(c)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    if chan_mode:
        x, aux, chan = carry
        return x, new_cache, aux, chan
    x, aux = carry
    return x, new_cache, aux


def stack_prefill(cfg, values: dict, x: jax.Array, positions: jax.Array,
                  plan, max_seq: int,
                  enc_out: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, dict, jax.Array]:
    """Full forward that also builds the stacked decode cache."""

    def body(carry, period_params):
        x, aux = carry
        cache = {}
        for i, (mixer, ffn) in enumerate(plan):
            key = f"pos{i}"
            x, c, a = block_prefill(cfg, period_params[key], x, positions,
                                    mixer, ffn, max_seq, enc_out)
            cache[key] = c
            aux = aux + a
        return (x, aux), cache

    if cfg.scan_layers:
        (x, aux), cache = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), values)
    else:
        n = jax.tree.leaves(values)[0].shape[0]
        carry = (x, jnp.zeros((), jnp.float32))
        outs = []
        for i in range(n):
            carry, c = body(carry, jax.tree.map(lambda v: v[i], values))
            outs.append(c)
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
        x, aux = carry
    return x, cache, aux


def stack_cache_init(cfg, plan, n_periods: int, batch: int, max_seq: int,
                     dtype, cross_len: int = 0) -> dict:
    one = {f"pos{i}": block_cache_init(cfg, mixer, batch, max_seq, dtype,
                                       cross_len)
           for i, (mixer, _) in enumerate(plan)}
    return jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (n_periods,) + v.shape), one)


def stack_cache_axes(cfg, plan, has_cross: bool) -> dict:
    one = {f"pos{i}": block_cache_axes(cfg, mixer, has_cross)
           for i, (mixer, _) in enumerate(plan)}
    return jax.tree.map(
        lambda ax: ("layers",) + tuple(ax), one,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))
