"""Top-level model API: init / loss / prefill / decode_step / input_specs.

Pure functions over a ``ModelConfig``; ``build(cfg)`` binds them into a
lightweight namespace.  All functions operate on the *value* tree (plain
arrays); ``init`` returns the Tagged tree carrying logical sharding axes.

Batch conventions
-----------------
train (token frontend)   {"tokens": (B,S) i32, "targets": (B,S) i32}
train (patch/audio)      {"feats": (B,S,Df) bf16, "targets": (B,S) i32}
                         enc-dec adds {"tokens": (B,S_dec) i32} and targets
                         align with decoder tokens.
prefill                  same as train minus targets -> (last_logits, cache)
decode                   (token (B,1) i32, positions (B,) i32, cache)
"""

from __future__ import annotations

import dataclasses
import functools
import types
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers, transformer
from repro.parallel.sharding import Tagged, constrain, split_tree

WHISPER_DECODER_LEN = 448   # whisper's real positional cap for train targets


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init(cfg: ModelConfig, rng: jax.Array) -> dict:
    r = layers.rsplit(rng, 6)
    p: Dict[str, Any] = {
        "embed": layers.embed_init(cfg, r[0]),
        "blocks": transformer.stack_init(cfg, r[1], cfg.layer_plan(),
                                         cfg.n_periods,
                                         cross=cfg.encoder_decoder),
        "final_norm": layers.norm_init(cfg, r[2]),
    }
    p.update(layers.unembed_init(cfg, r[3]))
    if cfg.encoder_decoder:
        enc_plan = cfg.encoder_layer_plan()
        assert cfg.n_encoder_layers % len(enc_plan) == 0
        p["encoder"] = transformer.stack_init(
            cfg, r[4], enc_plan, cfg.n_encoder_layers // len(enc_plan))
        p["encoder_norm"] = layers.norm_init(cfg, r[5])
    return p


# ---------------------------------------------------------------------------
# embedding helpers
# ---------------------------------------------------------------------------

def _embed_inputs(cfg, v, batch) -> Tuple[jax.Array, jax.Array]:
    """Returns (x (B,S,d), positions (B,S))."""
    if cfg.encoder_decoder or cfg.frontend == "token":
        key = "tokens"
        tokens = batch[key]
        x = layers.embed_tokens(cfg, v["embed"], tokens)
        b, s = tokens.shape
    else:
        feats = batch["feats"]
        x = layers.embed_frontend(cfg, v["embed"], feats)
        b, s = feats.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.use_abs_pos:
        pe = layers.sinusoidal_positions(s, cfg.d_model).astype(x.dtype)
        x = x + pe[None]
    return x, positions


def _encode(cfg, v, feats) -> jax.Array:
    x = layers.embed_frontend(cfg, v["embed"], feats)
    b, s = feats.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.use_abs_pos:
        x = x + layers.sinusoidal_positions(s, cfg.d_model).astype(x.dtype)[None]
    enc_plan = cfg.encoder_layer_plan()
    x, _ = transformer.stack_full(cfg, v["encoder"], x, positions, enc_plan)
    return layers.norm_apply(cfg, v["encoder_norm"], x)


# ---------------------------------------------------------------------------
# training loss
# ---------------------------------------------------------------------------

def _xent(cfg, v, x: jax.Array, targets: jax.Array) -> jax.Array:
    """Chunked cross-entropy over the (vocab-sharded) unembedding.

    Chunking the sequence bounds the live fp32 logits to (B, chunk, V)
    instead of (B, S, V) — a large activation-memory saving at equal FLOPs.
    """
    b, s, d = x.shape
    chunk = getattr(cfg, "loss_chunk", 512)
    if s % chunk != 0:
        chunk = s
    n_chunks = s // chunk
    xc = x.reshape(b, n_chunks, chunk, d).swapaxes(0, 1)
    tc = targets.reshape(b, n_chunks, chunk).swapaxes(0, 1)

    def one(carry, xt):
        xch, tch = xt
        logits = layers.unembed_apply(cfg, {k: v[k] for k in ("head",)
                                            if k in v}, v["embed"], xch)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tch[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        return carry + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(one, jnp.zeros((), jnp.float32), (xc, tc))
    return total / (b * s)


def forward(cfg, v, batch) -> Tuple[jax.Array, jax.Array]:
    """Full forward to final hidden states. Returns (x, aux_loss)."""
    enc_out = None
    if cfg.encoder_decoder:
        enc_out = _encode(cfg, v, batch["feats"])
    x, positions = _embed_inputs(cfg, v, batch)
    x = constrain(x, ("batch", "seq", "embed"))
    x, aux = transformer.stack_full(cfg, v["blocks"], x, positions,
                                    cfg.layer_plan(), enc_out=enc_out)
    x = layers.norm_apply(cfg, v["final_norm"], x)
    return x, aux


def logits_fn(cfg, v, batch) -> jax.Array:
    x, _ = forward(cfg, v, batch)
    return layers.unembed_apply(cfg, {k: v[k] for k in ("head",) if k in v},
                                v["embed"], x)


def loss_fn(cfg, v, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x, aux = forward(cfg, v, batch)
    nll = _xent(cfg, v, x, batch["targets"])
    loss = nll + cfg.router_aux_weight * aux
    return loss, {"nll": nll, "aux": aux, "loss": loss}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def prefill(cfg, v, batch, max_seq: Optional[int] = None
            ) -> Tuple[jax.Array, dict]:
    """Returns (last-position logits (B,V), decode cache)."""
    enc_out = None
    if cfg.encoder_decoder:
        enc_out = _encode(cfg, v, batch["feats"])
    x, positions = _embed_inputs(cfg, v, batch)
    max_seq = max_seq or x.shape[1]
    x, cache, _ = transformer.stack_prefill(
        cfg, v["blocks"], x, positions, cfg.layer_plan(), max_seq,
        enc_out=enc_out)
    x = layers.norm_apply(cfg, v["final_norm"], x)
    last = x[:, -1:]
    logits = layers.unembed_apply(cfg, {k: v[k] for k in ("head",) if k in v},
                                  v["embed"], last)[:, 0]
    return logits, cache


def decode_step(cfg, v, token: jax.Array, positions: jax.Array, cache: dict
                ) -> Tuple[jax.Array, dict]:
    """token: (B,1) i32; positions: (B,) current write index."""
    x = layers.embed_tokens(cfg, v["embed"], token)
    if cfg.use_abs_pos:
        # gather the sinusoidal row for each position
        pe = layers.sinusoidal_positions(
            int(_max_pos(cfg, cache)), cfg.d_model).astype(x.dtype)
        x = x + pe[positions][:, None]
    x, new_cache, _ = transformer.stack_step(cfg, v["blocks"], x, positions,
                                             cache, cfg.layer_plan())
    x = layers.norm_apply(cfg, v["final_norm"], x)
    logits = layers.unembed_apply(cfg, {k: v[k] for k in ("head",) if k in v},
                                  v["embed"], x)[:, 0]
    return logits, new_cache


def decode_step_channel(cfg, v, token: jax.Array, positions: jax.Array,
                        cache: dict, protocol, rng: jax.Array
                        ) -> Tuple[jax.Array, dict, dict]:
    """:func:`decode_step` with the wireless channel in the loop.

    Every mlp-FFN worker fusion in the stack aggregates the per-worker
    partials through ``protocol`` (a traced ``repro.protocol.Protocol``
    pytree — rebinding ``p_miss`` never recompiles) under the sensing key
    ``rng``; mixer fusions stay on the ideal ``tp_fusion`` collective.
    Returns ``(logits, new_cache, chan)`` where ``chan`` is the summed
    channel-accounting dict (``fusion.chan_zeros()`` layout) over the
    tick's :func:`channel_sites` aggregate calls.
    """
    x = layers.embed_tokens(cfg, v["embed"], token)
    if cfg.use_abs_pos:
        pe = layers.sinusoidal_positions(
            int(_max_pos(cfg, cache)), cfg.d_model).astype(x.dtype)
        x = x + pe[positions][:, None]
    x, new_cache, _, chan = transformer.stack_step(
        cfg, v["blocks"], x, positions, cache, cfg.layer_plan(),
        protocol=protocol, rng=rng)
    x = layers.norm_apply(cfg, v["final_norm"], x)
    logits = layers.unembed_apply(cfg, {k: v[k] for k in ("head",) if k in v},
                                  v["embed"], x)[:, 0]
    return logits, new_cache, chan


def channel_sites(cfg) -> int:
    """Channel aggregate calls per decode tick: one per mlp-FFN layer."""
    return cfg.n_periods * sum(1 for _, ffn in cfg.layer_plan()
                               if ffn == "mlp")


def _max_pos(cfg, cache) -> int:
    # self-attention KV cache: (layers, B, S_max, n_kv_heads, head_dim)
    for leaf in jax.tree.leaves(cache):
        if (leaf.ndim == 5 and leaf.shape[-2] == cfg.n_kv_heads
                and leaf.shape[-1] == cfg.head_dim_):
            return leaf.shape[2]
    return 32768


def cache_init(cfg, batch: int, max_seq: int, cross_len: int = 0) -> dict:
    return transformer.stack_cache_init(
        cfg, cfg.layer_plan(), cfg.n_periods, batch, max_seq, cfg.dtype,
        cross_len=cross_len)


def cache_axes(cfg) -> dict:
    return transformer.stack_cache_axes(cfg, cfg.layer_plan(),
                                        cfg.encoder_decoder)


# ---------------------------------------------------------------------------
# input specs (dry-run: ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Returns (specs, logical_axes) for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    specs: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}

    def token_inputs(with_targets: bool):
        if cfg.encoder_decoder:
            sd = min(WHISPER_DECODER_LEN, s)
            specs["feats"] = sds((b, s, cfg.frontend_dim), jnp.bfloat16)
            axes["feats"] = ("batch", "seq", None)
            specs["tokens"] = sds((b, sd), i32)
            axes["tokens"] = ("batch", "seq")
            if with_targets:
                specs["targets"] = sds((b, sd), i32)
                axes["targets"] = ("batch", "seq")
        elif cfg.frontend == "token":
            specs["tokens"] = sds((b, s), i32)
            axes["tokens"] = ("batch", "seq")
            if with_targets:
                specs["targets"] = sds((b, s), i32)
                axes["targets"] = ("batch", "seq")
        else:
            specs["feats"] = sds((b, s, cfg.frontend_dim), jnp.bfloat16)
            axes["feats"] = ("batch", "seq", None)
            if with_targets:
                specs["targets"] = sds((b, s), i32)
                axes["targets"] = ("batch", "seq")

    if shape.kind == "train":
        token_inputs(with_targets=True)
    elif shape.kind == "prefill":
        token_inputs(with_targets=False)
    elif shape.kind == "decode":
        specs["token"] = sds((b, 1), i32)
        axes["token"] = ("batch", None)
        specs["positions"] = sds((b,), i32)
        axes["positions"] = ("batch",)
        cross_len = s if cfg.encoder_decoder else 0
        cache = jax.eval_shape(
            lambda: cache_init(cfg, b, s, cross_len=cross_len))
        specs["cache"] = cache
        axes["cache"] = cache_axes(cfg)
    else:
        raise ValueError(shape.kind)
    return specs, axes


def build(cfg: ModelConfig) -> types.SimpleNamespace:
    return types.SimpleNamespace(
        cfg=cfg,
        init=functools.partial(init, cfg),
        loss=functools.partial(loss_fn, cfg),
        logits=functools.partial(logits_fn, cfg),
        forward=functools.partial(forward, cfg),
        prefill=functools.partial(prefill, cfg),
        decode_step=functools.partial(decode_step, cfg),
        decode_step_channel=functools.partial(decode_step_channel, cfg),
        channel_sites=functools.partial(channel_sites, cfg),
        cache_init=functools.partial(cache_init, cfg),
        cache_axes=functools.partial(cache_axes, cfg),
        input_specs=functools.partial(input_specs, cfg),
        split=split_tree,
    )
