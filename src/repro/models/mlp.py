"""MLP (SwiGLU / GELU) block with worker-axis TP and FedOCS fusion.

Weights are stored worker-factored (paper §II notation):
  w_gate/w_up : (worker, embed, ff_local)   worker -> model
  w_down      : (worker, ff_local, embed)   worker -> model

Each worker computes a private hidden slice and a *full-width* partial output;
partials fuse via :func:`repro.models.fusion.worker_reduce` — all-reduce(add)
for standard TP, all-reduce(max) (optionally on D-bit codes) for FedOCS.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import fusion, layers
from repro.parallel.sharding import constrain


def mlp_init(cfg, rng, d_ff: int | None = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    n = cfg.n_workers
    assert d_ff % n == 0, (cfg.name, d_ff, n)
    f_local = d_ff // n
    r = layers.rsplit(rng, 4)
    p = {
        "w_up": layers.param(r[0], (n, cfg.d_model, f_local),
                             ("worker", "embed", "ff_local"), cfg.param_dtype,
                             scale=cfg.d_model ** -0.5),
        "w_down": layers.param(r[1], (n, f_local, cfg.d_model),
                               ("worker", "ff_local", "embed"), cfg.param_dtype,
                               scale=d_ff ** -0.5),
    }
    if cfg.act == "silu":
        p["w_gate"] = layers.param(r[2], (n, cfg.d_model, f_local),
                                   ("worker", "embed", "ff_local"),
                                   cfg.param_dtype, scale=cfg.d_model ** -0.5)
    p.update(fusion.fusion_init(cfg, r[3], cfg.d_model))
    return p


def mlp_apply(cfg, p: dict, x: jax.Array, protocol=None, rng=None):
    """x: (B, S, d) -> (B, S, d).

    With ``protocol=None`` (default) the worker partials fuse via the
    config's static ``tp_fusion`` collective — the historical path,
    unchanged op for op.  With a ``repro.protocol.Protocol`` the partials
    — the paper's per-worker embeddings h_n — instead pool *through the
    simulated channel* and the call returns ``(out, ProtocolAccounting)``.
    """
    d = cfg.dtype
    up = jnp.einsum("bsd,ndf->nbsf", x, p["w_up"].astype(d))
    if "w_gate" in p:
        gate = jnp.einsum("bsd,ndf->nbsf", x, p["w_gate"].astype(d))
        hidden = jax.nn.silu(gate) * up
    else:
        hidden = layers.activation(cfg, up)
    hidden = constrain(hidden, ("worker", "batch", "seq", "ff_local"))
    partial = jnp.einsum("nbsf,nfe->nbse", hidden, p["w_down"].astype(d))
    partial = constrain(partial, ("worker", "batch", "seq", "embed"))
    if protocol is None:
        return fusion.worker_reduce(cfg, p, partial)
    return fusion.worker_reduce_channel(cfg, p, partial, protocol, rng)
