"""GQA attention with RoPE, KV caching, cross-attention, and FedOCS fusion
on the output projection.

Sharding layout (logical axes):
  q proj   : (embed, heads, head_dim)   heads -> model
  k/v proj : (embed, kv_heads, hd)      REPLICATED over model (kv_heads can be
                                        smaller than the TP degree — 2..16 in
                                        the assigned archs — so KV is computed
                                        redundantly per shard, Megatron-style)
  o proj   : (worker, heads/N, hd, embed)  worker -> model, FedOCS-fusable
  KV cache : (batch, kv_seq, kv_heads, hd) — kv_seq maps to the data axis for
             the long-context cells (flash-decode style sequence parallelism)
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import fusion, layers
from repro.parallel.sharding import constrain

NEG_INF = -1e9


def n_heads_padded(cfg) -> int:
    """Physical head count: ``pad_heads_to`` rounds uneven head counts up to
    an even multiple of the TP degree (hillclimb lever for the GSPMD
    uneven-shard all-gathers; padded heads are zero-masked)."""
    if cfg.pad_heads_to and cfg.pad_heads_to > cfg.n_heads:
        return cfg.pad_heads_to
    return cfg.n_heads


def attn_layout(cfg) -> str:
    """'worker' when heads divide the TP degree (FedOCS-fusable out-proj);
    'plain' otherwise (GSPMD pads the uneven head sharding; out-proj is a
    standard all-reduce(add) contraction — see DESIGN.md §5)."""
    return "worker" if n_heads_padded(cfg) % cfg.n_workers == 0 else "plain"


def attn_init(cfg, rng, cross: bool = False) -> dict:
    hd = cfg.head_dim_
    r = layers.rsplit(rng, 6)
    n = cfg.n_workers
    hp = n_heads_padded(cfg)
    p = {
        "wq": layers.param(r[0], (cfg.d_model, hp, hd),
                           ("embed", "heads", None), cfg.param_dtype),
        "wk": layers.param(r[1], (cfg.d_model, cfg.n_kv_heads, hd),
                           ("embed", None, None), cfg.param_dtype),
        "wv": layers.param(r[2], (cfg.d_model, cfg.n_kv_heads, hd),
                           ("embed", None, None), cfg.param_dtype),
    }
    if attn_layout(cfg) == "worker":
        # worker-factored output projection (FedOCS fusion point)
        p["wo"] = layers.param(r[3], (n, hp // n, hd, cfg.d_model),
                               ("worker", None, None, "embed"),
                               cfg.param_dtype,
                               scale=1.0 / (cfg.n_heads * hd) ** 0.5)
    else:
        p["wo"] = layers.param(r[3], (hp, hd, cfg.d_model),
                               ("heads", None, "embed"), cfg.param_dtype,
                               scale=1.0 / (cfg.n_heads * hd) ** 0.5)
    if cfg.qkv_bias:
        p["bq"] = layers.param(r[4], (hp, hd), ("heads", None),
                               cfg.param_dtype, mode="zeros")
        p["bk"] = layers.param(r[4], (cfg.n_kv_heads, hd), (None, None),
                               cfg.param_dtype, mode="zeros")
        p["bv"] = layers.param(r[4], (cfg.n_kv_heads, hd), (None, None),
                               cfg.param_dtype, mode="zeros")
    p.update(fusion.fusion_init(cfg, r[5], cfg.d_model))
    return p


def init_cache(cfg, batch: int, max_seq: int, dtype) -> dict:
    hd = cfg.head_dim_
    shape = (batch, max_seq, cfg.n_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


CACHE_AXES = {
    "k": ("batch", "kv_seq", None, None),
    "v": ("batch", "kv_seq", None, None),
}


def _qkv(cfg, p, x, kv_x):
    d = cfg.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(d))
    k = jnp.einsum("btd,dhk->bthk", kv_x, p["wk"].astype(d))
    v = jnp.einsum("btd,dhk->bthk", kv_x, p["wv"].astype(d))
    if "bq" in p:
        q = q + p["bq"].astype(d)
        k = k + p["bk"].astype(d)
        v = v + p["bv"].astype(d)
    return q, k, v


def _sdpa(cfg, q, k, v, mask) -> jax.Array:
    """q: (B,S,H,Dh), k/v: (B,T,Kv,Dh), mask: (B, S, T) bool or None.

    ``scores_dtype='bf16'`` keeps the materialized S x T scores in bf16
    (max-subtracted softmax for range safety) — halves the dominant
    activation-HBM term on long sequences at ~1e-2 logit error
    (hillclimb lever; default f32).
    """
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    sdt = jnp.bfloat16 if cfg.scores_dtype == "bf16" else jnp.float32
    q = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k,
                        preferred_element_type=sdt)
    scores = scores * jnp.asarray(hd ** -0.5, sdt)
    if mask is not None:
        scores = jnp.where(mask[:, None, None], scores,
                           jnp.asarray(NEG_INF, jnp.float32).astype(sdt))
    smax = jax.lax.stop_gradient(jnp.max(scores, axis=-1, keepdims=True))
    unnorm = jnp.exp((scores - smax).astype(sdt))
    denom = jnp.sum(unnorm.astype(jnp.float32), axis=-1, keepdims=True)
    probs = (unnorm / denom.astype(sdt)).astype(cfg.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, hd)


def _project_out(cfg, p, attn_out) -> jax.Array:
    """(B,S,H,Dh) -> fused (B,S,d) via the configured TP layout."""
    b, s, h, hd = attn_out.shape
    if h != cfg.n_heads:                       # zero-mask padded heads
        head_mask = (jnp.arange(h) < cfg.n_heads).astype(attn_out.dtype)
        attn_out = attn_out * head_mask[None, None, :, None]
    if attn_layout(cfg) == "plain":
        out = jnp.einsum("bshd,hde->bse", attn_out, p["wo"].astype(cfg.dtype))
        return constrain(out, ("batch", "seq", "embed"))
    n = cfg.n_workers
    grouped = attn_out.reshape(b, s, n, h // n, hd)
    partial = jnp.einsum("bsnhd,nhde->nbse", grouped, p["wo"].astype(cfg.dtype))
    partial = constrain(partial, ("worker", "batch", "seq", "embed"))
    return fusion.worker_reduce(cfg, p, partial)


def attn_full(cfg, p: dict, x: jax.Array, positions: jax.Array,
              causal: bool = True, kv_x: Optional[jax.Array] = None,
              return_kv: bool = False):
    """Full-sequence attention (train / prefill). x: (B, S, d)."""
    kv_in = x if kv_x is None else kv_x
    q, k, v = _qkv(cfg, p, x, kv_in)
    if cfg.use_rope and kv_x is None:
        q = layers.apply_rope(cfg, q, positions)
        k = layers.apply_rope(cfg, k, positions)
    q = constrain(q, ("batch", "seq", "heads", None))
    if cfg.use_flash and kv_x is None:
        # Pallas flash kernel ((B,H,S,D) layout); positions are arange here,
        # so block-causal masking inside the kernel is exact.
        from repro.kernels.flash_attention import ops as flash_ops
        out = flash_ops.flash_attention(
            q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2),
            causal).swapaxes(1, 2)
    else:
        mask = None
        if causal:
            tq = positions[:, :, None]
            tk = positions[:, None, :]
            mask = (tk <= tq)[:, :, :]                  # (B, S, S)
        out = _sdpa(cfg, q, k, v, mask)
    y = _project_out(cfg, p, out)
    if return_kv:
        return y, {"k": k, "v": v}
    return y


def attn_step(cfg, p: dict, x: jax.Array, positions: jax.Array,
              cache: dict, cross: bool = False) -> Tuple[jax.Array, dict]:
    """Single decode step. x: (B, 1, d); positions: (B,) current index;
    cache: {"k","v"} (B, S_max, Kv, Dh), entries < positions are valid."""
    d = cfg.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(d))
    if "bq" in p:
        q = q + p["bq"].astype(d)
    if cfg.use_rope and not cross:
        q = layers.apply_rope(cfg, q, positions[:, None])

    if cross:
        k, v = cache["k"], cache["v"]                  # encoder KV, static
        new_cache = cache
        valid = jnp.ones((x.shape[0], 1, k.shape[1]), bool)
    else:
        knew = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(d))
        vnew = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(d))
        if "bk" in p:
            knew = knew + p["bk"].astype(d)
            vnew = vnew + p["bv"].astype(d)
        if cfg.use_rope:
            knew = layers.apply_rope(cfg, knew, positions[:, None])

        def upd(c, new, pos):
            # literal starts must match pos's dtype (ints pick up int64
            # under JAX_ENABLE_X64 and lax rejects the mix)
            zero = jnp.zeros((), pos.dtype)
            return jax.lax.dynamic_update_slice(c, new, (pos, zero, zero))

        k = jax.vmap(upd)(cache["k"], knew, positions)
        v = jax.vmap(upd)(cache["v"], vnew, positions)
        k = constrain(k, CACHE_AXES["k"])
        v = constrain(v, CACHE_AXES["v"])
        new_cache = {"k": k, "v": v}
        t = jnp.arange(k.shape[1], dtype=positions.dtype)
        valid = (t[None, :] <= positions[:, None])[:, None, :]  # (B,1,S_max)

    out = _sdpa(cfg, q, k, v, valid)
    y = _project_out(cfg, p, out)
    return y, new_cache
