"""Mixture-of-Experts FFN with expert parallelism (EP).

Experts are sharded over the ``model`` mesh axis (one shard owns
``n_experts / N`` whole expert FFNs).  Routing uses *per-sequence grouped
dispatch*: top-k selection, a sort **within each sequence** (vmapped — never a
global cross-shard sort), and capacity-bounded scatter into per-expert
buffers.  The scatter/gather between the batch-sharded token axis and the
expert-sharded buffer axis is where GSPMD emits the EP all-to-all.

Dropped-token policy: tokens beyond ``capacity_factor``-scaled capacity are
dropped (scatter with out-of-bounds position — JAX drops OOB scatter updates),
standard Switch/GShard semantics.  The router adds the usual load-balancing
auxiliary loss.

The FedOCS fusion law does not apply inside expert FFNs (DESIGN.md §5): an
expert's FFN lives wholly on one shard, so there is no cross-worker partial
reduction to replace.  A shared expert (llama4-style), which *is* worker-
sharded, uses the standard MLP path and therefore does participate.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers, mlp
from repro.parallel.sharding import constrain


def moe_init(cfg, rng) -> dict:
    e, d = cfg.n_experts, cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    r = layers.rsplit(rng, 5)
    p = {
        "router": layers.param(r[0], (d, e), ("embed", None), jnp.float32,
                               scale=d ** -0.5),
        "w_up": layers.param(r[1], (e, d, f), ("experts", "embed", "ff_local"),
                             cfg.param_dtype, scale=d ** -0.5),
        "w_gate": layers.param(r[2], (e, d, f), ("experts", "embed", "ff_local"),
                               cfg.param_dtype, scale=d ** -0.5),
        "w_down": layers.param(r[3], (e, f, d), ("experts", "ff_local", "embed"),
                               cfg.param_dtype, scale=f ** -0.5),
    }
    if cfg.moe_shared_expert:
        p["shared"] = mlp.mlp_init(cfg, r[4], d_ff=cfg.moe_d_ff or cfg.d_ff)
    return p


def _capacity(cfg, tokens_per_seq: int) -> int:
    return max(1, math.ceil(
        tokens_per_seq * cfg.experts_per_token / cfg.n_experts
        * cfg.capacity_factor))


def _route_one_seq(cfg, probs: jax.Array, cap: int):
    """probs: (S, E) -> dispatch indices for one sequence.

    Returns (expert_idx, pos_in_expert, token_idx, weight), each (S*k,),
    with pos_in_expert == cap for dropped tokens (OOB scatter -> dropped).
    """
    s, e = probs.shape
    k = cfg.experts_per_token
    w, idx = jax.lax.top_k(probs, k)                     # (S, k)
    w = w / jnp.clip(jnp.sum(w, -1, keepdims=True), 1e-9)
    e_flat = idx.reshape(-1)                             # (S*k,)
    w_flat = w.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(s, dtype=jnp.int32), k)
    order = jnp.argsort(e_flat, stable=True)             # local per-seq sort
    e_s, w_s, t_s = e_flat[order], w_flat[order], tok_flat[order]
    counts = jnp.bincount(e_flat, length=e)              # (E,)
    start = jnp.cumsum(counts) - counts
    pos = jnp.arange(s * k, dtype=jnp.int32) - start[e_s].astype(jnp.int32)
    pos = jnp.where(pos < cap, pos, cap)                 # cap == dropped
    return e_s, pos, t_s, w_s


def moe_apply(cfg, p: dict, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    if cfg.moe_impl == "gather":
        return moe_apply_gather(cfg, p, x)
    return moe_apply_sort_scatter(cfg, p, x)


def moe_apply_sort_scatter(cfg, p: dict, x: jax.Array
                           ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    cap = _capacity(cfg, s)
    dt = cfg.dtype

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)              # (B, S, E)

    e_s, pos, t_s, w_s = jax.vmap(
        lambda pr: _route_one_seq(cfg, pr, cap))(probs)  # each (B, S*k)

    # dispatch: (B, S, d) -> (B, E, cap, d); OOB pos rows are dropped
    def scatter_one(xb, eb, pb, tb):
        buf = jnp.zeros((e, cap, d), dt)
        return buf.at[eb, pb].set(xb[tb], mode="drop")

    buf = jax.vmap(scatter_one)(x.astype(dt), e_s, pos, t_s)
    buf = constrain(buf, ("batch", "experts", None, "embed"))

    # expert FFN (SwiGLU), batched over (B, E): weights indexed by E
    gate = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(dt))
    up = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(dt))
    hidden = jax.nn.silu(gate) * up
    hidden = constrain(hidden, ("batch", "experts", None, "ff_local"))
    out_buf = jnp.einsum("becf,efd->becd", hidden, p["w_down"].astype(dt))
    out_buf = constrain(out_buf, ("batch", "experts", None, "embed"))

    # combine: gather back and weight
    def gather_one(ob, eb, pb, tb, wb):
        vals = ob[eb, jnp.minimum(pb, cap - 1)]          # (S*k, d)
        keep = (pb < cap).astype(dt)[:, None]
        y = jnp.zeros((s, d), dt)
        return y.at[tb].add(vals * wb[:, None].astype(dt) * keep)

    y = jax.vmap(gather_one)(out_buf, e_s, pos, t_s, w_s)
    y = constrain(y, ("batch", "seq", "embed"))

    if cfg.moe_shared_expert:
        y = y + mlp.mlp_apply(cfg, p["shared"], x)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=(0, 1))                    # (E,)
    dispatch_frac = jnp.zeros((e,), jnp.float32).at[e_s.reshape(-1)].add(
        1.0 / (b * s * k))
    aux = cfg.n_experts * jnp.sum(dispatch_frac * me)
    return y, aux.astype(jnp.float32)


def moe_apply_gather(cfg, p: dict, x: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    """Gather-dispatch / scatter-combine EP (hillclimb lever, §Perf).

    Against ``sort_scatter``, this formulation keeps the expensive tensors
    local: tokens ``x`` are replicated over the model axis between blocks, so
    each shard *gathers* its own experts' token rows (zero collective), runs
    its expert FFNs, and scatter-adds its partial outputs into token space —
    the only collective is one all-reduce(add) of the (B, S, d) combine,
    identical to a dense TP block.  The sort_scatter formulation instead
    gathers from the expert-sharded buffer with replicated indices, which
    GSPMD must realize as an all-gather of the whole (B, E, cap, d) buffer —
    the dominant collective in the qwen3-moe baseline.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    cap = _capacity(cfg, s)
    dt = cfg.dtype

    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)

    e_s, pos, t_s, w_s = jax.vmap(
        lambda pr: _route_one_seq(cfg, pr, cap))(probs)  # each (B, S*k)

    # slot->token inverse map + slot weights (tiny int/float buffers)
    def invert(eb, pb, tb, wb):
        tok_of = jnp.full((e, cap), s, jnp.int32)        # s == "no token"
        tok_of = tok_of.at[eb, pb].set(tb, mode="drop")
        w_of = jnp.zeros((e, cap), jnp.float32)
        w_of = w_of.at[eb, pb].set(wb, mode="drop")
        return tok_of, w_of

    tok_of, w_of = jax.vmap(invert)(e_s, pos, t_s, w_s)  # (B, E, cap)

    # dispatch: LOCAL gather of each shard's experts' rows (x replicated,
    # tok_of replicated, output expert-sharded)
    xz = jnp.concatenate([x.astype(dt), jnp.zeros((b, 1, d), dt)], axis=1)
    buf = jnp.take_along_axis(
        xz[:, None, :, :],                               # (B, 1, S+1, d)
        tok_of[..., None].astype(jnp.int32), axis=2)     # (B, E, cap, d)
    buf = constrain(buf, ("batch", "experts", None, "embed"))

    gate = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(dt))
    up = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(dt))
    hidden = jax.nn.silu(gate) * up
    hidden = constrain(hidden, ("batch", "experts", None, "ff_local"))
    out_buf = jnp.einsum("becf,efd->becd", hidden, p["w_down"].astype(dt))
    out_buf = out_buf * w_of[..., None].astype(dt)
    out_buf = constrain(out_buf, ("batch", "experts", None, "embed"))

    # combine: scatter-add partials into token space; the cross-expert sum
    # over the sharded E axis lowers to one all-reduce(add) of (B, S, d)
    def combine_one(ob, tof):
        y = jnp.zeros((s + 1, d), dt)
        y = y.at[tof.reshape(-1)].add(ob.reshape(-1, d), mode="drop")
        return y[:s]

    y = jax.vmap(combine_one)(out_buf, tok_of)
    y = constrain(y, ("batch", "seq", "embed"))

    if cfg.moe_shared_expert:
        y = y + mlp.mlp_apply(cfg, p["shared"], x)

    me = jnp.mean(probs, axis=(0, 1))
    dispatch_frac = jnp.zeros((e,), jnp.float32).at[e_s.reshape(-1)].add(
        1.0 / (b * s * k))
    aux = cfg.n_experts * jnp.sum(dispatch_frac * me)
    return y, aux.astype(jnp.float32)
