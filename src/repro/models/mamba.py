"""Mamba (S6 selective-state-space) mixer — used by jamba-1.5 hybrid layers.

Channel-parallel TP: the inner dimension ``d_inner`` is split over the worker
axis.  Everything between in-proj and out-proj (depthwise conv, dt/B/C
projections, selective scan) is *channelwise* and therefore fully local to a
worker; the out-projection is worker-factored and fuses through the FedOCS
law (``worker_reduce``), exactly like an MLP down-projection.

Training uses a sequential ``lax.scan`` over time by default;
``cfg`` flag ``mamba_assoc_scan`` (hillclimb lever) switches to
``jax.lax.associative_scan`` on the linear recurrence
``h_t = a_t * h_{t-1} + b_t`` for O(log S) depth.

Decode keeps (conv window, ssm state) in the cache and costs O(1) per token —
this is what makes jamba long_500k-capable (DESIGN.md §5).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import fusion, layers
from repro.parallel.sharding import Tagged, constrain


def mamba_init(cfg, rng) -> dict:
    n = cfg.n_workers
    di = cfg.d_inner
    assert di % n == 0, (cfg.name, di, n)
    dl = di // n                       # channels per worker
    st, dr = cfg.ssm_state_dim, cfg.dt_rank_
    r = layers.rsplit(rng, 8)
    p = {
        # in-proj -> (x, z), worker-sharded channels
        "w_in": layers.param(r[0], (n, cfg.d_model, 2 * dl),
                             ("worker", "embed", "ff_local"), cfg.param_dtype,
                             scale=cfg.d_model ** -0.5),
        # depthwise causal conv over time
        "w_conv": layers.param(r[1], (n, dl, cfg.conv_width),
                               ("worker", "ff_local", "conv"), cfg.param_dtype,
                               scale=1.0 / cfg.conv_width),
        "b_conv": layers.param(r[1], (n, dl), ("worker", "ff_local"),
                               cfg.param_dtype, mode="zeros"),
        # x -> (dt_rank, B, C)
        "w_xdbc": layers.param(r[2], (n, dl, dr + 2 * st),
                               ("worker", "ff_local", None), cfg.param_dtype,
                               scale=dl ** -0.5),
        # dt_rank -> channels (dt up-projection)
        "w_dt": layers.param(r[3], (n, dr, dl), ("worker", None, "ff_local"),
                             cfg.param_dtype, scale=dr ** -0.5),
        "b_dt": layers.param(r[4], (n, dl), ("worker", "ff_local"),
                             cfg.param_dtype, mode="zeros"),
        "A_log": Tagged_A(n, dl, st),
        "D": layers.param(r[5], (n, dl), ("worker", "ff_local"),
                          cfg.param_dtype, mode="ones"),
        "w_out": layers.param(r[6], (n, dl, cfg.d_model),
                              ("worker", "ff_local", "embed"), cfg.param_dtype,
                              scale=di ** -0.5),
    }
    p.update(fusion.fusion_init(cfg, r[7], cfg.d_model))
    return p


def Tagged_A(n: int, dl: int, st: int) -> Tagged:
    """S4D-real initialization: A = -(1..st) per channel, stored as log."""
    a = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, None, :],
                 (n, dl, 1))
    return Tagged(jnp.log(a), ("worker", "ff_local", "state"))


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (N,B,S,C) depthwise causal conv, w: (N,C,W)."""
    width = w.shape[-1]
    pad = jnp.pad(x, ((0, 0), (0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + pad[:, :, i:i + x.shape[2], :] * w[:, None, None, :, i]
    return out + b[:, None, None, :]


def _ssm_scan(cfg, a: jax.Array, bx: jax.Array, c: jax.Array,
              h0: Optional[jax.Array]):
    """Linear recurrence h_t = a_t * h_{t-1} + bx_t; y_t = sum_s c_t * h_t.

    a, bx: (N, B, S, C, St);  c: (N, B, S, St).  Returns y (N,B,S,C), h_last.
    """
    if getattr(cfg, "mamba_assoc_scan", False) and h0 is None:
        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br
        aa, hh = jax.lax.associative_scan(comb, (a, bx), axis=2)
        y = jnp.einsum("nbsct,nbst->nbsc", hh, c)
        return y, hh[:, :, -1]
    # sequential scan over time
    n, b, s, ch, st = a.shape
    h_init = jnp.zeros((n, b, ch, st), a.dtype) if h0 is None else h0

    def step(h, t):
        at, bxt, ct = t
        h = at * h + bxt
        y = jnp.einsum("nbct,nbt->nbc", h, ct)
        return h, y

    a_t = jnp.moveaxis(a, 2, 0)
    bx_t = jnp.moveaxis(bx, 2, 0)
    c_t = jnp.moveaxis(c, 2, 0)
    h_last, ys = jax.lax.scan(step, h_init, (a_t, bx_t, c_t))
    return jnp.moveaxis(ys, 0, 2), h_last


def _ssm_inner(cfg, p, xc: jax.Array, h0, positions_unused=None):
    """xc: (N, B, S, C) post-conv activations -> (y, h_last)."""
    d = cfg.dtype
    st, dr = cfg.ssm_state_dim, cfg.dt_rank_
    dbc = jnp.einsum("nbsc,ncr->nbsr", xc, p["w_xdbc"].astype(d))
    dt_low, bmat, cmat = jnp.split(dbc, [dr, dr + st], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("nbsr,nrc->nbsc", dt_low, p["w_dt"].astype(d))
        + p["b_dt"].astype(d)[:, None, None, :])                  # (N,B,S,C)
    a_mat = -jnp.exp(p["A_log"].astype(jnp.float32))              # (N,C,St)
    a_disc = jnp.exp(dt.astype(jnp.float32)[..., None]
                     * a_mat[:, None, None])                      # (N,B,S,C,St)
    bx = (dt * xc).astype(jnp.float32)[..., None] \
        * bmat.astype(jnp.float32)[:, :, :, None, :]              # (N,B,S,C,St)
    y, h_last = _ssm_scan(cfg, a_disc, bx, cmat.astype(jnp.float32), h0)
    y = y.astype(d) + xc * p["D"].astype(d)[:, None, None, :]
    return y, h_last


def mamba_full(cfg, p: dict, x: jax.Array, return_cache: bool = False):
    """Training / prefill path. x: (B, S, d) -> (B, S, d)."""
    d = cfg.dtype
    xi = jnp.einsum("bsd,ndf->nbsf", x, p["w_in"].astype(d))      # (N,B,S,2C)
    xraw, z = jnp.split(xi, 2, axis=-1)
    xraw = constrain(xraw, ("worker", "batch", "seq", "ff_local"))
    xc = jax.nn.silu(_causal_conv(xraw, p["w_conv"].astype(d),
                                  p["b_conv"].astype(d)))
    y, h_last = _ssm_inner(cfg, p, xc, None)
    y = y * jax.nn.silu(z)
    partial = jnp.einsum("nbsc,ncd->nbsd", y, p["w_out"].astype(d))
    partial = constrain(partial, ("worker", "batch", "seq", "embed"))
    out = fusion.worker_reduce(cfg, p, partial)
    if return_cache:
        w = cfg.conv_width
        window = xraw[:, :, -(w - 1):, :]                         # (N,B,W-1,C)
        return out, {"conv": window, "h": h_last}
    return out


def init_cache(cfg, batch: int, dtype) -> dict:
    n = cfg.n_workers
    dl = cfg.d_inner // n
    return {
        "conv": jnp.zeros((n, batch, cfg.conv_width - 1, dl), dtype),
        "h": jnp.zeros((n, batch, dl, cfg.ssm_state_dim), jnp.float32),
    }


MAMBA_CACHE_AXES = {
    "conv": ("worker", "batch", None, "ff_local"),
    "h": ("worker", "batch", "ff_local", "state"),
}


def mamba_step(cfg, p: dict, x: jax.Array, cache: dict
               ) -> Tuple[jax.Array, dict]:
    """Decode step. x: (B, 1, d) -> (B, 1, d); O(1) state update."""
    d = cfg.dtype
    xi = jnp.einsum("bsd,ndf->nbsf", x, p["w_in"].astype(d))      # (N,B,1,2C)
    xraw, z = jnp.split(xi, 2, axis=-1)
    # conv window: (N,B,W-1,C) ++ current
    win = jnp.concatenate([cache["conv"], xraw], axis=2)
    w = p["w_conv"].astype(d)                                     # (N,C,W)
    xc = jnp.einsum("nbwc,ncw->nbc", win, w) + p["b_conv"].astype(d)[:, None]
    xc = jax.nn.silu(xc)[:, :, None, :]                           # (N,B,1,C)
    y, h_last = _ssm_inner(cfg, p, xc, cache["h"])
    y = y * jax.nn.silu(z)
    partial = jnp.einsum("nbsc,ncd->nbsd", y, p["w_out"].astype(d))
    out = fusion.worker_reduce(cfg, p, partial)
    new_cache = {"conv": win[:, :, 1:], "h": h_last}
    return out, new_cache
