"""Primitive layers: initializers, norms, embeddings, rotary embeddings.

Every parameter is created through :func:`param` and carries logical axis
names (see ``parallel/sharding.py``).  Apply functions take the *value* tree
(plain arrays) with the same structure the init produced.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import Tagged, constrain


def param(rng, shape: Sequence[int], axes: Sequence[Optional[str]],
          dtype, scale: Optional[float] = None, mode: str = "normal") -> Tagged:
    """Create a Tagged parameter. scale=None => fan-in 1/sqrt(d) normal."""
    if mode == "zeros":
        return Tagged(jnp.zeros(shape, dtype), axes)
    if mode == "ones":
        return Tagged(jnp.ones(shape, dtype), axes)
    if scale is None:
        fan_in = shape[0] if len(shape) == 1 else math.prod(shape[:-1])
        # for worker-factored weights the true fan-in is the product of all
        # leading dims; callers override `scale` where that is wrong.
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    v = jax.random.normal(rng, tuple(shape), jnp.float32) * scale
    return Tagged(v.astype(dtype), axes)


def rsplit(rng, n: int):
    return jax.random.split(rng, n)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(cfg, rng) -> dict:
    p = {"scale": Tagged(jnp.ones((cfg.d_model,), cfg.param_dtype), ("embed",))}
    if cfg.norm == "layernorm":
        p["bias"] = Tagged(jnp.zeros((cfg.d_model,), cfg.param_dtype), ("embed",))
    return p


def norm_apply(cfg, p: dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    else:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / unembedding (vocab-sharded)
# ---------------------------------------------------------------------------

def embed_init(cfg, rng) -> dict:
    p = {"tokens": param(rng, (cfg.vocab_size, cfg.d_model),
                         ("vocab", "embed"), cfg.param_dtype, scale=1.0)}
    if cfg.frontend in ("patch", "audio"):
        fr = jax.random.fold_in(rng, 1)
        p["frontend_proj"] = param(
            fr, (cfg.frontend_dim or cfg.d_model, cfg.d_model),
            (None, "embed"), cfg.param_dtype)
    return p


def embed_tokens(cfg, p: dict, tokens: jax.Array) -> jax.Array:
    """Token ids (B, S) -> (B, S, d).  Table is vocab-sharded: the gather
    lowers to a one-hot-matmul/all-reduce pattern under SPMD."""
    out = jnp.take(p["tokens"].astype(cfg.dtype), tokens, axis=0)
    return constrain(out, ("batch", "seq", "embed"))


def embed_frontend(cfg, p: dict, feats: jax.Array) -> jax.Array:
    """Precomputed patch/frame embeddings (B, S, d_frontend) -> (B, S, d).

    The modality frontend itself (ViT patcher / audio conv stack) is a stub
    per the assignment: ``input_specs()`` supplies these features."""
    out = feats.astype(cfg.dtype) @ p["frontend_proj"].astype(cfg.dtype)
    return constrain(out, ("batch", "seq", "embed"))


def unembed_init(cfg, rng) -> dict:
    if cfg.tie_embeddings:
        return {}
    return {"head": param(rng, (cfg.d_model, cfg.vocab_size),
                          ("embed", "vocab"), cfg.param_dtype)}


def unembed_apply(cfg, p: dict, embed_params: dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = embed_params["tokens"].astype(cfg.dtype).T
    else:
        w = p["head"].astype(cfg.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w).astype(cfg.logit_dtype)
    return constrain(logits, ("batch", "seq", "vocab"))


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(cfg, head_dim: int) -> jax.Array:
    rot = int(head_dim * cfg.rotary_frac) // 2 * 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv  # (rot/2,)


def apply_rope(cfg, x: jax.Array, positions: jax.Array) -> jax.Array:
    """x: (B, S, H, Dh), positions: (B, S) int32."""
    hd = x.shape[-1]
    rot = int(hd * cfg.rotary_frac) // 2 * 2
    inv = rope_freqs(cfg, hd)                              # (rot/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (B, S, rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([rotated.astype(x.dtype), x_pass], axis=-1)


def sinusoidal_positions(seq_len: int, d_model: int) -> jax.Array:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d_model)
    pe = jnp.zeros((seq_len, d_model), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang[:, : (d_model // 2)]))
    return pe


def activation(cfg, x: jax.Array) -> jax.Array:
    if cfg.act == "silu":
        return jax.nn.silu(x)
    if cfg.act == "gelu":
        return jax.nn.gelu(x)
    raise ValueError(cfg.act)
