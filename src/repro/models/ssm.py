"""xLSTM mixers (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, inherently sequential).

TP layout for mLSTM: q/k are computed replicated (they appear in inner
products that need the full key dimension), while the *value* dimension of
each head is split over the worker axis — the matrix memory
``C = v kᵀ`` is then row-sharded, the read-out ``y = C q`` stays local, and
the down-projection is worker-factored and fuses through the FedOCS law.
sLSTM recurrences (h-feedback, 4 gates) are replicated across workers — the
assigned xlstm-125m has 4 heads against a 16-way TP axis, and the block is a
negligible fraction of compute (DESIGN.md §5).

Decode carries (C, n, m) / (h, c, n, m) in the cache: O(1) per token, which
is what qualifies xlstm for the long_500k cell.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import fusion, layers
from repro.parallel.sharding import constrain


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(cfg, rng) -> dict:
    n = cfg.n_workers
    di = cfg.d_inner
    h = cfg.n_heads
    dh = di // h
    assert dh % n == 0, (cfg.name, dh, n)
    dhl = dh // n
    r = layers.rsplit(rng, 7)
    p = {
        "w_up": layers.param(r[0], (cfg.d_model, 2 * di), ("embed", None),
                             cfg.param_dtype, scale=cfg.d_model ** -0.5),
        "w_q": layers.param(r[1], (di, h, dh), (None, None, None),
                            cfg.param_dtype, scale=di ** -0.5),
        "w_k": layers.param(r[2], (di, h, dh), (None, None, None),
                            cfg.param_dtype, scale=di ** -0.5),
        "w_v": layers.param(r[3], (n, di, h, dhl),
                            ("worker", None, None, None), cfg.param_dtype,
                            scale=di ** -0.5),
        "w_gates": layers.param(r[4], (di, 2 * h), (None, None),
                                cfg.param_dtype, scale=di ** -0.5),
        "b_gates": layers.param(r[4], (2 * h,), (None,), cfg.param_dtype,
                                mode="zeros"),
        "w_down": layers.param(r[5], (n, h * dhl, cfg.d_model),
                               ("worker", None, "embed"), cfg.param_dtype,
                               scale=di ** -0.5),
    }
    p.update(fusion.fusion_init(cfg, r[6], cfg.d_model))
    return p


def _mlstm_scan(q, k, v, i_raw, f_raw, state):
    """Stabilized exponential-gated matrix-memory recurrence.

    q,k: (B,S,H,Dh) fp32; v: (N,B,S,H,Dhl); i_raw,f_raw: (B,S,H).
    state: (C (N,B,H,Dhl,Dh), n (B,H,Dh), m (B,H)).
    Returns y (N,B,S,H,Dhl), new state.
    """
    f_log = jax.nn.log_sigmoid(f_raw)

    def step(carry, t):
        c_mat, n_vec, m = carry
        qt, kt, vt, it, ft = t                 # (B,H,Dh),(B,H,Dh),(N,B,H,Dhl),(B,H),(B,H)
        m_new = jnp.maximum(ft + m, it)
        fp = jnp.exp(ft + m - m_new)           # (B,H)
        ip = jnp.exp(it - m_new)
        c_mat = fp[None, :, :, None, None] * c_mat \
            + ip[None, :, :, None, None] * (vt[..., None] * kt[None, :, :, None, :])
        n_vec = fp[..., None] * n_vec + ip[..., None] * kt
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bhd,bhd->bh", n_vec, qt)), 1.0)
        y = jnp.einsum("nbhvd,bhd->nbhv", c_mat, qt) / denom[None, :, :, None]
        return (c_mat, n_vec, m_new), y

    ts = (jnp.moveaxis(q, 1, 0), jnp.moveaxis(k, 1, 0),
          jnp.moveaxis(v, 2, 0), jnp.moveaxis(i_raw, 1, 0),
          jnp.moveaxis(f_log, 1, 0))
    state, ys = jax.lax.scan(step, state, ts)
    return jnp.moveaxis(ys, 0, 2), state       # (N,B,S,H,Dhl)


def mlstm_state_init(cfg, batch: int) -> Tuple:
    n, h = cfg.n_workers, cfg.n_heads
    dh = cfg.d_inner // h
    dhl = dh // n
    return (jnp.zeros((n, batch, h, dhl, dh), jnp.float32),
            jnp.zeros((batch, h, dh), jnp.float32),
            jnp.full((batch, h), -1e9, jnp.float32))


MLSTM_CACHE_AXES = (("worker", "batch", None, None, None),
                    ("batch", None, None), ("batch", None))


def _mlstm_core(cfg, p, x, state):
    d = cfg.dtype
    n, h = cfg.n_workers, cfg.n_heads
    di = cfg.d_inner
    dh = di // h
    dhl = dh // n
    b, s, _ = x.shape
    up = x @ p["w_up"].astype(d)                       # (B,S,2di)
    xt, z = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bsd,dhk->bshk", xt, p["w_q"].astype(d)).astype(jnp.float32)
    k = (jnp.einsum("bsd,dhk->bshk", xt, p["w_k"].astype(d))
         * (dh ** -0.5)).astype(jnp.float32)
    v = jnp.einsum("bsd,ndhk->nbshk", xt, p["w_v"].astype(d)).astype(jnp.float32)
    v = constrain(v, ("worker", "batch", "seq", None, None))
    gates = (xt @ p["w_gates"].astype(d) + p["b_gates"].astype(d)
             ).astype(jnp.float32)                     # (B,S,2H)
    i_raw, f_raw = jnp.split(gates, 2, axis=-1)
    y, state = _mlstm_scan(q, k, v, i_raw, f_raw, state)
    y = y.reshape(n, b, s, h * dhl).astype(d)
    # output gate: z grouped to match the worker-sharded feature layout
    zg = z.reshape(b, s, h, n, dhl).transpose(3, 0, 1, 2, 4).reshape(
        n, b, s, h * dhl)
    y = y * jax.nn.silu(zg)
    partial = jnp.einsum("nbsf,nfe->nbse", y, p["w_down"].astype(d))
    partial = constrain(partial, ("worker", "batch", "seq", "embed"))
    return fusion.worker_reduce(cfg, p, partial), state


def mlstm_full(cfg, p: dict, x: jax.Array, return_cache: bool = False):
    state = mlstm_state_init(cfg, x.shape[0])
    out, state = _mlstm_core(cfg, p, x, state)
    return (out, state) if return_cache else out


def mlstm_step(cfg, p: dict, x: jax.Array, cache: Tuple
               ) -> Tuple[jax.Array, Tuple]:
    return _mlstm_core(cfg, p, x, cache)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(cfg, rng) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h
    r = layers.rsplit(rng, 3)
    return {
        "w": layers.param(r[0], (d, 4 * d), (None, None), cfg.param_dtype,
                          scale=d ** -0.5),
        "r": layers.param(r[1], (h, dh, 4 * dh), (None, None, None),
                          cfg.param_dtype, scale=dh ** -0.5),
        "b": layers.param(r[2], (4 * d,), (None,), cfg.param_dtype,
                          mode="zeros"),
    }


def slstm_state_init(cfg, batch: int) -> Tuple:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return (z, z, jnp.ones((batch, d), jnp.float32),
            jnp.full((batch, d), -1e9, jnp.float32))


SLSTM_CACHE_AXES = (("batch", None), ("batch", None),
                    ("batch", None), ("batch", None))


def _slstm_scan(cfg, p, wx, state):
    """wx: (B,S,4d) precomputed input contributions."""
    h_heads = cfg.n_heads
    d = cfg.d_model
    dh = d // h_heads
    r_mat = p["r"].astype(jnp.float32)

    def step(carry, wxt):
        h, c, n, m = carry                      # (B,d) each
        b = h.shape[0]
        hh = h.reshape(b, h_heads, dh)
        # (B,H,4*dh) -> (B,4,H,dh) -> (B,4d): match wx's [z|i|f|o] chunking
        rec = jnp.einsum("bhd,hdk->bhk", hh, r_mat)
        rec = rec.reshape(b, h_heads, 4, dh).transpose(0, 2, 1, 3)
        rec = rec.reshape(b, 4 * d)
        z_raw, i_raw, f_raw, o_raw = jnp.split(wxt + rec, 4, axis=-1)
        zt = jnp.tanh(z_raw)
        ot = jax.nn.sigmoid(o_raw)
        f_log = jax.nn.log_sigmoid(f_raw)
        m_new = jnp.maximum(f_log + m, i_raw)
        fp = jnp.exp(f_log + m - m_new)
        ip = jnp.exp(i_raw - m_new)
        c = fp * c + ip * zt
        n = fp * n + ip
        h = ot * c / jnp.maximum(n, 1.0)
        return (h, c, n, m_new), h

    state, hs = jax.lax.scan(step, state, jnp.moveaxis(wx, 1, 0))
    return jnp.moveaxis(hs, 0, 1), state        # (B,S,d)


def _slstm_core(cfg, p, x, state):
    wx = (x @ p["w"].astype(cfg.dtype) + p["b"].astype(cfg.dtype)
          ).astype(jnp.float32)
    hs, state = _slstm_scan(cfg, p, wx, state)
    return hs.astype(cfg.dtype), state


def slstm_full(cfg, p: dict, x: jax.Array, return_cache: bool = False):
    out, state = _slstm_core(cfg, p, x, slstm_state_init(cfg, x.shape[0]))
    return (out, state) if return_cache else out


def slstm_step(cfg, p: dict, x: jax.Array, cache: Tuple
               ) -> Tuple[jax.Array, Tuple]:
    return _slstm_core(cfg, p, x, cache)
