"""Worker-axis TP fusion — the FedOCS aggregation law inside model blocks.

Every row-parallel projection in the stack produces a *worker-leading*
partial tensor ``partial: (N, B, S, K)`` with the worker axis sharded over the
``model`` mesh axis (DESIGN.md §2.1).  :func:`worker_reduce` fuses it:

  sum               -> all-reduce(add)           (Megatron TP reference)
  max/max_q16/max_q8-> all-reduce(max) [on codes] (FedOCS, paper Eq. 4/7)
  concat            -> all-gather + wide fusion head (paper's comm-heavy
                       "Concat Workers Embed" baseline; needs `w_fuse`)

The concat path is deliberately forced through a real all-gather (activation
constraint to a replicated layout) so the dry-run's parsed collective bytes
reproduce the paper's O(N·K)-vs-O(K) comparison on the ICI fabric.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import fedocs
from repro.models import layers
from repro.parallel.sharding import constrain
from repro.protocol import Protocol


def fusion_init(cfg, rng, k_out: int) -> dict:
    """Extra parameters required by the fusion mode (concat only)."""
    if cfg.tp_fusion == "concat":
        return {"w_fuse": layers.param(
            rng, (cfg.n_workers * k_out, k_out), (None, "embed"),
            cfg.param_dtype)}
    return {}


def worker_reduce(cfg, p: dict, partial: jax.Array) -> jax.Array:
    """partial: (N, B, S, K) worker-sharded -> (B, S, K) fused output."""
    mode = cfg.tp_fusion
    if mode == "concat":
        gathered = fedocs.concat(partial)                  # (B, S, N*K)
        gathered = constrain(gathered, ("batch", "seq", None))  # force all-gather
        return gathered @ p["w_fuse"].astype(partial.dtype)
    proto = Protocol.from_mode(mode, tie_break=cfg.tie_break)
    out, _acct = proto.aggregate(partial)
    return constrain(out, ("batch", "seq", "embed"))


def worker_reduce_channel(cfg, p: dict, partial: jax.Array,
                          protocol: Protocol, rng: Optional[jax.Array]):
    """Fuse worker partials *through the simulated wireless channel*.

    Instead of the config's static ``tp_fusion`` collective, the per-worker
    partials — the paper's per-worker embeddings h_n — are pooled by an
    explicit :class:`repro.protocol.Protocol` (a traced pytree, so rebinding
    ``p_miss`` never recompiles).  Returns ``(fused (B,S,K), accounting)``;
    the measured :class:`ProtocolAccounting` is what the serving engine
    converts to per-tick airtime.  ``concat`` protocols are rejected: they
    change the residual width and cannot stand in for an in-block fusion.
    """
    if protocol.kind == "concat":
        raise ValueError(
            "worker_reduce_channel cannot use a concat protocol: the fused "
            "width N*K does not match the block's residual width K")
    out, acct = protocol.aggregate(partial, rng)
    return constrain(out, ("batch", "seq", "embed")), acct


# -- per-tick channel-accounting accumulator (plain dict of scalars so it
#    threads through lax.scan carries without touching ProtocolAccounting) --

def chan_zeros() -> dict:
    """Zeroed channel-accounting accumulator for one decode tick."""
    return {"rounds": jnp.int32(0), "collisions": jnp.int32(0),
            "contention_slots": jnp.int32(0),
            "correct_frac_sum": jnp.float32(0.0), "calls": jnp.int32(0)}


def chan_from_acct(acct) -> dict:
    """One ``ProtocolAccounting`` as an accumulator entry (calls=1)."""
    return {"rounds": acct.rounds, "collisions": acct.collisions,
            "contention_slots": acct.contention_slots,
            "correct_frac_sum": acct.correct_frac, "calls": jnp.int32(1)}


def chan_merge(a: dict, b: dict) -> dict:
    """Elementwise sum of two accumulators (same keys, same dtypes)."""
    return {k: a[k] + b[k] for k in a}


def worker_partial(x_grouped: jax.Array, w: jax.Array,
                   spec: str = "nbsf,nfk->nbsk") -> jax.Array:
    """Per-worker private projection: einsum batched over the worker axis."""
    return jnp.einsum(spec, x_grouped, w)
