"""Wireless-channel communication-load accounting (paper §I / §IV).

Pure-python bookkeeping that turns protocol outcomes into the byte/slot
tables the paper argues from: max-pooling via OCS costs O(K) payloads
(independent of N) against O(N·K) for concat/mean collection.  Also provides
the ICI-side accounting used to cross-check the dry-run's parsed collective
bytes for the TP fusion modes (DESIGN.md §2).

The per-method loaders (``ocs_load``/``concat_load``/``mean_load``) are the
*primitives*; consumers should go through
``repro.protocol.Protocol.comm_load(n_workers, k)``, which resolves the
``ChannelConfig`` — in particular ``payload_bits`` — from the protocol
object itself (ONE source of truth: the D-bit code payload for the
quantized kinds, a full float otherwise) instead of re-deriving it ad hoc
at every call site.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    payload_bits: int = 32          # bits per transmitted feature element
    contention_slot_bits: int = 1   # a blocking signal occupies one bit-slot
    ack_bits: int = 8               # per-sub-frame ACK broadcast by the server
    n_channels: int = 1             # OFDMA parallel channels


# frozen, so one shared instance is a safe signature default (a call in a
# default expression would allocate per-import and trips flake8-bugbear B008)
_DEFAULT_CFG = ChannelConfig()


@dataclasses.dataclass(frozen=True)
class CommLoad:
    """Uplink/downlink load for one aggregation round (forward + backward)."""

    method: str
    n_workers: int
    k_elems: int
    uplink_payload_msgs: int        # feature elements sent worker -> server
    uplink_overhead_bits: int       # contention + ACK overhead
    downlink_msgs: int              # gradient elements server -> worker(s)
    latency_slots: int              # serialized channel occupancy (slots)
    payload_bits: int = 32          # bits per payload message (ChannelConfig)

    @property
    def uplink_bits(self) -> int:
        return self.uplink_payload_msgs * self.payload_bits + self.uplink_overhead_bits

    def as_row(self) -> str:
        return (f"{self.method},{self.n_workers},{self.k_elems},"
                f"{self.uplink_payload_msgs},{self.uplink_overhead_bits},"
                f"{self.downlink_msgs},{self.latency_slots},"
                f"{self.payload_bits}")


def ocs_load(n_workers: int, k_elems: int, bits: int,
             cfg: ChannelConfig = _DEFAULT_CFG) -> CommLoad:
    """FedOCS: K payloads uplink (N-independent), one O(K) broadcast down."""
    id_bits = max(1, math.ceil(math.log2(max(n_workers, 2))))
    contention = k_elems * (bits + id_bits) * cfg.contention_slot_bits
    acks = k_elems * cfg.ack_bits
    payload_slots = k_elems * cfg.payload_bits
    return CommLoad(
        method="fedocs_maxpool",
        n_workers=n_workers,
        k_elems=k_elems,
        uplink_payload_msgs=k_elems,
        uplink_overhead_bits=contention + acks,
        downlink_msgs=k_elems,      # broadcast dL/dv once (paper Eq. 5-6)
        latency_slots=(contention + acks + payload_slots) // cfg.n_channels,
        payload_bits=cfg.payload_bits,
    )


def concat_load(n_workers: int, k_elems: int,
                cfg: ChannelConfig = _DEFAULT_CFG) -> CommLoad:
    """Concat baseline: every worker sends all K elements; grads return per worker."""
    msgs = n_workers * k_elems
    return CommLoad(
        method="concat",
        n_workers=n_workers,
        k_elems=k_elems,
        uplink_payload_msgs=msgs,
        uplink_overhead_bits=0,
        downlink_msgs=msgs,         # dL/dh_n differs per worker
        latency_slots=msgs * cfg.payload_bits // cfg.n_channels,
        payload_bits=cfg.payload_bits,
    )


def mean_load(n_workers: int, k_elems: int,
              cfg: ChannelConfig = _DEFAULT_CFG) -> CommLoad:
    """Mean-pool baseline: every worker still transmits every element."""
    msgs = n_workers * k_elems
    return CommLoad(
        method="mean_pool",
        n_workers=n_workers,
        k_elems=k_elems,
        uplink_payload_msgs=msgs,
        uplink_overhead_bits=0,
        downlink_msgs=k_elems,      # same gradient broadcast to all
        latency_slots=msgs * cfg.payload_bits // cfg.n_channels,
        payload_bits=cfg.payload_bits,
    )


def avg_pred_load(n_workers: int, n_classes: int,
                  cfg: ChannelConfig = _DEFAULT_CFG) -> CommLoad:
    """Prediction-averaging baseline: each worker uploads a class distribution."""
    msgs = n_workers * n_classes
    return CommLoad(
        method="avg_preds",
        n_workers=n_workers,
        k_elems=n_classes,
        uplink_payload_msgs=msgs,
        uplink_overhead_bits=0,
        downlink_msgs=0,            # no backward needed at inference
        latency_slots=msgs * cfg.payload_bits // cfg.n_channels,
        payload_bits=cfg.payload_bits,
    )


# ---------------------------------------------------------------------------
# ICI-side analytical model (cross-check for dry-run parsed collective bytes)
# ---------------------------------------------------------------------------

def ring_allreduce_bytes(elem_bytes: int, payload_elems: int, n_shards: int) -> int:
    """Per-device bytes moved by a ring all-reduce (reduce-scatter + all-gather)."""
    return 2 * (n_shards - 1) * payload_elems * elem_bytes // n_shards


def ring_allgather_bytes(elem_bytes: int, payload_elems: int, n_shards: int) -> int:
    """Per-device bytes for a ring all-gather of per-shard payloads."""
    return (n_shards - 1) * payload_elems * elem_bytes


def tp_fusion_bytes(mode: str, k_elems: int, n_shards: int,
                    dtype_bytes: int = 2) -> int:
    """Collective bytes per device for one TP block fusion of a K-elem feature."""
    if mode in ("sum", "max"):
        return ring_allreduce_bytes(dtype_bytes, k_elems, n_shards)
    if mode == "max_q16":
        return ring_allreduce_bytes(2, k_elems, n_shards)
    if mode == "max_q8":
        return ring_allreduce_bytes(1, k_elems, n_shards)
    if mode == "concat":
        return ring_allgather_bytes(dtype_bytes, k_elems, n_shards)
    raise ValueError(f"unknown fusion mode {mode}")
