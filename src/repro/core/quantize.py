"""Monotone D-bit quantization of floating-point features (paper Eq. 7).

The OCS protocol maps a feature value ``h`` to a backoff period
``g(h) = 2^D - INT(h)`` where ``INT`` reinterprets the float's bit pattern as
an integer (paper §III, footnote 2).  The IEEE-754 trick: for bit pattern
``b`` of a float,

    code(b) = ~b            if the sign bit is set   (negative values)
    code(b) = b | SIGN_BIT  otherwise                (non-negative values)

is a *strictly increasing* total order embedding of float values into unsigned
integers (NaNs excluded).  Truncating to the top ``D`` bits gives the paper's
D-bit backoff code: still monotone (non-strict), so ``max`` over workers of
the D-bit codes selects a true argmax worker up to D-bit resolution — ties in
code space are exactly the paper's contention ties.

Because ``max`` commutes with any monotone map, an ``all-reduce(max)`` may run
directly on the integer codes; this is the basis of the quantized max
collective (DESIGN.md §2.1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_F32_SIGN = jnp.uint32(0x80000000)
_F16_SIGN = jnp.uint16(0x8000)


def _sign_bit_and_width(dtype):
    dtype = jnp.dtype(dtype)
    if dtype == jnp.float32:
        return _F32_SIGN, jnp.uint32, 32
    if dtype in (jnp.bfloat16, jnp.float16):
        return _F16_SIGN, jnp.uint16, 16
    raise ValueError(f"unsupported dtype for monotone code: {dtype}")


def monotone_code(x: jax.Array) -> jax.Array:
    """Order-embed floats into unsigned ints: x < y  <=>  code(x) < code(y).

    Caveat (paper footnote 2 applies equally): -0.0 orders strictly below
    +0.0 although IEEE comparison treats them as equal — harmless for
    max-pooling since both decode back to zero."""
    sign, utype, _ = _sign_bit_and_width(x.dtype)
    b = jax.lax.bitcast_convert_type(x, utype)
    neg = (b & sign) != 0
    return jnp.where(neg, ~b, b | sign)


def monotone_decode(code: jax.Array, dtype) -> jax.Array:
    """Inverse of :func:`monotone_code`."""
    sign, utype, _ = _sign_bit_and_width(dtype)
    code = code.astype(utype)
    neg = (code & sign) == 0          # codes below SIGN_BIT came from negatives
    b = jnp.where(neg, ~code, code & ~sign)
    return jax.lax.bitcast_convert_type(b, jnp.dtype(dtype))


def quantize(x: jax.Array, bits: int) -> jax.Array:
    """D-bit monotone code in ``[0, 2^bits)`` (top ``bits`` of the full code)."""
    _, _, width = _sign_bit_and_width(x.dtype)
    if not (1 <= bits <= width):
        raise ValueError(f"bits must be in [1, {width}], got {bits}")
    code = monotone_code(x)
    shifted = jax.lax.shift_right_logical(
        code, jnp.array(width - bits, code.dtype)
    )
    if bits <= 8:
        return shifted.astype(jnp.uint8)
    if bits <= 16:
        return shifted.astype(jnp.uint16)
    return shifted.astype(jnp.uint32)


def dequantize(code: jax.Array, bits: int, dtype) -> jax.Array:
    """Representative float for a D-bit code (low bits zero-filled).

    Zero-filling the truncated bits makes dequantize(quantize(x)) a
    *round-toward-negative* D-bit rounding of x, so the dequantized max is
    always achievable by a worker (matches the paper: the winner transmits its
    real payload; the code only drives contention).
    """
    _, utype, width = _sign_bit_and_width(dtype)
    full = jax.lax.shift_left(
        code.astype(utype), jnp.array(width - bits, utype)
    )
    out = monotone_decode(full, dtype)
    # The lowest bucket zero-fills into negative-NaN bit space; its monotone-
    # consistent representative is -inf.
    return jnp.where(jnp.isnan(out), jnp.array(-jnp.inf, out.dtype), out)


def backoff_code(x: jax.Array, bits: int) -> jax.Array:
    """Paper Eq. 7: g(h) = 2^D - INT(h) — strictly decreasing in h.

    Returned in the same integer width as :func:`quantize`; the worker with
    the *smallest* backoff (earliest transmission) holds the max feature.
    ``2^D - 1 - code`` keeps the value in [0, 2^D): Eq. 7's offset by one slot
    has no effect on ordering.
    """
    q = quantize(x, bits)
    maxcode = jnp.array((1 << bits) - 1, q.dtype)
    return maxcode - q
