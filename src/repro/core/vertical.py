"""The paper's hierarchical vertical learner (§II): N private encoders + a
shared fusion head, trained end-to-end through a pooled embedding.

This is the *paper-faithful* model used by ``examples/reconstruction.py``
(§IV-A, multi-sensor MNIST-like denoising) and
``examples/patch_classification.py`` (§IV-B, CIFAR-like patch grids), and by
the Table-I benchmark.  Worker encoders are stored with a leading worker axis
(N, ...) — the same worker-axis formulation the big-model stack uses — so the
identical code runs single-host (vmap over workers) or sharded (worker axis on
the ``model`` mesh axis).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import channel, fedocs


@dataclasses.dataclass(frozen=True)
class VerticalConfig:
    n_workers: int = 4
    input_dim: int = 784                 # per-worker view dimension (x_n)
    encoder_dims: Sequence[int] = (512, 256, 128)
    embed_dim: int = 64                  # K — the transmitted feature width
    head_dims: Sequence[int] = (128, 256, 512)
    output_dim: int = 784                # recon: global dim / cls: |C|
    task: str = "reconstruction"         # "reconstruction" | "classification"
    aggregation: str = "max"             # fedocs.VALID_MODES
    tie_break: str = "all"
    noise_bits: int = 16                 # max_noisy: backoff/payload depth D
    noise_max_rounds: int = 3            # max_noisy: re-contention bound
    noise_backend: str = "scan"          # max_noisy: "scan" | "pallas"
    prediction_level: bool = False       # True => per-worker heads (baselines
                                         # "Avg. Workers Preds"/"Best Worker")
    dtype: jnp.dtype = jnp.float32

    def head_input_dim(self) -> int:
        if self.prediction_level:
            return self.embed_dim
        return fedocs.output_dim(self.aggregation, self.n_workers, self.embed_dim)


def _dense_init(rng, fan_in: int, fan_out: int, dtype) -> dict:
    w = jax.random.normal(rng, (fan_in, fan_out), dtype) * jnp.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros((fan_out,), dtype)}


def _mlp_init(rng, dims: Sequence[int], dtype) -> list:
    rngs = jax.random.split(rng, len(dims) - 1)
    return [_dense_init(r, dims[i], dims[i + 1], dtype)
            for i, r in enumerate(rngs)]


def _mlp_apply(params: list, x: jax.Array, final_act: bool = False) -> jax.Array:
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def init(cfg: VerticalConfig, rng: jax.Array) -> dict:
    enc_rng, head_rng = jax.random.split(rng)
    enc_dims = (cfg.input_dim, *cfg.encoder_dims, cfg.embed_dim)
    # private per-worker encoders: leading worker axis on every leaf
    enc = jax.vmap(lambda r: _mlp_init(r, enc_dims, cfg.dtype))(
        jax.random.split(enc_rng, cfg.n_workers))
    head_dims = (cfg.head_input_dim(), *cfg.head_dims, cfg.output_dim)
    if cfg.prediction_level:
        head = jax.vmap(lambda r: _mlp_init(r, head_dims, cfg.dtype))(
            jax.random.split(head_rng, cfg.n_workers))
    else:
        head = _mlp_init(head_rng, head_dims, cfg.dtype)
    return {"encoders": enc, "head": head}


def embeddings(cfg: VerticalConfig, params: dict, views: jax.Array) -> jax.Array:
    """h_n = f_n(x_n; theta_n).  views: (N, B, input_dim) -> (N, B, K)."""
    return jax.vmap(_mlp_apply)(params["encoders"], views)


def forward(cfg: VerticalConfig, params: dict, views: jax.Array, *,
            noise: Optional[fedocs.ChannelNoise] = None) -> jax.Array:
    """Full fusion forward: views (N, B, d) -> prediction (B, output_dim).

    ``noise`` is required when ``cfg.aggregation == 'max_noisy'`` — the
    embeddings are then fused through the simulated OCS channel (traced
    ``rng``/``p_miss``, static ``cfg.noise_bits``/``cfg.noise_max_rounds``).
    """
    h = embeddings(cfg, params, views)
    if cfg.prediction_level:
        preds = jax.vmap(_mlp_apply)(params["head"], h)       # (N, B, out)
        if cfg.task == "classification":
            preds = jax.nn.softmax(preds, axis=-1)
        return jnp.mean(preds, axis=0)                        # Avg. Workers Preds
    v = fedocs.aggregate(h, cfg.aggregation, tie_break=cfg.tie_break,
                         noise=noise, noise_bits=cfg.noise_bits,
                         noise_max_rounds=cfg.noise_max_rounds,
                         noise_backend=cfg.noise_backend)
    return _mlp_apply(params["head"], v)


def per_worker_predictions(cfg: VerticalConfig, params: dict,
                           views: jax.Array) -> jax.Array:
    """(N, B, out) — used by the 'Best Worker Pred' baseline."""
    assert cfg.prediction_level
    h = embeddings(cfg, params, views)
    return jax.vmap(_mlp_apply)(params["head"], h)


def loss_fn(cfg: VerticalConfig, params: dict, views: jax.Array,
            target: jax.Array, *,
            noise: Optional[fedocs.ChannelNoise] = None
            ) -> Tuple[jax.Array, dict]:
    pred = forward(cfg, params, views, noise=noise)
    if cfg.task == "reconstruction":
        # Paper Eq. 2 squared error == Gaussian NLL up to constants; we report
        # per-pixel NLL with unit variance /2 convention for Fig.2 comparison.
        loss = jnp.mean((pred - target) ** 2)
        return loss, {"mse": loss, "nll": 0.5 * loss}
    if cfg.task == "classification":
        if cfg.prediction_level:
            # pred is averaged prob already
            logp = jnp.log(jnp.clip(pred, 1e-9))
        else:
            logp = jax.nn.log_softmax(pred, axis=-1)
        nll = -jnp.mean(jnp.take_along_axis(logp, target[:, None], axis=-1))
        acc = jnp.mean(jnp.argmax(logp, -1) == target)
        return nll, {"nll": nll, "acc": acc}
    raise ValueError(cfg.task)


def comm_load(cfg: VerticalConfig, bits: int = 16) -> channel.CommLoad:
    """Per-sample uplink/downlink accounting for the configured aggregation."""
    if cfg.prediction_level:
        return channel.avg_pred_load(cfg.n_workers, cfg.output_dim)
    if cfg.aggregation in ("max", "max_q16", "max_q8", "max_noisy"):
        b = {"max": bits, "max_q16": 16, "max_q8": 8,
             "max_noisy": cfg.noise_bits}[cfg.aggregation]
        if cfg.aggregation == "max":
            # plain max transmits the winner's full float payload; the
            # D bits only drive contention
            return channel.ocs_load(cfg.n_workers, cfg.embed_dim, b)
        # every quantized-code mode pools the dequantized D-bit code, so the
        # winner's uplink payload is the D-bit code itself
        ccfg = channel.ChannelConfig(payload_bits=b)
        return channel.ocs_load(cfg.n_workers, cfg.embed_dim, b, cfg=ccfg)
    if cfg.aggregation == "mean":
        return channel.mean_load(cfg.n_workers, cfg.embed_dim)
    return channel.concat_load(cfg.n_workers, cfg.embed_dim)
