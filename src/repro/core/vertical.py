"""The paper's hierarchical vertical learner (§II): N private encoders + a
shared fusion head, trained end-to-end through a pooled embedding.

This is the *paper-faithful* model used by ``examples/reconstruction.py``
(§IV-A, multi-sensor MNIST-like denoising) and
``examples/patch_classification.py`` (§IV-B, CIFAR-like patch grids), and by
the Table-I benchmark.  Worker encoders are stored with a leading worker axis
(N, ...) — the same worker-axis formulation the big-model stack uses — so the
identical code runs single-host (vmap over workers) or sharded (worker axis on
the ``model`` mesh axis).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import channel

# NOTE: repro.protocol imports repro.core at import time (for the
# aggregation primitives), so the Protocol class is imported lazily inside
# the functions below instead of at module scope.
if TYPE_CHECKING:
    from repro.protocol import Protocol


@dataclasses.dataclass(frozen=True)
class VerticalConfig:
    n_workers: int = 4
    input_dim: int = 784                 # per-worker view dimension (x_n)
    encoder_dims: Sequence[int] = (512, 256, 128)
    embed_dim: int = 64                  # K — the transmitted feature width
    head_dims: Sequence[int] = (128, 256, 512)
    output_dim: int = 784                # recon: global dim / cls: |C|
    task: str = "reconstruction"         # "reconstruction" | "classification"
    # the fusion protocol: a repro.protocol.Protocol, or (legacy sugar) one
    # of the fedocs.VALID_MODES strings, resolved together with the
    # tie_break/noise_* fields by resolve_protocol()
    aggregation: Union[str, "Protocol"] = "max"
    tie_break: str = "all"
    noise_bits: int = 16                 # max_noisy: backoff/payload depth D
    noise_max_rounds: int = 3            # max_noisy: re-contention bound
    noise_backend: str = "scan"          # max_noisy: "scan" | "pallas"
    prediction_level: bool = False       # True => per-worker heads (baselines
                                         # "Avg. Workers Preds"/"Best Worker")
    dtype: jnp.dtype = jnp.float32

    def resolve_protocol(self) -> "Protocol":
        """The configured fusion protocol as a first-class object.

        A ``Protocol`` passed in ``aggregation`` is returned as-is; a legacy
        mode string is combined with the ``tie_break``/``noise_*`` fields
        (``Protocol.from_mode`` — same semantics as the retired
        string-mode dispatch).
        """
        from repro.protocol import Protocol
        if isinstance(self.aggregation, Protocol):
            return self.aggregation
        return Protocol.from_mode(
            self.aggregation, tie_break=self.tie_break, bits=self.noise_bits,
            max_rounds=self.noise_max_rounds, backend=self.noise_backend)

    def head_input_dim(self) -> int:
        if self.prediction_level:
            return self.embed_dim
        return self.resolve_protocol().output_dim(self.n_workers,
                                                  self.embed_dim)


def _dense_init(rng, fan_in: int, fan_out: int, dtype) -> dict:
    w = jax.random.normal(rng, (fan_in, fan_out), dtype) * jnp.sqrt(2.0 / fan_in)
    return {"w": w, "b": jnp.zeros((fan_out,), dtype)}


def _mlp_init(rng, dims: Sequence[int], dtype) -> list:
    rngs = jax.random.split(rng, len(dims) - 1)
    return [_dense_init(r, dims[i], dims[i + 1], dtype)
            for i, r in enumerate(rngs)]


def _mlp_apply(params: list, x: jax.Array, final_act: bool = False) -> jax.Array:
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def init(cfg: VerticalConfig, rng: jax.Array) -> dict:
    enc_rng, head_rng = jax.random.split(rng)
    enc_dims = (cfg.input_dim, *cfg.encoder_dims, cfg.embed_dim)
    # private per-worker encoders: leading worker axis on every leaf
    enc = jax.vmap(lambda r: _mlp_init(r, enc_dims, cfg.dtype))(
        jax.random.split(enc_rng, cfg.n_workers))
    head_dims = (cfg.head_input_dim(), *cfg.head_dims, cfg.output_dim)
    if cfg.prediction_level:
        head = jax.vmap(lambda r: _mlp_init(r, head_dims, cfg.dtype))(
            jax.random.split(head_rng, cfg.n_workers))
    else:
        head = _mlp_init(head_rng, head_dims, cfg.dtype)
    return {"encoders": enc, "head": head}


def embeddings(cfg: VerticalConfig, params: dict, views: jax.Array) -> jax.Array:
    """h_n = f_n(x_n; theta_n).  views: (N, B, input_dim) -> (N, B, K)."""
    return jax.vmap(_mlp_apply)(params["encoders"], views)


def _fuse_forward(cfg: VerticalConfig, params: dict, views: jax.Array,
                  rng, protocol, fault=None, fault_state=None):
    """Shared forward: (prediction, accounting-or-None, protocol-or-None,
    new-fault-state-or-None)."""
    h = embeddings(cfg, params, views)
    if cfg.prediction_level:
        preds = jax.vmap(_mlp_apply)(params["head"], h)       # (N, B, out)
        if cfg.task == "classification":
            preds = jax.nn.softmax(preds, axis=-1)
        return jnp.mean(preds, axis=0), None, None, None      # Avg. Workers Preds
    proto = protocol if protocol is not None else cfg.resolve_protocol()
    if fault is not None:
        from repro import faults                   # lazy: faults -> protocol
        v, new_state, acct = faults.aggregate(proto, fault, fault_state, h,
                                              rng)
        return _mlp_apply(params["head"], v), acct, proto, new_state
    v, acct = proto.aggregate(h, rng)
    return _mlp_apply(params["head"], v), acct, proto, None


def forward(cfg: VerticalConfig, params: dict, views: jax.Array, *,
            rng: Optional[jax.Array] = None,
            protocol: Optional[Protocol] = None) -> jax.Array:
    """Full fusion forward: views (N, B, d) -> prediction (B, output_dim).

    The embeddings are fused by ``cfg.resolve_protocol()`` — or by
    ``protocol`` when given, the traced per-call override the curve engine
    uses to vmap a ``p_miss`` lane axis.  An OCS protocol additionally
    needs ``rng`` (the sensing PRNG key); both are ordinary traced values.
    """
    pred, _, _, _ = _fuse_forward(cfg, params, views, rng, protocol)
    return pred


def per_worker_predictions(cfg: VerticalConfig, params: dict,
                           views: jax.Array) -> jax.Array:
    """(N, B, out) — used by the 'Best Worker Pred' baseline."""
    assert cfg.prediction_level
    h = embeddings(cfg, params, views)
    return jax.vmap(_mlp_apply)(params["head"], h)


def loss_fn(cfg: VerticalConfig, params: dict, views: jax.Array,
            target: jax.Array, *,
            rng: Optional[jax.Array] = None,
            protocol: Optional[Protocol] = None,
            fault=None, fault_state=None
            ) -> Tuple[jax.Array, dict]:
    """Task loss + metrics.  For an OCS fusion protocol the metrics carry
    the measured channel telemetry of this step's aggregate call
    (``chan_rounds``, ``chan_collision_frac``, ``chan_correct_frac``) —
    the signal :class:`repro.protocol.BitsSchedule` policies consume.
    ``chan_collision_frac`` is a true fraction in [0, 1]: collided
    re-contention opportunities over the ``K * max_rounds`` available
    (the core bills a sub-frame once per round it stays collided).

    ``fault``/``fault_state`` (a ``repro.faults.FaultModel`` + carried
    ``FaultState``) switch the aggregation to the fault-aware path: the
    metrics then additionally carry the evolved carry under
    ``metrics["fault_state"]`` (a pytree — pop it before scalar logging)
    and the degradation telemetry scalars (``fault_dropped_frames``,
    ``fault_stale_age``, ``fault_offline``, ``fault_retry_slots``,
    ``fault_outage``)."""
    pred, acct, proto, new_fault_state = _fuse_forward(
        cfg, params, views, rng, protocol, fault, fault_state)
    if cfg.task == "reconstruction":
        # Paper Eq. 2 squared error == Gaussian NLL up to constants; we report
        # per-pixel NLL with unit variance /2 convention for Fig.2 comparison.
        loss = jnp.mean((pred - target) ** 2)
        metrics = {"mse": loss, "nll": 0.5 * loss}
    elif cfg.task == "classification":
        if cfg.prediction_level:
            # pred is averaged prob already
            logp = jnp.log(jnp.clip(pred, 1e-9))
        else:
            logp = jax.nn.log_softmax(pred, axis=-1)
        nll = -jnp.mean(jnp.take_along_axis(logp, target[:, None], axis=-1))
        acc = jnp.mean(jnp.argmax(logp, -1) == target)
        loss, metrics = nll, {"nll": nll, "acc": acc}
    else:
        raise ValueError(cfg.task)
    if acct is not None and proto.kind == "ocs":
        # collisions are billed once per (sub-frame, round) a sub-frame
        # stays collided, so the fraction normalizes over all K*max_rounds
        # re-contention opportunities of this aggregate call
        k_total = views.shape[1] * cfg.embed_dim      # batch * K elements
        metrics["chan_rounds"] = acct.rounds.astype(jnp.float32)
        metrics["chan_collision_frac"] = (
            acct.collisions.astype(jnp.float32)
            / (k_total * proto.max_rounds))
        metrics["chan_correct_frac"] = acct.correct_frac
    if new_fault_state is not None:
        metrics["fault_state"] = new_fault_state
        metrics["fault_dropped_frames"] = acct.dropped_frames
        metrics["fault_stale_age"] = acct.stale_age
        metrics["fault_offline"] = acct.offline_workers
        metrics["fault_retry_slots"] = acct.retry_slots
        metrics["fault_outage"] = acct.outage
    return loss, metrics


def comm_load(cfg: VerticalConfig, bits: int = 16) -> channel.CommLoad:
    """Per-sample uplink/downlink accounting for the configured protocol.

    Delegates to ``Protocol.comm_load`` — the one payload-bits source of
    truth (D-bit code payloads for the quantized kinds, floats otherwise).
    ``bits`` only parameterizes the contention depth of the plain-``max``
    protocol (whose payload stays a full float), preserving the historical
    signature.
    """
    if cfg.prediction_level:
        return channel.avg_pred_load(cfg.n_workers, cfg.output_dim)
    proto = cfg.resolve_protocol()
    if proto.kind == "max" and proto.bits != bits:
        proto = dataclasses.replace(proto, bits=bits)
    return proto.comm_load(cfg.n_workers, cfg.embed_dim)
