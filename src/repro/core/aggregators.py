"""Registry of the five Table-I aggregation methods (paper §IV-B).

Maps the paper's method names onto :mod:`repro.core.vertical` configurations
— each embedding-level method carries its fusion law as a first-class
``repro.protocol.Protocol`` — so benchmarks and examples can sweep them
uniformly.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.vertical import VerticalConfig

TABLE1_METHODS = (
    "concat_workers_embed",
    "best_worker_pred",
    "avg_workers_preds",
    "avg_workers_embed",
    "fedocs",
)


def table1_config(method: str, base: VerticalConfig) -> VerticalConfig:
    """Specialize a base vertical config to one of the paper's five methods."""
    # lazy: repro.protocol imports repro.core at import time
    from repro.protocol import Protocol
    if method == "concat_workers_embed":
        return dataclasses.replace(base, aggregation=Protocol.concat(),
                                   prediction_level=False)
    if method == "avg_workers_embed":
        return dataclasses.replace(base, aggregation=Protocol.mean(),
                                   prediction_level=False)
    if method == "fedocs":
        return dataclasses.replace(
            base, aggregation=Protocol.max(tie_break=base.tie_break),
            prediction_level=False)
    if method in ("avg_workers_preds", "best_worker_pred"):
        # both train per-worker heads; they differ only at evaluation time
        return dataclasses.replace(base, prediction_level=True)
    raise ValueError(f"unknown Table-I method {method!r}")


def display_name(method: str) -> str:
    return {
        "concat_workers_embed": "Concat Workers Embed",
        "best_worker_pred": "Best Worker Pred",
        "avg_workers_preds": "Avg. Workers Preds",
        "avg_workers_embed": "Avg. Workers Embed",
        "fedocs": "FedOCS (max-pool)",
    }[method]


def all_configs(base: VerticalConfig) -> Dict[str, VerticalConfig]:
    return {m: table1_config(m, base) for m in TABLE1_METHODS}
