"""FedOCS core: the paper's contribution as composable JAX modules.

- quantize:    Eq. 7 monotone D-bit codes (order-exact quantization)
- ocs:         Algorithm 1 MAC-layer distributed-argmax simulator
- fedocs:      pooled aggregation laws (max / quantized-max / mean / concat)
               with winner-routed custom_vjp backward (Eq. 5-6); the
               string-mode dispatcher is deprecated in favor of
               repro.protocol.Protocol
- channel:     wireless + ICI communication-load accounting (consumed via
               Protocol.comm_load)
- vertical:    the paper's split encoder/fusion-head learner (§II)
- aggregators: Table-I method registry (§IV-B)
"""

from repro.core import aggregators, channel, fedocs, ocs, quantize, vertical

__all__ = ["aggregators", "channel", "fedocs", "ocs", "quantize", "vertical"]
