"""Opportunistic Carrier Sensing (OCS) max-pooling protocol — paper §III, Alg. 1.

Discrete-event simulation of the MAC-layer distributed argmax.  The protocol
runs K sub-frames (one per feature element).  In sub-frame k, each worker n
derives a D-bit backoff code from its feature value ``h[n, k]`` (Eq. 7) and
contends bit-by-bit, MSB first:

  * sub-slot d: workers whose backoff bit is 0 transmit a *blocking signal*;
    workers whose backoff bit is 1 stay silent and *sense*.
  * a sensing worker that hears a blocking signal quits the contention
    (Alg. 1 line 4) — some still-alive worker provably holds a larger code;
  * if nobody transmitted in the slot, every survivor continues (no
    information was revealed; Alg. 1 line 7, "no ACK received").

After D sub-slots, the survivors are exactly the workers holding the maximal
D-bit code.  The paper's ACK mechanism resolves ties; we realize it as a
deterministic extension: ``ceil(log2 N)`` extra ID sub-slots in which each
survivor contends with the bitwise complement of its unique worker index, so
the *lowest-indexed* tied worker wins (this is the fusion center ACK-ing a
single decodable preamble).  The winner then transmits its payload
(Alg. 1 line 9).

Two layers:

  * ``ocs_maxpool_core`` / ``ocs_maxpool_noisy_core`` — batched cores.  They
    take a padded worker axis plus a boolean ``mask`` of real workers and a
    *traced* ``id_bits``, so one compiled computation can evaluate many
    ``(N, p_miss)`` scenarios via ``vmap`` (see ``repro.sim.sweep``).  The
    bit-slot scan runs a static ``bits + max_id_bits`` sub-slots; slots past
    the scenario's ``bits + id_bits`` are inert, so the channel accounting is
    bit-for-bit identical to an unpadded run.
  * ``ocs_maxpool`` / ``ocs_maxpool_noisy`` — the single-round convenience
    wrappers (all workers real, exact scan length), used by the tests and
    the protocol-equivalence oracles.

The simulator is fully vectorized (a `lax.scan` over bit-slots) and jittable;
it returns both the selection result and the channel accounting used by
``benchmarks/bench_comm.py`` to reproduce the paper's O(K)-vs-O(N·K) claim.

The TPU system does not use this MAC (DESIGN.md §2 — ICI is a switched
fabric); the simulator exists to validate the protocol the paper actually
proposes and to generate the wireless-side communication-load tables.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import quantize as qz


@dataclasses.dataclass(frozen=True)
class OCSResult:
    """Outcome of one max-pooling round over the shared channel."""

    winner: jax.Array            # (K,) int32 — worker index that transmits element k
    value: jax.Array             # (K,) float — payload transmitted (winner's h)
    pooled_code: jax.Array       # (K,) uint  — max D-bit code (what contention decides)
    ties: jax.Array              # (K,) int32 — number of workers tied at the max code
    contention_slots: jax.Array  # ()  int32  — total contention sub-slots consumed
    blocking_tx: jax.Array       # ()  int32  — total blocking-signal transmissions
    payload_tx: jax.Array        # ()  int32  — total payload transmissions (== K)
    # baselines for the same round (paper §IV comparison):
    concat_payload_tx: jax.Array  # () int32 — N*K payloads (concat / mean-pool)


@dataclasses.dataclass(frozen=True)
class NoisyOCSResult:
    """Outcome under imperfect sensing (the paper assumes error-free §IV)."""

    winner: jax.Array            # (K,) int32 — final payload transmitter
    correct: jax.Array           # (K,) bool  — winner holds the true max code
    collisions: jax.Array        # ()  int32  — sub-frames needing re-contention
    rounds: jax.Array            # ()  int32  — rounds until every sub-frame
    #   resolved (== max_rounds when lowest-index capture was needed)
    contention_slots: jax.Array  # ()  int32  — re-contention counts only the
    #   sub-frames still unresolved at the start of each round


@dataclasses.dataclass(frozen=True)
class MultichannelOCSResult:
    """OFDMA variant outcome: untouched protocol accounting + channel latency.

    ``result.contention_slots`` keeps the *total* contention sub-slots (the
    transmission count consumers read); the wall-clock benefit of striping
    over orthogonal channels lives in ``latency_slots`` only, mirroring
    ``repro.sim.sweep.SweepResult.*_latency_slots``.
    """

    result: OCSResult
    latency_slots: jax.Array     # () int32 — ceil(contention_slots / n_channels)


# Registered as pytrees so the batched cores can return them through
# jit/vmap and the sweep engine can stack them along scenario/round axes.
for _cls in (OCSResult, NoisyOCSResult, MultichannelOCSResult):
    jax.tree_util.register_dataclass(
        _cls,
        data_fields=[f.name for f in dataclasses.fields(_cls)],
        meta_fields=[],
    )


def host_id_bits(n_workers: int) -> int:
    """ID sub-slots needed to tie-break N workers: ceil(log2(max(N, 2)))."""
    return max(1, math.ceil(math.log2(max(n_workers, 2))))


def _id_codes(n_workers: int, id_bits: jax.Array) -> jax.Array:
    """Per-worker tie-break codes: complement of index => lowest index wins max.

    ``id_bits`` may be traced; codes for indices >= 2**id_bits wrap around in
    uint32 — those rows must be masked out by the caller (padded workers).
    """
    idx = jnp.arange(n_workers, dtype=jnp.uint32)
    top = (jnp.uint32(1) << jnp.asarray(id_bits).astype(jnp.uint32)) - jnp.uint32(1)
    return top - idx


def sensing_keep_prob(p_miss: jax.Array, dtype) -> jax.Array:
    """Per-sub-slot hear probability, broadcastable over an (N, K) slot.

    ``p_miss`` is either a scalar (every worker senses equally well) or a
    per-worker ``(N,)`` array (heterogeneous near/far users: a far worker
    overhears blocking signals with lower probability).  Returns ``1 - p``
    shaped ``()`` or ``(N, 1)`` so ``bernoulli(key, p_keep, (N, K))`` draws
    the worker axis down the leading dimension.  With every entry equal the
    vector path is bit-for-bit the scalar path (the uniform draw does not
    depend on the threshold; property-tested).
    """
    dt = dtype if jnp.issubdtype(jnp.dtype(dtype), jnp.floating) else jnp.float32
    p = jnp.asarray(p_miss, dt)
    if p.ndim == 0:
        return 1.0 - p
    if p.ndim == 1:
        return 1.0 - p[:, None]
    raise ValueError(f"p_miss must be scalar or (N,), got shape {p.shape}")


def sensing_heard(key: jax.Array, p_keep: jax.Array, n: int, k: int) -> jax.Array:
    """One sub-slot of carrier-sensing draws: heard[n, k] ~ Bern(p_keep[n]).

    The single place the sensing randomness is drawn — the ``lax.scan``
    protocol core consumes it slot by slot and the fused Pallas contention
    kernel (``repro.kernels.ocs_contention``) pre-draws the identical stream
    by vmapping this helper over the (round, sub-slot) key grid, which keeps
    the two backends bit-for-bit interchangeable.
    """
    return jax.random.bernoulli(key, p_keep, (n, k))


def ocs_maxpool_core(h: jax.Array, mask: jax.Array, id_bits: jax.Array, *,
                     bits: int, max_id_bits: int) -> OCSResult:
    """Batched Algorithm 1 core over a padded worker axis.

    Args:
      h:           (N_max, K) worker feature matrix; padded rows are ignored.
      mask:        (N_max,) bool — True for real workers (>=1 must be real).
      id_bits:     () int32 — tie-break sub-slots for the *real* worker count
                   (``host_id_bits(n)``); may be a traced value so scenarios
                   with different N share one compilation.
      bits:        D, the backoff quantization depth (static).
      max_id_bits: static scan-length bound; must satisfy
                   ``max_id_bits >= id_bits`` for every batched scenario.

    Returns:
      OCSResult with accounting identical, bit for bit, to an unpadded
      ``ocs_maxpool`` run at the real worker count (property-tested in
      ``tests/test_sweep.py``): sub-slots past ``bits + id_bits`` are gated
      off, so neither ``contention_slots`` nor ``blocking_tx`` see them.
    """
    if bits + max_id_bits > 32:
        raise ValueError(
            f"contention word overflows uint32: bits={bits} + "
            f"max_id_bits={max_id_bits} > 32")
    n_max, k_elems = h.shape
    qcodes = qz.quantize(h, bits)                              # (N_max, K)
    codes = qcodes.astype(jnp.uint32)
    id_bits = jnp.asarray(id_bits, jnp.int32)
    ids = _id_codes(n_max, id_bits)                            # (N_max,)
    # Full contention word: [ value code | id code ] — MSB-first tournament
    # over this word is (a) Alg. 1 for the top `bits` slots, (b) the ACK
    # tie-break for the bottom `id_bits` slots.
    word = (codes << id_bits.astype(jnp.uint32)) | ids[:, None]  # (N_max, K)
    total_bits = bits + id_bits                                # () int32

    def slot(carry, d):
        alive, slots, blocks = carry
        active = d < total_bits
        shift = jnp.maximum(total_bits - 1 - d, 0).astype(jnp.uint32)
        bit = (word >> shift) & jnp.uint32(1)                  # (N_max, K)
        tx = alive & (bit == 1) & active                       # blocking transmitters
        any_tx = jnp.any(tx, axis=0, keepdims=True)            # (1, K)
        # sensing workers (bit==0) quit iff someone transmitted (Alg.1 l.3-4);
        # otherwise everyone continues (Alg.1 l.6-7).  Inactive (padding)
        # slots transmit nothing, so they are no-ops.
        alive = alive & (tx | ~any_tx)
        slots = slots + jnp.where(active, k_elems, 0).astype(jnp.int32)
        blocks = blocks + jnp.sum(tx, dtype=jnp.int32)
        return (alive, slots, blocks), None

    alive0 = jnp.broadcast_to(mask[:, None], (n_max, k_elems))
    (alive, slots, blocks), _ = jax.lax.scan(
        slot,
        (alive0, jnp.int32(0), jnp.int32(0)),
        jnp.arange(bits + max_id_bits),
    )

    # After value+id slots exactly one real worker survives per sub-frame.
    winner = jnp.argmax(alive, axis=0).astype(jnp.int32)       # (K,)
    at_max = (codes == jnp.max(jnp.where(mask[:, None], codes, 0),
                               axis=0)[None, :]) & mask[:, None]
    pooled_code = jnp.max(jnp.where(mask[:, None], codes, 0), axis=0)
    ties = jnp.sum(at_max, axis=0).astype(jnp.int32)
    value = jnp.take_along_axis(h, winner[None, :], axis=0)[0]
    n_workers = jnp.sum(mask, dtype=jnp.int32)

    return OCSResult(
        winner=winner,
        value=value,
        pooled_code=pooled_code.astype(qcodes.dtype),
        ties=ties,
        contention_slots=slots,
        blocking_tx=blocks,
        payload_tx=jnp.int32(k_elems),
        concat_payload_tx=n_workers * k_elems,
    )


def ocs_maxpool(h: jax.Array, bits: int = 16) -> OCSResult:
    """Run Algorithm 1 for all K sub-frames of one aggregation round.

    Args:
      h:    (N, K) worker feature matrix (float32/bf16/f16).
      bits: D, the backoff quantization depth (paper Eq. 7).

    Returns:
      OCSResult. ``winner``/``pooled_code`` are exactly
      ``argmax/max(quantize(h), axis=0)`` with lowest-index tie-break — this
      equivalence is property-tested in ``tests/test_ocs.py``.
    """
    if h.ndim != 2:
        raise ValueError(f"h must be (N, K), got {h.shape}")
    n_workers = h.shape[0]
    id_bits = host_id_bits(n_workers)
    return ocs_maxpool_core(
        h, jnp.ones((n_workers,), dtype=bool), id_bits,
        bits=bits, max_id_bits=id_bits)


def ocs_maxpool_multichannel(h: jax.Array, bits: int = 16,
                             n_channels: int = 4) -> MultichannelOCSResult:
    """Multi-channel (OFDMA) variant — paper §III ref [16].

    K sub-frames are striped over ``n_channels`` orthogonal channels running
    the same contention in parallel; selection results and total slot counts
    are identical, wall time divides by ``n_channels``.  The returned
    ``result`` is exactly the single-channel :func:`ocs_maxpool` outcome
    (``contention_slots`` stays the total transmission-slot count);
    ``latency_slots`` carries the striped wall-clock figure.
    """
    res = ocs_maxpool(h, bits)
    # contention latency improves; transmission counts are unchanged.
    return MultichannelOCSResult(
        result=res,
        latency_slots=(res.contention_slots + n_channels - 1) // n_channels,
    )


def reference_maxpool(h: jax.Array, bits: int):
    """Pure-jnp oracle for the protocol outcome (used by tests)."""
    codes = qz.quantize(h, bits)
    pooled_code = jnp.max(codes, axis=0)
    winner = jnp.argmax(codes == pooled_code[None, :], axis=0).astype(jnp.int32)
    value = jnp.take_along_axis(h, winner[None, :], axis=0)[0]
    return winner, value, pooled_code


# ---------------------------------------------------------------------------
# beyond-paper: imperfect carrier sensing
# ---------------------------------------------------------------------------

NOISY_BACKENDS = ("scan", "pallas")


def ocs_maxpool_noisy_core(h: jax.Array, mask: jax.Array, id_bits: jax.Array,
                           rng: jax.Array, p_miss: jax.Array, *,
                           bits: int, max_id_bits: int,
                           max_rounds: int = 3,
                           backend: str = "scan") -> NoisyOCSResult:
    """Batched imperfect-sensing core (padded N, traced ``id_bits``/``p_miss``).

    Same contract as :func:`ocs_maxpool_core`; additionally ``p_miss`` may be
    a traced scalar — or a per-worker ``(N_max,)`` array for heterogeneous
    near/far users — so a whole miss-probability axis of a scenario grid
    shares one compilation.  With ``max_id_bits == id_bits`` the random-bit
    consumption matches the historical unbatched implementation exactly.

    ``backend`` selects the contention engine:

      * ``"scan"``  — the reference ``lax.scan`` over (max_rounds x sub-slot)
        steps, one Bernoulli draw + alive update per sub-slot;
      * ``"pallas"`` — the fused ``repro.kernels.ocs_contention`` kernel: the
        sensing stream is pre-drawn in one batched call and packed into
        uint32 bit-planes, and the whole tournament runs in a single VMEM
        pass (interpret-mode on CPU hosts).  Bit-for-bit identical to
        ``"scan"`` in every ``NoisyOCSResult`` field (property-tested in
        ``tests/test_kernels_contention.py``).
    """
    if bits + max_id_bits > 32:
        raise ValueError(
            f"contention word overflows uint32: bits={bits} + "
            f"max_id_bits={max_id_bits} > 32")
    if backend not in NOISY_BACKENDS:
        raise ValueError(
            f"unknown noisy-OCS backend {backend!r}; valid: {NOISY_BACKENDS}")
    n_max, k_elems = h.shape
    codes = qz.quantize(h, bits).astype(jnp.uint32)
    id_bits = jnp.asarray(id_bits, jnp.int32)
    ids = _id_codes(n_max, id_bits)
    word = (codes << id_bits.astype(jnp.uint32)) | ids[:, None]
    total_bits = bits + id_bits
    n_slots = bits + max_id_bits
    p_keep = sensing_keep_prob(p_miss, h.dtype)

    if backend == "pallas":
        # imported lazily: the kernels layer is optional and core must not
        # pull Pallas in for scan-only users.
        from repro.kernels.ocs_contention import ops as contention_ops

        winner, contending, collided = contention_ops.noisy_contention(
            word, mask, total_bits, rng, p_keep,
            n_slots=n_slots, max_rounds=max_rounds)
        # pin the accumulators: jnp.sum promotes int/bool to the platform
        # int, which becomes int64 under JAX_ENABLE_X64
        slots = (total_bits.astype(jnp.int32)
                 * jnp.sum(contending, dtype=jnp.int32))
        rounds = jnp.sum(contending > 0, dtype=jnp.int32)
        collisions = jnp.sum(collided, dtype=jnp.int32)
    else:
        def contention_round(alive, key):
            def slot(alive, d):
                active = d < total_bits
                shift = jnp.maximum(total_bits - 1 - d, 0).astype(jnp.uint32)
                bit = (word >> shift) & jnp.uint32(1)
                tx = alive & (bit == 1) & active
                any_tx = jnp.any(tx, axis=0, keepdims=True)
                heard = sensing_heard(
                    jax.random.fold_in(key, d), p_keep, n_max, k_elems)
                # a sensing worker quits only if someone transmitted AND it
                # heard
                alive = alive & (tx | ~(any_tx & heard))
                return alive, None

            alive, _ = jax.lax.scan(slot, alive, jnp.arange(n_slots))
            return alive

        def round_body(carry, r):
            alive, slots, rounds, done = carry
            key = jax.random.fold_in(rng, r)
            # only sub-frames still unresolved at round start re-contend:
            # they alone consume channel slots (bits + id_bits sub-slots
            # each); a resolved sub-frame's lone survivor keeps its claim
            # untouched.
            contending = jnp.sum(~done, dtype=jnp.int32)      # () sub-frames
            survivors = contention_round(alive, key)
            n_surv = jnp.sum(survivors, axis=0)               # (K,)
            collided = n_surv > 1
            # collided sub-frames re-contend among survivors; resolved keep
            # winner
            new_done = done | ~collided
            slots = slots + total_bits.astype(jnp.int32) * contending
            rounds = rounds + (contending > 0).astype(jnp.int32)
            return (survivors, slots, rounds, new_done), jnp.sum(
                collided, dtype=jnp.int32)

        alive0 = jnp.broadcast_to(mask[:, None], (n_max, k_elems))
        done0 = jnp.zeros((k_elems,), dtype=bool)
        (alive, slots, rounds, done), coll_rounds = jax.lax.scan(
            round_body, (alive0, jnp.int32(0), jnp.int32(0), done0),
            jnp.arange(max_rounds))
        winner = jnp.argmax(alive, axis=0).astype(jnp.int32)  # lowest-idx cap
        collisions = jnp.sum(coll_rounds, dtype=jnp.int32)

    true_code = jnp.max(jnp.where(mask[:, None], codes, 0), axis=0)
    correct = jnp.take_along_axis(codes, winner[None, :], axis=0)[0] \
        == true_code
    return NoisyOCSResult(
        winner=winner,
        correct=correct,
        collisions=collisions,
        rounds=rounds,
        contention_slots=slots,
    )


def ocs_maxpool_noisy(h: jax.Array, rng: jax.Array, bits: int = 16,
                      p_miss: float = 0.0, max_rounds: int = 3,
                      backend: str = "scan") -> NoisyOCSResult:
    """Algorithm 1 with miss-detection: a sensing worker overhears a blocking
    signal with probability ``1 - p_miss`` per sub-slot.  Missed detections
    create false survivors; when several survivors transmit payloads the
    fusion center detects the collision (no clean ACK) and the survivors
    re-contend (up to ``max_rounds``, then lowest-index capture).

    With ``p_miss=0`` this reduces exactly to :func:`ocs_maxpool`
    (property-tested).  ``p_miss`` is a scalar or a per-worker ``(N,)``
    array (near/far users).  The fusion result degrades gracefully: an
    incorrect winner still transmits *its own true value*, so the pooled
    feature is a lower bound of the true max — the learner sees a noisy
    max-pool, never a corrupted value.
    """
    if h.ndim != 2:
        raise ValueError(f"h must be (N, K), got {h.shape}")
    n_workers = h.shape[0]
    id_bits = host_id_bits(n_workers)
    return ocs_maxpool_noisy_core(
        h, jnp.ones((n_workers,), dtype=bool), id_bits, rng, p_miss,
        bits=bits, max_id_bits=id_bits, max_rounds=max_rounds,
        backend=backend)
