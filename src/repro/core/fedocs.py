"""FedOCS feature aggregation — the paper's core technique as a JAX module.

All aggregators operate on a *worker-leading* tensor ``h: (N, ...)`` — the
paper's h = [h_1 … h_N].  Inside the distributed model the worker axis is
sharded over the ``model`` mesh axis, so a reduction over axis 0 lowers to a
single ``all-reduce`` collective on the ICI fabric:

  * ``sum``      -> all-reduce(add)      (Megatron-style TP; reference)
  * ``max``      -> all-reduce(max)      (FedOCS, paper Eq. 4)
  * ``max_q16``  -> all-reduce(max) on uint16 monotone codes (paper Eq. 7 as
                    a lossy-but-order-exact collective compression; DESIGN §2.1)
  * ``max_q8``   -> all-reduce(max) on uint8 codes (4x byte reduction vs f32)
  * ``mean``     -> all-reduce(add) / N  (paper baseline "Avg. Workers Embed")
  * ``concat``   -> all-gather           (paper baseline "Concat Workers Embed",
                    O(N·K) bytes — the comm-heavy upper bound)

Backward (paper Eq. 5-6): the cotangent of the pooled feature is routed only
to the winning worker(s).  Both pooled variants use a ``custom_vjp`` whose
backward is **collective-free**: the pooled value is already replicated across
the worker axis after the forward all-reduce, so each shard computes its own
winner mask locally and multiplies — this is the TPU realization of "the
fusion center broadcasts dL/dv once" (§II-B).

Tie handling: with ``tie_break='all'`` (default) every worker tied at the max
receives the full cotangent — a valid subgradient, zero extra communication,
and identical to Eq. 6 whenever the argmax is unique (ties are measure-zero
for continuous features).  ``tie_break='first'`` reproduces the OCS protocol
exactly (lowest worker index wins, one extra tiny all-reduce(min) of int32
indices); equality with the protocol simulator is property-tested.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quantize as qz

VALID_MODES = ("sum", "max", "max_q16", "max_q8", "mean", "concat")


def _winner_mask(h: jax.Array, pooled: jax.Array, tie_break: str) -> jax.Array:
    """Mask of workers receiving gradient. pooled is broadcast over axis 0."""
    mask = (h == pooled[None]).astype(h.dtype)
    if tie_break == "all":
        return mask
    if tie_break == "first":
        n = h.shape[0]
        idx = jnp.arange(n, dtype=jnp.int32).reshape((n,) + (1,) * (h.ndim - 1))
        cand = jnp.where(mask > 0, idx, jnp.int32(n))
        first = jnp.min(cand, axis=0)            # all-reduce(min) when sharded
        return (idx == first[None]).astype(h.dtype) * mask
    raise ValueError(f"unknown tie_break {tie_break!r}")


# ---------------------------------------------------------------------------
# max-pool (paper Eq. 4) with winner-routed backward (Eq. 6)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def maxpool(h: jax.Array, tie_break: str = "all") -> jax.Array:
    return jnp.max(h, axis=0)


def _maxpool_fwd(h, tie_break):
    pooled = jnp.max(h, axis=0)
    return pooled, (h, pooled)


def _maxpool_bwd(tie_break, res, g):
    h, pooled = res
    return (g[None] * _winner_mask(h, pooled, tie_break),)


maxpool.defvjp(_maxpool_fwd, _maxpool_bwd)


# ---------------------------------------------------------------------------
# quantized max-pool: all-reduce(max) over D-bit monotone codes (DESIGN §2.1)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def maxpool_quantized(h: jax.Array, bits: int, tie_break: str = "all") -> jax.Array:
    codes = qz.quantize(h, bits)
    pooled_code = jnp.max(codes, axis=0)         # AR(max) on uint8/uint16 codes
    return qz.dequantize(pooled_code, bits, h.dtype)


def _maxpool_q_fwd(h, bits, tie_break):
    codes = qz.quantize(h, bits)
    pooled_code = jnp.max(codes, axis=0)
    pooled = qz.dequantize(pooled_code, bits, h.dtype)
    return pooled, (codes, pooled_code)


def _maxpool_q_bwd(bits, tie_break, res, g):
    codes, pooled_code = res
    # Straight-through: gradient flows to the worker(s) whose code won the
    # contention (exactly the OCS winner set); quantizer Jacobian ~ identity.
    mask = (codes == pooled_code[None]).astype(g.dtype)
    if tie_break == "first":
        n = codes.shape[0]
        idx = jnp.arange(n, dtype=jnp.int32).reshape((n,) + (1,) * (codes.ndim - 1))
        cand = jnp.where(mask > 0, idx, jnp.int32(n))
        first = jnp.min(cand, axis=0)
        mask = mask * (idx == first[None]).astype(g.dtype)
    return (g[None] * mask,)


maxpool_quantized.defvjp(_maxpool_q_fwd, _maxpool_q_bwd)


# ---------------------------------------------------------------------------
# baselines + dispatcher
# ---------------------------------------------------------------------------

def meanpool(h: jax.Array) -> jax.Array:
    return jnp.mean(h, axis=0)


def concat(h: jax.Array) -> jax.Array:
    """(N, ..., K) -> (..., N*K): all-gather + feature concat (paper baseline)."""
    moved = jnp.moveaxis(h, 0, -2)                 # (..., N, K)
    return moved.reshape(h.shape[1:-1] + (h.shape[0] * h.shape[-1],))


def aggregate(h: jax.Array, mode: str, *, tie_break: str = "all") -> jax.Array:
    """Pool a worker-leading feature tensor. h: (N, ..., K)."""
    if mode == "sum":
        return jnp.sum(h, axis=0)
    if mode == "max":
        return maxpool(h, tie_break)
    if mode == "max_q16":
        return maxpool_quantized(h, 16, tie_break)
    if mode == "max_q8":
        return maxpool_quantized(h, 8, tie_break)
    if mode == "mean":
        return meanpool(h)
    if mode == "concat":
        return concat(h)
    raise ValueError(f"unknown aggregation mode {mode!r}; valid: {VALID_MODES}")


def output_dim(mode: str, n_workers: int, k: int) -> int:
    """Feature width the fusion head sees for a given aggregation mode."""
    return n_workers * k if mode == "concat" else k
