"""FedOCS feature aggregation — the paper's core technique as a JAX module.

All aggregators operate on a *worker-leading* tensor ``h: (N, ...)`` — the
paper's h = [h_1 … h_N].  Inside the distributed model the worker axis is
sharded over the ``model`` mesh axis, so a reduction over axis 0 lowers to a
single ``all-reduce`` collective on the ICI fabric:

  * ``sum``      -> all-reduce(add)      (Megatron-style TP; reference)
  * ``max``      -> all-reduce(max)      (FedOCS, paper Eq. 4)
  * ``max_q16``  -> all-reduce(max) on uint16 monotone codes (paper Eq. 7 as
                    a lossy-but-order-exact collective compression; DESIGN §2.1)
  * ``max_q8``   -> all-reduce(max) on uint8 codes (4x byte reduction vs f32)
  * ``mean``     -> all-reduce(add) / N  (paper baseline "Avg. Workers Embed")
  * ``concat``   -> all-gather           (paper baseline "Concat Workers Embed",
                    O(N·K) bytes — the comm-heavy upper bound)

Backward (paper Eq. 5-6): the cotangent of the pooled feature is routed only
to the winning worker(s).  Both pooled variants use a ``custom_vjp`` whose
backward is **collective-free**: the pooled value is already replicated across
the worker axis after the forward all-reduce, so each shard computes its own
winner mask locally and multiplies — this is the TPU realization of "the
fusion center broadcasts dL/dv once" (§II-B).

Tie handling: with ``tie_break='all'`` (default) every worker tied at the max
receives the full cotangent — a valid subgradient, zero extra communication,
and identical to Eq. 6 whenever the argmax is unique (ties are measure-zero
for continuous features).  ``tie_break='first'`` reproduces the OCS protocol
exactly (lowest worker index wins, one extra tiny all-reduce(min) of int32
indices); equality with the protocol simulator is property-tested.

Channel-in-the-loop training (``max_noisy``): :func:`maxpool_noisy` replaces
the ideal pooled max with the *protocol outcome under imperfect carrier
sensing* — the winner per element is selected by
``repro.core.ocs.ocs_maxpool_noisy_core`` (quantized D-bit codes,
per-sub-slot miss detection, lowest-index capture after ``max_rounds``), the
pooled value is the winner's D-bit payload, and the backward routes the
cotangent to that winner only.  ``rng`` and ``p_miss`` are ordinary traced
arguments, so one compiled train step serves a whole miss-probability axis;
at ``p_miss=0`` the forward AND the vjp coincide bit-for-bit with
``maxpool_quantized(tie_break='first')`` (property-tested).

These pooling laws (``maxpool``, ``maxpool_quantized``, ``maxpool_noisy``,
``meanpool``, ``concat``) are the *primitives*; the protocol itself is a
first-class value — ``repro.protocol.Protocol`` — carrying every
protocol-side knob as one pytree object with a single
``protocol.aggregate(h, rng) -> (pooled, accounting)`` entry point that
dispatches to them.  (The legacy string-mode ``aggregate``/``output_dim``
dispatchers and the ``ChannelNoise`` carrier lived here through their
one-release deprecation window and are now removed; ``VALID_MODES`` stays
as the legacy mode-name vocabulary ``Protocol.from_mode`` accepts.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ocs
from repro.core import quantize as qz

VALID_MODES = ("sum", "max", "max_q16", "max_q8", "max_noisy", "mean",
               "concat")


def _winner_mask(h: jax.Array, pooled: jax.Array, tie_break: str) -> jax.Array:
    """Mask of workers receiving gradient. pooled is broadcast over axis 0."""
    mask = (h == pooled[None]).astype(h.dtype)
    if tie_break == "all":
        return mask
    if tie_break == "first":
        n = h.shape[0]
        idx = jnp.arange(n, dtype=jnp.int32).reshape((n,) + (1,) * (h.ndim - 1))
        cand = jnp.where(mask > 0, idx, jnp.int32(n))
        first = jnp.min(cand, axis=0)            # all-reduce(min) when sharded
        return (idx == first[None]).astype(h.dtype) * mask
    raise ValueError(f"unknown tie_break {tie_break!r}")


# ---------------------------------------------------------------------------
# max-pool (paper Eq. 4) with winner-routed backward (Eq. 6)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def maxpool(h: jax.Array, tie_break: str = "all") -> jax.Array:
    return jnp.max(h, axis=0)


def _maxpool_fwd(h, tie_break):
    pooled = jnp.max(h, axis=0)
    return pooled, (h, pooled)


def _maxpool_bwd(tie_break, res, g):
    h, pooled = res
    return (g[None] * _winner_mask(h, pooled, tie_break),)


maxpool.defvjp(_maxpool_fwd, _maxpool_bwd)


# ---------------------------------------------------------------------------
# quantized max-pool: all-reduce(max) over D-bit monotone codes (DESIGN §2.1)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def maxpool_quantized(h: jax.Array, bits: int, tie_break: str = "all") -> jax.Array:
    codes = qz.quantize(h, bits)
    pooled_code = jnp.max(codes, axis=0)         # AR(max) on uint8/uint16 codes
    return qz.dequantize(pooled_code, bits, h.dtype)


def _maxpool_q_fwd(h, bits, tie_break):
    codes = qz.quantize(h, bits)
    pooled_code = jnp.max(codes, axis=0)
    pooled = qz.dequantize(pooled_code, bits, h.dtype)
    return pooled, (codes, pooled_code)


def _maxpool_q_bwd(bits, tie_break, res, g):
    codes, pooled_code = res
    # Straight-through: gradient flows to the worker(s) whose code won the
    # contention (exactly the OCS winner set); quantizer Jacobian ~ identity.
    mask = (codes == pooled_code[None]).astype(g.dtype)
    if tie_break == "first":
        n = codes.shape[0]
        idx = jnp.arange(n, dtype=jnp.int32).reshape((n,) + (1,) * (codes.ndim - 1))
        cand = jnp.where(mask > 0, idx, jnp.int32(n))
        first = jnp.min(cand, axis=0)
        mask = mask * (idx == first[None]).astype(g.dtype)
    return (g[None] * mask,)


maxpool_quantized.defvjp(_maxpool_q_fwd, _maxpool_q_bwd)


# ---------------------------------------------------------------------------
# channel-in-the-loop max-pool: noisy-OCS winner selection in the forward
# ---------------------------------------------------------------------------

def _maxpool_noisy_impl(h, rng, p_miss, bits, max_rounds, backend,
                        online=None):
    """Protocol-outcome pooling: (pooled, winner one-hot mask, accounting).

    The third element is the contention core's full ``NoisyOCSResult`` —
    ``repro.protocol`` surfaces its collision/round counters as the
    ``ProtocolAccounting`` of ``Protocol.aggregate``.

    ``online`` (optional ``(N,)`` bool) removes dark workers from the
    contention mask entirely — they neither transmit nor capture by index
    (``repro.faults`` worker dropout).  ``None`` means everyone contends;
    an all-``True`` array is bit-for-bit identical to ``None``.  With no
    online worker the core's lowest-index capture degenerates to worker 0:
    callers that allow total outage must gate on ``online.any()``
    (``repro.faults`` does).
    """
    n = h.shape[0]
    flat = h.reshape(n, -1)                                    # (N, M)
    id_bits = ocs.host_id_bits(n)
    mask = (jnp.ones((n,), dtype=bool) if online is None
            else jnp.asarray(online, bool))
    res = ocs.ocs_maxpool_noisy_core(
        flat, mask, id_bits, rng, p_miss,
        bits=bits, max_id_bits=id_bits, max_rounds=max_rounds,
        backend=backend)
    codes = qz.quantize(flat, bits)
    win_code = jnp.take_along_axis(codes, res.winner[None, :], axis=0)[0]
    pooled = qz.dequantize(win_code, bits, h.dtype).reshape(h.shape[1:])
    onehot = jnp.arange(n, dtype=jnp.int32)[:, None] == res.winner[None, :]
    return pooled, onehot.reshape(h.shape).astype(h.dtype), res


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def maxpool_noisy(h: jax.Array, rng: jax.Array, p_miss: jax.Array,
                  bits: int = 16, max_rounds: int = 3,
                  backend: str = "scan") -> jax.Array:
    """Max-pool through the *simulated* OCS channel (paper Alg. 1 + misses).

    The per-element winner is the noisy-protocol outcome — quantized D-bit
    contention with per-sub-slot miss detection and lowest-index capture
    after ``max_rounds`` — and it transmits its D-bit payload, so the fused
    feature the head sees is exactly what the wireless fusion center would
    decode.  Backward routes the cotangent to the selected winner only
    (Eq. 6 for the *actual* transmitter, not the ideal argmax).

    ``p_miss`` is a traced scalar or per-worker ``(N,)`` array.  ``backend``
    picks the contention engine for the forward pass: ``"scan"`` (the
    reference ``lax.scan``) or ``"pallas"`` (the fused
    ``repro.kernels.ocs_contention`` kernel) — bit-for-bit interchangeable,
    forward and vjp (the Eq.-6 winner-routed backward is shared).

    At ``p_miss=0`` this is bit-for-bit ``maxpool_quantized(h, bits,
    'first')`` in both the forward and the vjp.
    """
    pooled, _, _ = _maxpool_noisy_impl(h, rng, p_miss, bits, max_rounds,
                                       backend)
    return pooled


def _maxpool_noisy_fwd(h, rng, p_miss, bits, max_rounds, backend):
    pooled, mask, _ = _maxpool_noisy_impl(h, rng, p_miss, bits, max_rounds,
                                          backend)
    return pooled, (mask, rng, p_miss)


def _maxpool_noisy_bwd(bits, max_rounds, backend, res, g):
    mask, rng, p_miss = res
    # rng is integer-typed (a PRNG key): its cotangent space is float0.
    d_rng = np.zeros(np.shape(rng), jax.dtypes.float0)
    return (g[None] * mask, d_rng, jnp.zeros_like(p_miss))


maxpool_noisy.defvjp(_maxpool_noisy_fwd, _maxpool_noisy_bwd)


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

def meanpool(h: jax.Array) -> jax.Array:
    return jnp.mean(h, axis=0)


def concat(h: jax.Array) -> jax.Array:
    """(N, ..., K) -> (..., N*K): all-gather + feature concat (paper baseline)."""
    moved = jnp.moveaxis(h, 0, -2)                 # (..., N, K)
    return moved.reshape(h.shape[1:-1] + (h.shape[0] * h.shape[-1],))
