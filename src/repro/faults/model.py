"""Fault injection for the wireless channel: bursty sensing, worker dropout,
and graceful degradation — as one traced pytree value.

The repo's baseline channel is an i.i.d. Bernoulli miss draw (``p_miss``);
real wireless links fail in *bursts* (deep fades) and whole workers go dark
(device dropout, stragglers).  :class:`FaultModel` upgrades the sensing
channel to a Gilbert–Elliott two-state Markov chain with per-state miss
probabilities, adds an evolving per-worker offline mask, and names a
:class:`DegradePolicy` for what the aggregator does when an OCS frame
resolves nothing — all with the same pytree discipline as
``repro.protocol.Protocol``: every probability is a traced data leaf, so one
compiled program serves a whole grid of fault parameters (zero recompiles),
and only the policy is static metadata.

Chain mechanics (one :func:`aggregate` call = one contention frame):

* sensing state: ``bad' = bad ? (u >= p_bg) : (u < p_gb)`` per worker —
  mean bad sojourn ``1/p_bg`` frames, mean good sojourn ``1/p_gb`` frames;
  the effective miss probability fed to the contention core is
  ``where(bad', p_miss_bad, p_miss_good)``;
* dropout: ``offline' = offline ? (u >= p_recover) : (u < p_drop)`` —
  offline workers leave the contention mask entirely (they are *deaf and
  mute*, never miss-sensing false winners);
* degradation: when no worker is online the frame resolves nothing — the
  policy fills the pooled value with zeros (``zero_fill``), the last
  resolved frame from a carried cache (``stale``), or first spends a
  bounded retransmission budget with exponential backoff (``retry``),
  billing every extra attempt through the accounting.

The chain uniforms are drawn from ``fold_in(rng, tag)`` side streams with
tags disjoint from the contention core's round indices, so the *sensing*
random stream is untouched: a :meth:`FaultModel.iid` model (identical
good/bad states, no dropout) reproduces the plain ``Protocol.aggregate``
path bit for bit — forward, vjp and accounting (property-tested).

Gradients (paper Eq. 5-6 extended): on a resolved frame the cotangent
routes to the actual winner exactly as before; on a dropped frame nothing
reaches ``h`` and the cotangent of the pooled value routes to the stale
cache instead (``stale`` policy) or vanishes (``zero_fill``/``retry``),
so degraded steps never invent gradient signal.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fedocs, ocs

POLICIES = ("zero_fill", "stale", "retry")

# fold_in tags for the fault side-streams.  The contention core consumes
# fold_in(rng, r) for round indices r < max_rounds and fold_in(key, d) for
# bit-slot indices below that; these large tags can never collide with
# either, which is what keeps the sensing stream bit-for-bit unchanged.
_CHAIN_TAG = 0x000C5A17   # Gilbert–Elliott sensing-state chain
_DROP_TAG = 0x000D2079    # worker-dropout chain
_RETRY_TAG = 0x000AE771   # retry-recovery re-draws


@dataclasses.dataclass(frozen=True)
class DegradePolicy:
    """What the aggregator does when a frame resolves nothing (static).

    ``zero_fill`` emits zeros for the dropped frame; ``stale`` replays the
    last resolved pooled value from the carried cache; ``retry`` spends up
    to ``retry_budget`` retransmission attempts (each re-drawing worker
    recovery and billing a full contention frame plus an exponential
    backoff wait) before degrading to zeros.
    """

    kind: str = "zero_fill"
    retry_budget: int = 0

    def __post_init__(self):
        if self.kind not in POLICIES:
            raise ValueError(
                f"unknown degrade policy {self.kind!r}; valid: {POLICIES}")
        if self.kind == "retry" and self.retry_budget < 1:
            raise ValueError("retry policy needs retry_budget >= 1")
        if self.kind != "retry" and self.retry_budget != 0:
            raise ValueError(
                f"retry_budget is only meaningful for kind='retry', "
                f"got {self.retry_budget} with {self.kind!r}")

    @classmethod
    def zero_fill(cls) -> "DegradePolicy":
        return cls(kind="zero_fill")

    @classmethod
    def stale(cls) -> "DegradePolicy":
        return cls(kind="stale")

    @classmethod
    def retry(cls, budget: int = 2) -> "DegradePolicy":
        return cls(kind="retry", retry_budget=budget)


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """The channel fault process as a frozen pytree (traced leaves).

    Every probability is a traced ``float32`` leaf — scalar or per-worker
    ``(N,)`` — so fault parameters rebind without recompiles and a ``vmap``
    lane axis serves a whole fault grid; only ``policy`` is static.
    Construct with :meth:`iid`, :meth:`gilbert_elliott`, or :meth:`burst`
    (+ :meth:`with_dropout` / :meth:`with_policy`).
    """

    p_gb: jax.Array          # P(good -> bad) per frame
    p_bg: jax.Array          # P(bad -> good) per frame
    p_miss_good: jax.Array   # sensing miss prob in the good state
    p_miss_bad: jax.Array    # sensing miss prob in the bad state
    p_drop: jax.Array        # P(online -> offline) per frame
    p_recover: jax.Array     # P(offline -> online) per frame
    policy: DegradePolicy = DegradePolicy()

    @classmethod
    def iid(cls, p_miss, *, policy: Optional[DegradePolicy] = None
            ) -> "FaultModel":
        """Degenerate model: identical states, no dropout — bit-for-bit the
        existing i.i.d. ``p_miss`` path (the reduction witness)."""
        p = jnp.asarray(p_miss, jnp.float32)
        z = jnp.float32(0.0)
        return cls(p_gb=z, p_bg=z, p_miss_good=p, p_miss_bad=p,
                   p_drop=z, p_recover=jnp.float32(1.0),
                   policy=policy or DegradePolicy.zero_fill())

    @classmethod
    def gilbert_elliott(cls, *, p_gb, p_bg, p_miss_good=0.0, p_miss_bad=0.5,
                        policy: Optional[DegradePolicy] = None
                        ) -> "FaultModel":
        return cls(p_gb=jnp.asarray(p_gb, jnp.float32),
                   p_bg=jnp.asarray(p_bg, jnp.float32),
                   p_miss_good=jnp.asarray(p_miss_good, jnp.float32),
                   p_miss_bad=jnp.asarray(p_miss_bad, jnp.float32),
                   p_drop=jnp.float32(0.0), p_recover=jnp.float32(1.0),
                   policy=policy or DegradePolicy.zero_fill())

    @classmethod
    def burst(cls, *, burst_len: float, gap_len: float, p_miss_bad=0.5,
              p_miss_good=0.0, policy: Optional[DegradePolicy] = None
              ) -> "FaultModel":
        """Gilbert–Elliott parameterized by mean sojourn times: bad spans
        average ``burst_len`` frames, good spans ``gap_len`` frames."""
        if burst_len < 1.0 or gap_len < 1.0:
            raise ValueError(
                f"burst_len/gap_len are mean sojourns in frames, >= 1 "
                f"(got {burst_len}, {gap_len})")
        return cls.gilbert_elliott(
            p_gb=1.0 / gap_len, p_bg=1.0 / burst_len,
            p_miss_good=p_miss_good, p_miss_bad=p_miss_bad, policy=policy)

    def with_dropout(self, p_drop, p_recover=0.25) -> "FaultModel":
        return dataclasses.replace(
            self, p_drop=jnp.asarray(p_drop, jnp.float32),
            p_recover=jnp.asarray(p_recover, jnp.float32))

    def with_policy(self, policy: DegradePolicy) -> "FaultModel":
        return dataclasses.replace(self, policy=policy)


jax.tree_util.register_dataclass(
    FaultModel,
    data_fields=["p_gb", "p_bg", "p_miss_good", "p_miss_bad",
                 "p_drop", "p_recover"],
    meta_fields=["policy"])


@dataclasses.dataclass(frozen=True)
class FaultState:
    """The carried fault state (one per independent channel/lane).

    ``stale`` caches the last *resolved* pooled value (the ``stale``
    policy's replay source; carried regardless of policy so policies can
    rebind without re-shaping the carry), ``age`` counts frames since the
    last resolved frame, ``consec`` counts consecutive dropped frames.
    """

    bad: jax.Array       # (N,) bool — sensing chain state
    offline: jax.Array   # (N,) bool — dropout chain state
    stale: jax.Array     # pooled-shape cache of the last resolved frame
    age: jax.Array       # () int32 — frames since last resolution
    consec: jax.Array    # () int32 — consecutive dropped frames


jax.tree_util.register_dataclass(
    FaultState,
    data_fields=["bad", "offline", "stale", "age", "consec"],
    meta_fields=[])


def init_state(n_workers: int, pooled_shape: Tuple[int, ...] = (),
               dtype=jnp.float32) -> FaultState:
    """All-good initial state: every worker online, chain in the good
    state, empty stale cache of the pooled shape ``h.shape[1:]``."""
    return FaultState(
        bad=jnp.zeros((n_workers,), bool),
        offline=jnp.zeros((n_workers,), bool),
        stale=jnp.zeros(pooled_shape, dtype),
        age=jnp.int32(0), consec=jnp.int32(0))


@dataclasses.dataclass(frozen=True)
class FaultAccounting:
    """Honest channel accounting of one fault-aware aggregation.

    The first four fields keep the exact :class:`ProtocolAccounting` names
    (``rounds``/``collisions``/``contention_slots``/``correct_frac``) so
    every telemetry consumer of ``Protocol.aggregate`` reads this object
    unchanged; ``contention_slots`` additionally includes the retry bill.
    """

    rounds: jax.Array            # () int32
    collisions: jax.Array        # () int32
    contention_slots: jax.Array  # () int32 — core slots + retry_slots
    correct_frac: jax.Array      # () float32 — 0.0 on a dropped frame
    dropped_frames: jax.Array    # () int32 — sub-frames that resolved nothing
    stale_age: jax.Array         # () int32 — frames since last resolution
    offline_workers: jax.Array   # () int32
    retry_slots: jax.Array       # () int32 — extra airtime spent retrying
    outage: jax.Array            # () int32 — 1 if this frame was dropped


jax.tree_util.register_dataclass(
    FaultAccounting,
    data_fields=["rounds", "collisions", "contention_slots", "correct_frac",
                 "dropped_frames", "stale_age", "offline_workers",
                 "retry_slots", "outage"],
    meta_fields=[])


# ---------------------------------------------------------------------------
# chain evolution (side-stream rng; sensing stream untouched)
# ---------------------------------------------------------------------------

def step_chains(model: FaultModel, state: FaultState, rng: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """One Markov step of both chains: ``(new_bad, new_offline)``."""
    n = state.bad.shape[0]
    u_s = jax.random.uniform(jax.random.fold_in(rng, _CHAIN_TAG), (n,),
                             jnp.float32)
    p_gb = jnp.asarray(model.p_gb, jnp.float32)
    p_bg = jnp.asarray(model.p_bg, jnp.float32)
    new_bad = jnp.where(state.bad, u_s >= p_bg, u_s < p_gb)
    u_d = jax.random.uniform(jax.random.fold_in(rng, _DROP_TAG), (n,),
                             jnp.float32)
    p_drop = jnp.asarray(model.p_drop, jnp.float32)
    p_rec = jnp.asarray(model.p_recover, jnp.float32)
    new_offline = jnp.where(state.offline, u_d >= p_rec, u_d < p_drop)
    return new_bad, new_offline


def effective_p_miss(model: FaultModel, bad: jax.Array) -> jax.Array:
    """Per-worker sensing miss probability under the current chain state."""
    return jnp.where(bad, jnp.asarray(model.p_miss_bad, jnp.float32),
                     jnp.asarray(model.p_miss_good, jnp.float32))


def _retry_recover(model: FaultModel, offline: jax.Array, rng: jax.Array,
                   frame_slots: int) -> Tuple[jax.Array, jax.Array]:
    """Bounded retransmission: while the cell is in total outage, re-draw
    worker recovery up to ``retry_budget`` times, billing each attempt a
    full contention frame plus an exponential-backoff wait."""
    kr = jax.random.fold_in(rng, _RETRY_TAG)
    p_rec = jnp.asarray(model.p_recover, jnp.float32)
    n = offline.shape[0]
    retry_slots = jnp.int32(0)
    for a in range(model.policy.retry_budget):    # static unroll: budget is
        outage = ~jnp.any(~offline)               # policy metadata
        u = jax.random.uniform(jax.random.fold_in(kr, a), (n,), jnp.float32)
        cost = jnp.int32(frame_slots + 2 ** a)
        retry_slots = retry_slots + jnp.where(outage, cost, jnp.int32(0))
        offline = jnp.where(outage, offline & (u >= p_rec), offline)
    return offline, retry_slots


# ---------------------------------------------------------------------------
# the fault-aware pooling law (custom_vjp: degraded frames never invent
# gradient signal)
# ---------------------------------------------------------------------------

def _fault_pool_impl(h, rng, p_eff, online, stale, bits, max_rounds,
                     backend, stale_fill):
    pooled_raw, onehot, res = fedocs._maxpool_noisy_impl(
        h, rng, p_eff, bits, max_rounds, backend, online=online)
    ok = jnp.any(online)
    okf = ok.astype(h.dtype)
    fill = stale if stale_fill else jnp.zeros_like(stale)
    pooled = jnp.where(ok, pooled_raw, fill)
    new_stale = jnp.where(ok, pooled_raw, stale)
    mask = okf * onehot                           # winner routing, outage-gated
    return (pooled, new_stale, res, ok), (mask, okf)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _fault_pool(h, rng, p_eff, online, stale, bits, max_rounds, backend,
                stale_fill):
    """``fedocs._maxpool_noisy_impl`` + outage gating + stale-cache carry.

    Returns ``(pooled, new_stale, NoisyOCSResult, ok)``.  On a resolved
    frame (``ok``) this is bit-for-bit the plain noisy pool; on outage the
    pooled value is the policy fill and the cache/telemetry carry forward.
    """
    out, _ = _fault_pool_impl(h, rng, p_eff, online, stale, bits,
                              max_rounds, backend, stale_fill)
    return out


def _fault_pool_fwd(h, rng, p_eff, online, stale, bits, max_rounds, backend,
                    stale_fill):
    out, (mask, okf) = _fault_pool_impl(h, rng, p_eff, online, stale, bits,
                                        max_rounds, backend, stale_fill)
    return out, (mask, okf, p_eff, rng, online)


def _fault_pool_bwd(bits, max_rounds, backend, stale_fill, residuals, g):
    mask, okf, p_eff, rng, online = residuals
    g_pooled, g_new_stale, _g_res, _g_ok = g     # telemetry: non-diff
    # pooled and new_stale both equal pooled_raw on a resolved frame, so the
    # winner receives the sum of their cotangents; mask is already okf-gated
    # (nothing reaches h on a dropped frame).
    d_h = (g_pooled + g_new_stale)[None] * mask
    # on a dropped frame the cache passes through to new_stale, and under
    # the stale policy it IS the pooled output as well.
    d_stale = (1.0 - okf) * (g_new_stale
                             + (g_pooled if stale_fill
                                else jnp.zeros_like(g_pooled)))
    d_rng = np.zeros(np.shape(rng), jax.dtypes.float0)
    d_online = np.zeros(np.shape(online), jax.dtypes.float0)
    return (d_h, d_rng, jnp.zeros_like(p_eff), d_online, d_stale)


_fault_pool.defvjp(_fault_pool_fwd, _fault_pool_bwd)


# ---------------------------------------------------------------------------
# the one entry point
# ---------------------------------------------------------------------------

def aggregate(protocol, model: FaultModel, state: FaultState, h: jax.Array,
              rng: jax.Array
              ) -> Tuple[jax.Array, FaultState, FaultAccounting]:
    """Fault-aware OCS aggregation: one contention frame under the fault
    process.

    Evolves both Markov chains, runs the (possibly retried) contention with
    the effective per-worker miss probabilities and the offline workers
    removed from the mask, applies the degrade policy on outage, and bills
    everything through :class:`FaultAccounting`.  ``protocol`` supplies the
    static contention parameters (``bits``/``max_rounds``/``backend``); its
    own ``p_miss`` leaf is superseded by the model's per-state
    probabilities.  Returns ``(pooled, new_state, accounting)``.
    """
    if protocol.kind != "ocs":
        raise ValueError(
            f"fault injection needs an OCS protocol, got {protocol.kind!r}")
    n = h.shape[0]
    new_bad, new_offline = step_chains(model, state, rng)
    retry_slots = jnp.int32(0)
    if model.policy.kind == "retry":
        frame_slots = ((protocol.bits + ocs.host_id_bits(n))
                       * int(np.prod(h.shape[1:])))
        new_offline, retry_slots = _retry_recover(model, new_offline, rng,
                                                  frame_slots)
    online = ~new_offline
    p_eff = effective_p_miss(model, new_bad)
    pooled, new_stale, res, ok = _fault_pool(
        h, rng, p_eff, online, state.stale, protocol.bits,
        protocol.max_rounds, protocol.backend,
        model.policy.kind == "stale")
    age = jnp.where(ok, jnp.int32(0), state.age + jnp.int32(1))
    consec = jnp.where(ok, jnp.int32(0), state.consec + jnp.int32(1))
    new_state = FaultState(bad=new_bad, offline=new_offline, stale=new_stale,
                           age=age, consec=consec)
    m_frames = int(np.prod(h.shape[1:]))
    acct = FaultAccounting(
        rounds=res.rounds, collisions=res.collisions,
        contention_slots=res.contention_slots + retry_slots,
        correct_frac=jnp.where(ok, jnp.mean(res.correct.astype(jnp.float32)),
                               jnp.float32(0.0)),
        dropped_frames=jnp.where(ok, jnp.int32(0), jnp.int32(m_frames)),
        stale_age=age,
        offline_workers=jnp.sum(new_offline.astype(jnp.int32)),
        retry_slots=retry_slots,
        outage=(~ok).astype(jnp.int32))
    return pooled, new_state, acct
