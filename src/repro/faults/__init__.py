"""Channel fault injection: bursty sensing, worker dropout, degradation."""

from repro.faults.model import (
    POLICIES,
    DegradePolicy,
    FaultAccounting,
    FaultModel,
    FaultState,
    aggregate,
    effective_p_miss,
    init_state,
    step_chains,
)

__all__ = [
    "POLICIES",
    "DegradePolicy",
    "FaultAccounting",
    "FaultModel",
    "FaultState",
    "aggregate",
    "effective_p_miss",
    "init_state",
    "step_chains",
]
