"""Sharded, mesh-agnostic checkpointing (no orbax in the container).

Layout per checkpoint:
    <dir>/step_<N>/
        index.json            tree structure, shapes, dtypes, logical axes
        shard_<host>.npz      raw buffers owned by this host
        COMMIT                written last (atomic-rename) -> completeness marker
    <dir>/latest              text file with the newest committed step

Tensors are stored with their *logical axes*, not a mesh layout, so a restore
may target any mesh/sharding (elastic scaling: tested 8 -> 4 -> 2 devices).
Writes go to a temp dir then ``os.replace`` (atomic on POSIX); a crash
mid-write can never corrupt the ``latest`` pointer.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
import jax.numpy as jnp

SEP = "/"


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


def save(ckpt_dir: str, step: int, values, axes_tree=None,
         extra: Optional[Dict[str, Any]] = None, host: int = 0) -> str:
    """Write one checkpoint. `values` is any pytree of arrays."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        flat = _flatten_with_paths(values)
        arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
        np.savez(os.path.join(tmp, f"shard_{host}.npz"), **arrays)
        axes_flat = {}
        if axes_tree is not None:
            axes_flat = {k: list(v) for k, v in
                         _flatten_with_paths(axes_tree).items()}
        index = {
            "step": step,
            "keys": sorted(arrays),
            "shapes": {k: list(a.shape) for k, a in arrays.items()},
            "dtypes": {k: str(a.dtype) for k, a in arrays.items()},
            "axes": axes_flat,
            "extra": extra or {},
            "n_hosts": 1,
        }
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    with open(os.path.join(ckpt_dir, ".latest_tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, ".latest_tmp"),
               os.path.join(ckpt_dir, "latest"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest *committed* step (ignores torn/uncommitted directories)."""
    marker = os.path.join(ckpt_dir, "latest")
    candidates = []
    if os.path.exists(marker):
        with open(marker) as f:
            try:
                candidates.append(int(f.read().strip()))
            except ValueError:
                pass
    if os.path.isdir(ckpt_dir):
        for name in os.listdir(ckpt_dir):
            if name.startswith("step_"):
                path = os.path.join(ckpt_dir, name)
                if os.path.exists(os.path.join(path, "COMMIT")):
                    candidates.append(int(name[len("step_"):]))
    return max(candidates) if candidates else None


def restore(ckpt_dir: str, step: Optional[int] = None,
            template=None, shardings=None
            ) -> Tuple[Any, int, Dict[str, Any]]:
    """Load a checkpoint.

    `template`: pytree with the same structure (e.g. from eval_shape) used to
    rebuild the treedef.  `shardings`: optional matching pytree of
    NamedShardings — arrays are placed directly onto the (possibly different)
    target mesh, which is the elastic-rescale path.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "index.json")) as f:
        index = json.load(f)
    data = {}
    for name in os.listdir(path):
        if name.startswith("shard_") and name.endswith(".npz"):
            with np.load(os.path.join(path, name)) as z:
                for k in z.files:
                    data[k] = z[k]
    if template is None:
        raise ValueError("restore requires a structure template")
    flat_template = _flatten_with_paths(template)
    missing = set(flat_template) - set(data)
    if missing:
        raise KeyError(f"checkpoint missing keys: {sorted(missing)[:5]} ...")
    flat_shardings = (_flatten_with_paths(shardings)
                      if shardings is not None else {})

    def materialize(key, like):
        arr = data[key]
        if flat_shardings:
            return jax.device_put(arr, flat_shardings[key])
        return jnp.asarray(arr)

    values = {k: materialize(k, v) for k, v in flat_template.items()}
    # rebuild tree in template order
    leaves = [values[k] for k in flat_template]
    treedef = _treedef_of(template)
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    return restored, step, index.get("extra", {})
