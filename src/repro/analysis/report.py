"""Findings, reports and waiver baselines for the static-analysis pass.

Every check in ``repro.analysis`` — trace-level contracts, HLO checks and
the AST lint — reports violations as :class:`Finding` values.  A finding's
:attr:`~Finding.key` is stable across unrelated edits (it names the rule,
the file/contract and a detail token, but never a line number), so a
committed waiver baseline keeps CI green across line drift while still
failing on any *new* violation.

The baseline file is JSON::

    {"waivers": ["rule::where::detail", ...]}

and lives at the repo root as ``analysis_baseline.json`` (committed empty —
CI starts strict; add a key only with a comment-worthy reason in the PR).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Sequence

# rule identifiers (one per invariant; tests assert fixtures are flagged by
# exactly the intended rule)
RECOMPILE_HAZARD = "recompile-hazard"
F64_PROMOTION = "f64-promotion"
HOST_SYNC = "host-sync"
DONATION_ALIAS = "donation-alias"
UNEXPECTED_COLLECTIVE = "unexpected-collective"
EXCESS_COPIES = "excess-copies"
INTERPRET_HARDCODE = "interpret-hardcode"
HOST_SYNC_IN_JIT = "host-sync-in-jit"
EAGER_LOOP_IN_JIT = "eager-loop-in-jit"
MISSING_KERNEL_REF = "missing-kernel-ref"
NONDETERMINISM = "nondeterminism"
SILENT_EXCEPT = "silent-except"
UNKNOWN_DTYPE = "unknown-dtype"
CHECK_ERROR = "check-error"

ALL_RULES = (
    RECOMPILE_HAZARD, F64_PROMOTION, HOST_SYNC, DONATION_ALIAS,
    UNEXPECTED_COLLECTIVE, EXCESS_COPIES, INTERPRET_HARDCODE,
    HOST_SYNC_IN_JIT, EAGER_LOOP_IN_JIT, MISSING_KERNEL_REF, NONDETERMINISM,
    SILENT_EXCEPT, UNKNOWN_DTYPE, CHECK_ERROR,
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation.

    ``where`` names the contract (``contract:protocol.aggregate``) or the
    file (repo-relative path); ``detail`` is a short stable token (symbol,
    primitive, dtype) distinguishing findings within one ``where``;
    ``line`` is display-only and excluded from the waiver key.
    """

    rule: str
    where: str
    detail: str
    message: str
    line: Optional[int] = None

    @property
    def key(self) -> str:
        return f"{self.rule}::{self.where}::{self.detail}"

    def render(self) -> str:
        loc = f"{self.where}:{self.line}" if self.line else self.where
        return f"[{self.rule}] {loc}: {self.message}"

    def to_dict(self) -> Dict:
        return {"rule": self.rule, "where": self.where,
                "detail": self.detail, "message": self.message,
                "line": self.line, "key": self.key}


@dataclasses.dataclass
class Report:
    """All findings of one analysis run, plus the applied baseline."""

    findings: List[Finding] = dataclasses.field(default_factory=list)
    waivers: Sequence[str] = ()

    def extend(self, findings: Sequence[Finding]) -> None:
        self.findings.extend(findings)

    def unwaived(self) -> List[Finding]:
        waived = set(self.waivers)
        return [f for f in self.findings if f.key not in waived]

    def stale_waivers(self) -> List[str]:
        live = {f.key for f in self.findings}
        return [w for w in self.waivers if w not in live]

    def to_dict(self) -> Dict:
        return {
            "findings": [f.to_dict() for f in self.findings],
            "waived": sorted({f.key for f in self.findings}
                             & set(self.waivers)),
            "stale_waivers": self.stale_waivers(),
            "ok": not self.unwaived(),
        }

    def write_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")


def load_baseline(path: Optional[str]) -> List[str]:
    """Waiver keys from a baseline file (``None``/missing -> strict)."""
    if path is None:
        return []
    with open(path) as f:
        data = json.load(f)
    waivers = data.get("waivers", [])
    if not isinstance(waivers, list) or any(
            not isinstance(w, str) for w in waivers):
        raise ValueError(f"{path}: 'waivers' must be a list of finding keys")
    return waivers
