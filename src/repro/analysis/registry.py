"""The declarative contract registry: every jitted entry point of the repo
declares its performance invariants here, and ``python -m repro.analysis``
(or the tier-1 ``tests/test_analysis.py`` parametrization) enforces them.

A :class:`Contract` names the entry point, the leaves it must stay
recompile-free over, its dispatch bound (documentation for the shared
assertions in :mod:`repro.analysis.contracts` that the benchmarks call),
and which checks apply.  The :attr:`Contract.build` thunk materializes the
actual traceable function + argument factory lazily — builders import the
subsystem locally and construct arguments from ``ShapeDtypeStruct``/
``jax.eval_shape`` stand-ins, so checking a contract never executes a real
training or serving step (tiny host constants like PRNG key data and log
slot maps are the only concrete arrays involved).

Adding a contract for a new entry point::

    def _build_my_engine() -> Entry:
        from repro.my import engine                    # local import
        fn = engine._make_step(...)                    # the jitted callable
        def argsf(p):                                  # p perturbs the leaf
            return (..., Protocol.ocs(bits=8, p_miss=np.full((N,), p,
                                                             np.float32)), ...)
        return Entry(fn=fn, argsf=argsf)

    CONTRACTS += (Contract(name="my.engine", build=_build_my_engine,
                           recompile_free_over="protocol.p_miss",
                           max_dispatches="1 per run"),)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts as C
from repro.analysis.report import Finding


@dataclasses.dataclass
class Entry:
    """A materialized entry point: the traceable callable + its arguments.

    ``argsf(p)`` embeds the perturbation ``p`` into the contract's
    rebindable leaves (``p_miss``); every other argument must be identical
    across calls.  ``lower`` (optional) produces a ``jax.stages.Lowered``
    for the HLO-level checks; ``donated`` is the donated-buffer count the
    donation check expects in the lowering.
    """

    fn: Callable
    argsf: Callable[[float], Tuple]
    lower: Optional[Callable] = None
    donated: int = 0


@dataclasses.dataclass(frozen=True)
class Contract:
    """One entry point's declared invariants (see module docstring)."""

    name: str
    build: Callable[[], Entry]
    recompile_free_over: str = "protocol.p_miss"   # "" disables the check
    max_dispatches: str = ""                       # documented host bound
    forbid_f64: bool = True
    forbid_host_sync: bool = True
    host_sync_allowlist: Tuple[str, ...] = ()
    check_donation: bool = False
    forbid_collectives: bool = False


# ---------------------------------------------------------------------------
# builders (lazy: subsystem imports stay inside)
# ---------------------------------------------------------------------------

_N_WORKERS = 4          # worker count shared by the tiny vertical builders


def _key_data(*shape) -> np.ndarray:
    """Concrete uint32 key data (raw-key form; no device op to build)."""
    return np.zeros(shape + (2,), np.uint32)


def _build_protocol_aggregate() -> Entry:
    from repro.protocol import Protocol

    h = jax.ShapeDtypeStruct((_N_WORKERS, 2, 8), jnp.float32)
    rng = _key_data()

    def agg(protocol, h, rng):
        return protocol.aggregate(h, rng)

    def argsf(p):
        proto = Protocol.ocs(
            bits=8, max_rounds=2,
            p_miss=np.full((_N_WORKERS,), p, np.float32))
        return (proto, h, rng)

    return Entry(fn=agg, argsf=argsf,
                 lower=lambda: jax.jit(agg).lower(*argsf(0.05)))


def _tiny_curve_config():
    from repro.sim.train_curves import CurveConfig
    return CurveConfig(bits=(8,), p_miss=(0.0, 0.05), steps=4, batch=4,
                       max_rounds=2, n_train=32, n_val=16, hw=8,
                       encoder_dims=(8,), embed_dim=4, head_dims=(8,),
                       log_every=2)


def _curve_args(ccfg, per_bits, logged):
    """Abstract-aval argument factory shared by both curve engines."""
    from repro.core import vertical
    from repro.sim import train_curves as tc

    vcfg_n, opt = per_bits[0], per_bits[2]
    params0 = jax.eval_shape(lambda k: vertical.init(vcfg_n, k),
                             jax.random.PRNGKey(0))
    opt0 = jax.eval_shape(opt.init, params0)
    patch_dim = (ccfg.hw // ccfg.grid) ** 2
    sds = jax.ShapeDtypeStruct
    views = sds((ccfg.n_workers, ccfg.n_train, patch_dim), jnp.float32)
    labels = sds((ccfg.n_train,), jnp.int32)
    vviews = sds((ccfg.n_workers, ccfg.n_val, patch_dim), jnp.float32)
    vlabels = sds((ccfg.n_val,), jnp.int32)
    slots = tc._log_slots(ccfg, logged)
    lane_keys, k_data = _key_data(len(ccfg.p_miss)), _key_data()

    def argsf(p):
        lanes = np.asarray([0.0, p], np.float32)
        return (params0, opt0, lane_keys, lanes, k_data, views, labels,
                vviews, vlabels, slots)

    return argsf


def _build_curves_fused() -> Entry:
    from repro.sim import train_curves as tc

    ccfg = _tiny_curve_config()
    per_bits = tc._make_steps(ccfg, 8)
    logged = ccfg.logged_steps()
    fused = tc._make_fused(ccfg, per_bits, len(logged), n_dev=1)
    return Entry(fn=fused, argsf=_curve_args(ccfg, per_bits, logged))


def _build_curves_fused_dp() -> Entry:
    from repro.optim.compressed_allreduce import CompressedAllReduce
    from repro.core import vertical
    from repro.sim import train_curves as tc

    ccfg = dataclasses.replace(_tiny_curve_config(), dp_shards=2)
    compress = CompressedAllReduce.topk(0.25)
    per_bits = tc._make_steps(ccfg, 8)
    logged = ccfg.logged_steps()
    fused = tc._make_fused_dp(ccfg, compress, per_bits, len(logged),
                              n_s=1, n_d=1)

    vcfg_n, opt = per_bits[0], per_bits[2]
    params0 = jax.eval_shape(lambda k: vertical.init(vcfg_n, k),
                             jax.random.PRNGKey(0))
    opt0 = jax.eval_shape(opt.init, params0)
    patch_dim = (ccfg.hw // ccfg.grid) ** 2
    sds = jax.ShapeDtypeStruct
    views = sds((ccfg.n_workers, ccfg.n_train, patch_dim), jnp.float32)
    labels = sds((ccfg.n_train,), jnp.int32)
    vviews = sds((ccfg.n_workers, ccfg.n_val, patch_dim), jnp.float32)
    vlabels = sds((ccfg.n_val,), jnp.int32)
    slots = tc._log_slots(ccfg, logged)
    lane_keys, k_data = _key_data(len(ccfg.p_miss)), _key_data()
    shard_ids = np.arange(ccfg.dp_shards, dtype=np.int32)
    lanes = len(ccfg.p_miss)

    def argsf(p):
        # the perturbation lands in BOTH rebindable state leaves: the lane
        # p_miss axis AND the error-feedback memory values — the EF carry
        # must be ordinary traced data, never a recompile trigger (concrete
        # arrays here, so differing values would show up as differing
        # jaxprs if they were ever baked in)
        p_lanes = np.asarray([0.0, p], np.float32)
        err0 = jax.tree.map(
            lambda x: np.full((lanes, ccfg.dp_shards) + tuple(x.shape), p,
                              np.float32), params0)
        return (params0, opt0, err0, lane_keys, p_lanes, shard_ids, k_data,
                views, labels, vviews, vlabels, slots)

    return Entry(fn=fused, argsf=argsf)


def _build_curves_sched() -> Entry:
    from repro.protocol import CollisionAdaptiveBits
    from repro.sim import train_curves as tc

    ccfg = _tiny_curve_config()
    schedule = CollisionAdaptiveBits((8, 16))
    per_cand = [tc._make_steps(ccfg, b) for b in schedule.candidates]
    logged = ccfg.logged_steps()
    fused = tc._make_sched_fused(ccfg, schedule, per_cand, len(logged))
    return Entry(fn=fused, argsf=_curve_args(ccfg, per_cand[0], logged))


def _build_serve_tick() -> Entry:
    from repro import faults
    from repro.configs import get_reduced
    from repro.models import model as M
    from repro.parallel.sharding import split_tree
    from repro.protocol import Protocol
    from repro.serve.engine import ServeConfig, ServeEngine

    cfg = get_reduced("qwen1.5-0.5b", n_layers=1, d_model=8, n_heads=2,
                      n_kv_heads=2, d_ff=16, vocab_size=32, n_workers=2)
    m = M.build(cfg)
    values = jax.eval_shape(lambda k: split_tree(m.init(k))[0],
                            jax.random.PRNGKey(0))
    eng = ServeEngine(m, values, ServeConfig(batch_slots=2, max_seq=8))

    def argsf(p):
        # the perturbation lands in EVERY rebindable channel leaf at once:
        # protocol.p_miss, the Gilbert–Elliott transition/miss probs, the
        # dropout rates, AND the carried chain state (bad mask, stale
        # cache, outage counters) — a fault sweep must reuse the one
        # compiled tick
        proto = Protocol.ocs(bits=8, max_rounds=2,
                             p_miss=np.full((2,), p, np.float32))
        fm = faults.FaultModel.gilbert_elliott(
            p_gb=p, p_bg=2 * p, p_miss_good=p, p_miss_bad=0.5,
            policy=faults.DegradePolicy.stale()).with_dropout(p, 1.0 - p)
        fstate = faults.FaultState(
            bad=np.arange(2) % 2 == int(p > 0.05),
            offline=np.zeros((2,), bool),
            stale=np.float32(p), age=np.int32(int(100 * p)),
            consec=np.int32(0))
        return (values, proto, fm, fstate, eng.cur_token, eng.positions,
                eng.cache, np.int32(0))

    return Entry(fn=eng._tick, argsf=argsf,
                 lower=lambda: eng._tick.lower(*argsf(0.05)))


def _build_faults_aggregate() -> Entry:
    from repro import faults
    from repro.protocol import Protocol

    h = jax.ShapeDtypeStruct((_N_WORKERS, 2, 8), jnp.float32)
    rng = _key_data()

    def agg(protocol, model, state, h, rng):
        return faults.aggregate(protocol, model, state, h, rng)

    def argsf(p):
        proto = Protocol.ocs(
            bits=8, max_rounds=2,
            p_miss=np.full((_N_WORKERS,), p, np.float32))
        fm = faults.FaultModel(
            p_gb=np.float32(p), p_bg=np.float32(2 * p),
            p_miss_good=np.float32(p / 2),
            p_miss_bad=np.float32(0.4 + p),
            p_drop=np.float32(p), p_recover=np.float32(1.0 - p),
            policy=faults.DegradePolicy.stale())
        state = faults.FaultState(
            bad=np.arange(_N_WORKERS) % 2 == int(p > 0.05),
            offline=np.arange(_N_WORKERS) % 3 == int(p > 0.05),
            stale=np.full((2, 8), p, np.float32),
            age=np.int32(int(100 * p)), consec=np.int32(int(10 * p)))
        return (proto, fm, state, h, rng)

    return Entry(fn=agg, argsf=argsf,
                 lower=lambda: jax.jit(agg).lower(*argsf(0.05)))


def _build_curves_fused_faults() -> Entry:
    from repro import faults
    from repro.core import vertical
    from repro.sim import train_curves as tc

    ccfg = _tiny_curve_config()
    lanes = 2
    per_bits = tc._make_fault_steps(ccfg, 8)
    logged = ccfg.logged_steps()
    fused = tc._make_fused_faults(ccfg, per_bits, len(logged))

    vcfg_n = per_bits[0]
    params0 = jax.eval_shape(lambda k: vertical.init(vcfg_n, k),
                             jax.random.PRNGKey(0))
    opt0 = jax.eval_shape(per_bits[1].init, params0)
    patch_dim = (ccfg.hw // ccfg.grid) ** 2
    sds = jax.ShapeDtypeStruct
    views = sds((ccfg.n_workers, ccfg.n_train, patch_dim), jnp.float32)
    labels = sds((ccfg.n_train,), jnp.int32)
    vviews = sds((ccfg.n_workers, ccfg.n_val, patch_dim), jnp.float32)
    vlabels = sds((ccfg.n_val,), jnp.int32)
    slots = tc._log_slots(ccfg, logged)
    lane_keys, k_data = _key_data(lanes), _key_data()
    n = ccfg.n_workers

    def argsf(p):
        # lane-stacked fault grid: both lanes' GE transition probs, dropout
        # rates AND the carried chain state (bad/offline masks, stale
        # cache) move with p — the fused engine must hold at one trace
        fm = faults.FaultModel(
            p_gb=np.asarray([0.0, p], np.float32),
            p_bg=np.asarray([0.25, 2 * p], np.float32),
            p_miss_good=np.asarray([0.0, p], np.float32),
            p_miss_bad=np.asarray([0.5, 0.4 + p], np.float32),
            p_drop=np.asarray([0.0, p], np.float32),
            p_recover=np.asarray([1.0, 1.0 - p], np.float32),
            policy=faults.DegradePolicy.stale())
        fs0 = faults.FaultState(
            bad=np.zeros((lanes, n), bool),
            offline=(np.arange(lanes * n).reshape(lanes, n) % 3
                     == int(p > 0.05)),
            stale=np.full((lanes, ccfg.batch, ccfg.embed_dim), p,
                          np.float32),
            age=np.zeros((lanes,), np.int32),
            consec=np.zeros((lanes,), np.int32))
        return (params0, opt0, lane_keys, fm, fs0, k_data, views, labels,
                vviews, vlabels, slots)

    return Entry(fn=fused, argsf=argsf)


def _build_sweep_noisy() -> Entry:
    from repro.sim import sweep as sweep_mod

    fn = functools.partial(sweep_mod._sweep_noisy, bits=8, max_id_bits=2,
                           max_rounds=2, backend="scan", n_devices=1)
    s, r = 2, 1
    h = jax.ShapeDtypeStruct((s, r, _N_WORKERS, 8), jnp.float32)
    mask = jax.ShapeDtypeStruct((s, _N_WORKERS), jnp.bool_)
    id_bits = np.full((s,), 2, np.int32)
    rng = _key_data(s, r)
    n_channels = np.ones((s,), np.int32)

    def argsf(p):
        p_miss = np.full((s, _N_WORKERS), p, np.float32)
        return (h, mask, id_bits, rng, p_miss, n_channels)

    return Entry(fn=fn, argsf=argsf)


def _build_train_step_donated() -> Entry:
    from repro.core import vertical
    from repro.core.vertical import VerticalConfig
    from repro.optim import optimizers, schedules
    from repro.protocol import Protocol
    from repro.train.train_step import make_train_step

    vcfg = VerticalConfig(
        n_workers=_N_WORKERS, input_dim=16, encoder_dims=(8,), embed_dim=4,
        head_dims=(8,), output_dim=4, task="classification",
        aggregation=Protocol.ideal_max(8, tie_break="first"))

    def loss(values, batch):
        views, labels = batch
        return vertical.loss_fn(vcfg, values, views, labels)

    opt = optimizers.adamw(schedules.constant(1e-3), weight_decay=0.01)
    step = make_train_step(loss, opt, donate=True)
    values = jax.eval_shape(lambda k: vertical.init(vcfg, k),
                            jax.random.PRNGKey(0))
    opt_state = jax.eval_shape(opt.init, values)
    batch = (jax.ShapeDtypeStruct((_N_WORKERS, 8, 16), jnp.float32),
             jax.ShapeDtypeStruct((8,), jnp.int32))
    args = (values, opt_state, batch)
    donated = len(jax.tree_util.tree_leaves((values, opt_state)))

    return Entry(fn=step, argsf=lambda p: args,
                 lower=lambda: step.lower(*args), donated=donated)


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------

CONTRACTS: Tuple[Contract, ...] = (
    Contract(
        name="protocol.aggregate",
        build=_build_protocol_aggregate,
        max_dispatches="inline (no host loop)",
        forbid_collectives=True,
    ),
    Contract(
        name="curves.fused",
        build=_build_curves_fused,
        max_dispatches="1 per bits value "
                       "(+ <= ceil(steps/log_every)+2 result fetches)",
    ),
    Contract(
        name="curves.fused_dp",
        build=_build_curves_fused_dp,
        recompile_free_over="protocol.p_miss + error-feedback memory",
        max_dispatches="1 per bits value "
                       "(+ <= ceil(steps/log_every)+2 result fetches)",
    ),
    Contract(
        name="curves.sched",
        build=_build_curves_sched,
        max_dispatches="1 per scheduled run",
    ),
    Contract(
        name="serve.tick",
        build=_build_serve_tick,
        recompile_free_over="protocol.p_miss + fault-model leaves + "
                            "chain state",
        max_dispatches="1 per decode tick",
        forbid_collectives=True,
    ),
    Contract(
        name="faults.aggregate",
        build=_build_faults_aggregate,
        recompile_free_over="GE transition/miss probs + dropout rates + "
                            "chain state + protocol.p_miss",
        max_dispatches="inline (no host loop)",
        forbid_collectives=True,
    ),
    Contract(
        name="curves.fused_faults",
        build=_build_curves_fused_faults,
        recompile_free_over="fault-model leaves + FaultState carry "
                            "(incl. stale cache + dropout masks)",
        max_dispatches="1 per bits value (+ result fetches)",
    ),
    Contract(
        name="sweep.noisy",
        build=_build_sweep_noisy,
        max_dispatches="1 per bits value",
    ),
    Contract(
        name="train.step_donated",
        build=_build_train_step_donated,
        recompile_free_over="",          # no channel leaf: ideal protocol
        max_dispatches="1 per step",
        check_donation=True,
    ),
)


def contract_names() -> Tuple[str, ...]:
    return tuple(c.name for c in CONTRACTS)


def get_contract(name: str) -> Contract:
    for c in CONTRACTS:
        if c.name == name:
            return c
    raise KeyError(f"no contract named {name!r}; "
                   f"known: {contract_names()}")


def check_contract(contract: Contract, *, skip_hlo: bool = False
                   ) -> List[Finding]:
    """Run every check the contract declares; returns its findings."""
    entry = contract.build()
    findings: List[Finding] = []
    if contract.recompile_free_over:
        findings += C.check_trace_stable(contract.name, entry.fn,
                                         entry.argsf)
    if contract.forbid_host_sync:
        findings += C.check_no_host_sync(contract.name, entry.fn,
                                         entry.argsf(0.05),
                                         contract.host_sync_allowlist)
    if contract.forbid_f64:
        findings += C.check_no_f64(contract.name, entry.fn, entry.argsf)
    if contract.check_donation and entry.lower is not None:
        findings += C.check_donation(contract.name, entry.fn,
                                     entry.argsf(0.05), entry.donated)
    if not skip_hlo and entry.lower is not None:
        from repro.analysis import hlo_checks
        findings += hlo_checks.check_entry_hlo(contract, entry)
    return findings


def check_all(*, skip_hlo: bool = False) -> List[Finding]:
    findings: List[Finding] = []
    for c in CONTRACTS:
        findings += check_contract(c, skip_hlo=skip_hlo)
    return findings
