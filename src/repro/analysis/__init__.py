"""Static analysis: machine-enforced performance contracts + repo lint.

Three layers (see ``python -m repro.analysis --help`` for the CLI):

* :mod:`repro.analysis.contracts` — trace-level checks on registered
  jitted entry points (jaxpr-hash recompile stability over ``p_miss``
  rebinds, f64 hygiene under ``JAX_ENABLE_X64``, host-sync freedom,
  donation), plus the shared dispatch-count assertions the benchmark
  self-checks call;
* :mod:`repro.analysis.hlo_checks` — compiled-module checks (donated
  buffers alias outputs, no collective/copy insertions);
* :mod:`repro.analysis.lint` — repo-specific AST rules (no hardcoded
  Pallas interpret mode, no concretization inside jit scopes, no eager
  jnp loops in jitted code, kernel parity coverage, engine determinism).

The registry (:data:`repro.analysis.registry.CONTRACTS`) is the single
declaration point: tier-1 tests parametrize over it and CI runs the CLI
against the committed (empty) ``analysis_baseline.json``.
"""

from repro.analysis.contracts import (  # noqa: F401
    assert_fused_dispatches, assert_single_dispatch,
    assert_tick_dispatch_bracket, assert_trace_count, fused_dispatch_bound,
)
from repro.analysis.registry import (  # noqa: F401
    CONTRACTS, check_all, check_contract, contract_names, get_contract,
)
from repro.analysis.report import (  # noqa: F401
    Finding, Report, load_baseline,
)

__all__ = [
    "CONTRACTS", "Finding", "Report", "assert_fused_dispatches",
    "assert_single_dispatch", "assert_tick_dispatch_bracket",
    "assert_trace_count", "check_all", "check_contract", "contract_names",
    "fused_dispatch_bound", "get_contract", "load_baseline",
]
