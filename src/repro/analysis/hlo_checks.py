"""HLO-level checks: lower/compile registered entry points and inspect the
compiled module text — the layer below the jaxpr checks, generalizing what
``repro.launch.hlo_analysis`` does for the roofline.

Three inspections, all driven by the contract's declared flags:

``donation-alias``
    A contract with donated buffers must compile with an
    ``input_output_alias`` table — the runtime-level proof that donation
    survived compilation (the lowering-level attribute check lives in
    :func:`repro.analysis.contracts.check_donation`).

``unexpected-collective``
    Contracts flagged ``forbid_collectives`` (single-cell entry points:
    the protocol aggregation law, the serve tick) must compile with ZERO
    cross-device collectives; any all-reduce/all-gather/... insertion means
    a sharding annotation leaked into a single-device program.  Counting is
    delegated to :func:`repro.launch.hlo_analysis.parse_collectives` — the
    same parser the roofline uses.

``excess-copies``
    Reported (never a hard failure on its own) when a compiled entry point
    carries an unusually copy-heavy module; the count rides in the JSON
    report so copy regressions are visible over time.
"""

from __future__ import annotations

import re
from typing import List

from repro.analysis import report as R
from repro.analysis.report import Finding
from repro.launch.hlo_analysis import parse_collectives

# a compiled tiny entry point has no business exceeding this many explicit
# copy ops; the bound sits well above the measured baselines (the serve
# tick's vmapped KV-cache scatter compiles to ~126 on CPU) so only an
# order-of-magnitude double-buffering regression trips it
DEFAULT_MAX_COPIES = 512

_COPY_RE = re.compile(r"=\s*\w+\[[^\]]*\][^=]*\bcopy\(")


def count_copies(hlo_text: str) -> int:
    return sum(1 for line in hlo_text.splitlines() if _COPY_RE.search(line))


def check_entry_hlo(contract, entry) -> List[Finding]:
    """Compile the entry point once and run its declared HLO inspections."""
    where = f"contract:{contract.name}"
    try:
        compiled_text = entry.lower().compile().as_text()
    except Exception as e:
        return [Finding(
            R.CHECK_ERROR, where, "hlo",
            f"HLO check could not lower/compile the entry point: "
            f"{type(e).__name__}: {e}")]
    findings: List[Finding] = []

    if contract.check_donation and entry.donated:
        if "input_output_alias" not in compiled_text:
            findings.append(Finding(
                R.DONATION_ALIAS, where, "compiled",
                f"contract declares {entry.donated} donated buffers but the "
                f"compiled module has no input_output_alias table — XLA "
                f"double-buffers the train state"))

    if contract.forbid_collectives:
        stats = parse_collectives(compiled_text, strict=False)
        if stats.counts:
            findings.append(Finding(
                R.UNEXPECTED_COLLECTIVE, where, "collectives",
                f"single-cell entry point compiles with cross-device "
                f"collectives {stats.counts} — a sharding annotation "
                f"leaked into a single-device program"))

    n_copies = count_copies(compiled_text)
    if n_copies > DEFAULT_MAX_COPIES:
        findings.append(Finding(
            R.EXCESS_COPIES, where, "copies",
            f"compiled module carries {n_copies} copy ops "
            f"(> {DEFAULT_MAX_COPIES}) — something is double-buffering"))
    return findings
