"""Repo-specific AST lint: the rules ruff can't express.

Six rules, all syntactic (no imports of the scanned code, so a broken
module parses and lints like any other):

``interpret-hardcode``
    No ``interpret=True`` literal (or ``INTERPRET = True`` constant)
    anywhere outside ``repro/kernels/__init__.py`` — Pallas interpret mode
    is resolved exactly once, by ``repro.kernels.interpret_default()``
    (env-driven), so CI can flip the whole repo between compiled and
    interpreter kernels.

``host-sync-in-jit``
    Inside a jitted scope: no ``.item()``, no ``float(x)``/``int(x)`` on a
    non-literal, no ``np.asarray``/``np.array`` — each one concretizes a
    traced value, which either fails to trace or (worse) silently bakes a
    host value into the compiled program and breaks the zero-recompile
    contract.

``eager-loop-in-jit``
    Inside a jitted scope: no ``jnp.*`` calls in a Python ``for``/``while``
    body — the loop unrolls into the trace (compile time and program size
    scale with the trip count); use ``lax.scan``/``fori_loop``.  Building
    *branch closures* in a loop is fine — the rule only fires on direct
    ``jnp`` array ops.

``missing-kernel-ref``
    Every ``src/repro/kernels/<pkg>/`` package must ship a ``ref.py``
    reference implementation and appear in a ``ParityOp`` grid
    registration under ``tests/`` — the kernel parity harness is the
    standing guardrail; a kernel without it is unverifiable.

``nondeterminism``
    Engine code (sim/serve/protocol/core/train/optim/models/faults) must
    not call wall clocks (``time.*``, ``datetime.now``) or global-state
    RNGs (stdlib ``random.*``, legacy ``np.random.*``); seeded
    ``np.random.default_rng`` stays legal.  Benchmarks time things — they
    are exempt from this rule, not from the jit rules.

``silent-except``
    Engine code must not swallow exceptions: no bare ``except:`` and no
    handler whose entire body is ``pass``/``...`` — a fault-injection run
    that silently eats an error reports clean numbers for a broken
    experiment.  Degrade *policies* handle faults explicitly
    (``repro.faults.DegradePolicy``); code outside the engine subtrees
    (e.g. best-effort checkpoint discovery) may still catch-and-continue.

Jitted scopes are detected syntactically: functions decorated with
``@jax.jit``/``@jit``/``@functools.partial(jax.jit, ...)``, functions
wrapped as ``jax.jit(name)`` anywhere in the module, lambdas passed
directly to ``jax.jit``, and every ``def`` nested inside one of those
(nested defs trace with their parent).
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, List, Optional, Set, Tuple

from repro.analysis import report as R
from repro.analysis.report import Finding

# rules `host-sync-in-jit` and `eager-loop-in-jit` apply to jitted scopes
# in any scanned file; `nondeterminism` and `silent-except` only to these
# engine subtrees
ENGINE_DIRS = ("src/repro/sim", "src/repro/serve", "src/repro/protocol",
               "src/repro/core", "src/repro/train", "src/repro/optim",
               "src/repro/models", "src/repro/faults")

# the one module allowed to spell `interpret=` resolution
INTERPRET_HOME = "src/repro/kernels/__init__.py"

_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "PCG64",
                 "Philox", "bit_generator"}


def _is_jax_jit(node: ast.AST) -> bool:
    """``jit`` / ``jax.jit`` (as a decorator or a called function)."""
    if isinstance(node, ast.Name):
        return node.id == "jit"
    if isinstance(node, ast.Attribute):
        return (node.attr == "jit" and isinstance(node.value, ast.Name)
                and node.value.id == "jax")
    return False


def _jit_decorated(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        if _is_jax_jit(dec):
            return True
        # @functools.partial(jax.jit, ...) / @partial(jax.jit, ...)
        if isinstance(dec, ast.Call):
            f = dec.func
            is_partial = (
                (isinstance(f, ast.Name) and f.id == "partial")
                or (isinstance(f, ast.Attribute) and f.attr == "partial"))
            if is_partial and dec.args and _is_jax_jit(dec.args[0]):
                return True
            if _is_jax_jit(f):
                return True
    return False


def _jit_scopes(tree: ast.Module) -> List[ast.AST]:
    """Function/lambda nodes whose bodies trace under jit."""
    scopes: List[ast.AST] = []
    wrapped_names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _jit_decorated(node):
                scopes.append(node)
        elif isinstance(node, ast.Call) and _is_jax_jit(node.func):
            if node.args and isinstance(node.args[0], ast.Name):
                wrapped_names.add(node.args[0].id)
            elif node.args and isinstance(node.args[0], ast.Lambda):
                scopes.append(node.args[0])
    if wrapped_names:
        for node in ast.walk(tree):
            if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and node.name in wrapped_names):
                scopes.append(node)
    return scopes


def _scope_name(scope: ast.AST) -> str:
    return getattr(scope, "name", "<lambda>")


def _call_symbol(call: ast.Call) -> Optional[str]:
    """Short printable symbol of a concretizing call, or None if benign."""
    f = call.func
    if isinstance(f, ast.Attribute) and f.attr == "item":
        return ".item()"
    if (isinstance(f, ast.Name) and f.id in ("float", "int")
            and len(call.args) == 1
            and not isinstance(call.args[0], ast.Constant)):
        return f"{f.id}()"
    if (isinstance(f, ast.Attribute) and f.attr in ("asarray", "array")
            and isinstance(f.value, ast.Name)
            and f.value.id in ("np", "numpy", "onp")):
        return f"{f.value.id}.{f.attr}()"
    return None


def _is_jnp_call(node: ast.AST) -> bool:
    if not (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)):
        return False
    v = node.func.value
    if isinstance(v, ast.Name) and v.id == "jnp":
        return True
    # jax.numpy.<op>(...)
    return (isinstance(v, ast.Attribute) and v.attr == "numpy"
            and isinstance(v.value, ast.Name) and v.value.id == "jax")


def _module_imports(tree: ast.Module) -> Set[str]:
    """Top-level module names bound by plain ``import`` statements."""
    mods: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                mods.add(alias.asname or alias.name.split(".")[0])
    return mods


# ---------------------------------------------------------------------------
# per-file rules
# ---------------------------------------------------------------------------

def _check_interpret(tree: ast.Module, rel: str) -> List[Finding]:
    findings = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if (kw.arg == "interpret"
                        and isinstance(kw.value, ast.Constant)
                        and kw.value.value is True):
                    findings.append(Finding(
                        R.INTERPRET_HARDCODE, rel, "interpret=True",
                        "hardcoded interpret=True — route through "
                        "repro.kernels.interpret_default() so CI controls "
                        "interpret mode", line=node.lineno))
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                if (isinstance(tgt, ast.Name) and tgt.id == "INTERPRET"
                        and isinstance(node.value, ast.Constant)
                        and node.value.value is True):
                    findings.append(Finding(
                        R.INTERPRET_HARDCODE, rel, "INTERPRET=True",
                        "hardcoded INTERPRET constant — route through "
                        "repro.kernels.interpret_default()",
                        line=node.lineno))
    return findings


def _check_jit_scopes(tree: ast.Module, rel: str) -> List[Finding]:
    findings = []
    for scope in _jit_scopes(tree):
        sname = _scope_name(scope)
        for node in ast.walk(scope):
            sym = _call_symbol(node) if isinstance(node, ast.Call) else None
            if sym is not None:
                findings.append(Finding(
                    R.HOST_SYNC_IN_JIT, rel, f"{sname}:{sym}",
                    f"`{sym}` inside jitted `{sname}` concretizes a traced "
                    f"value (host sync / bakes a constant into the trace)",
                    line=node.lineno))
            if isinstance(node, (ast.For, ast.While)):
                jnp_call = next((c for c in ast.walk(node)
                                 if _is_jnp_call(c)), None)
                if jnp_call is not None:
                    findings.append(Finding(
                        R.EAGER_LOOP_IN_JIT, rel, f"{sname}:loop",
                        f"Python loop with jnp ops inside jitted "
                        f"`{sname}` unrolls into the trace — use "
                        f"lax.scan/fori_loop", line=node.lineno))
    return findings


def _check_nondeterminism(tree: ast.Module, rel: str) -> List[Finding]:
    imports = _module_imports(tree)
    findings = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)):
            continue
        f = node.func
        sym = None
        if isinstance(f.value, ast.Name):
            base = f.value.id
            if base == "time" and "time" in imports:
                sym = f"time.{f.attr}"
            elif base == "random" and "random" in imports:
                sym = f"random.{f.attr}"
            elif base == "datetime" and f.attr in ("now", "utcnow", "today"):
                sym = f"datetime.{f.attr}"
        elif (isinstance(f.value, ast.Attribute)
              and f.value.attr == "random"
              and isinstance(f.value.value, ast.Name)
              and f.value.value.id in ("np", "numpy")
              and f.attr not in _NP_RANDOM_OK):
            sym = f"np.random.{f.attr}"
        if sym is not None:
            findings.append(Finding(
                R.NONDETERMINISM, rel, sym,
                f"`{sym}()` in engine code — engines must be "
                f"seed-deterministic (thread a PRNG key or a seeded "
                f"default_rng)", line=node.lineno))
    return findings


def _check_silent_except(tree: ast.Module, rel: str) -> List[Finding]:
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if node.type is None:
            findings.append(Finding(
                R.SILENT_EXCEPT, rel, "bare",
                "bare `except:` in engine code catches everything "
                "(including KeyboardInterrupt) — name the exception",
                line=node.lineno))
            continue
        swallow = all(
            isinstance(stmt, ast.Pass)
            or (isinstance(stmt, ast.Expr)
                and isinstance(stmt.value, ast.Constant)
                and stmt.value.value is Ellipsis)
            for stmt in node.body)
        if swallow:
            name = ast.unparse(node.type)
            findings.append(Finding(
                R.SILENT_EXCEPT, rel, f"swallow:{name}",
                f"`except {name}: pass` in engine code swallows the error "
                f"— a faulted run would report clean numbers; handle it "
                f"or let it propagate", line=node.lineno))
    return findings


def lint_file(path: Path, rel: str, *, engine: bool) -> List[Finding]:
    """All per-file rules on one source file (``rel`` is the repo-relative
    path used in findings; ``engine`` enables the nondeterminism and
    silent-except rules)."""
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:
        return [Finding(R.CHECK_ERROR, rel, "syntax",
                        f"could not parse: {e}", line=e.lineno)]
    findings: List[Finding] = []
    if rel != INTERPRET_HOME:
        findings += _check_interpret(tree, rel)
    findings += _check_jit_scopes(tree, rel)
    if engine:
        findings += _check_nondeterminism(tree, rel)
        findings += _check_silent_except(tree, rel)
    return findings


# ---------------------------------------------------------------------------
# repo-level rules + the scan driver
# ---------------------------------------------------------------------------

def check_kernel_refs(root: Path) -> List[Finding]:
    """Every kernels/<pkg>/ ships ref.py and a ParityOp registration."""
    kdir = root / "src/repro/kernels"
    if not kdir.is_dir():
        return []
    registrations = []
    tests = root / "tests"
    if tests.is_dir():
        for t in sorted(tests.glob("*.py")):
            text = t.read_text()
            if "ParityOp(" in text:
                registrations.append(text)
    findings = []
    for pkg in sorted(p for p in kdir.iterdir()
                      if p.is_dir() and (p / "ops.py").exists()):
        rel = f"src/repro/kernels/{pkg.name}"
        if not (pkg / "ref.py").exists():
            findings.append(Finding(
                R.MISSING_KERNEL_REF, rel, "ref.py",
                f"kernel package `{pkg.name}` has no ref.py reference "
                f"implementation — the parity harness has nothing to "
                f"check against"))
        if not any(pkg.name in text for text in registrations):
            findings.append(Finding(
                R.MISSING_KERNEL_REF, rel, "parity-op",
                f"kernel package `{pkg.name}` has no ParityOp grid "
                f"registration under tests/ — register it with the "
                f"kernel parity harness"))
    return findings


def _iter_files(root: Path) -> Iterable[Tuple[Path, str, bool]]:
    """(path, relpath, engine?) of every scannable source file."""
    for top in ("src/repro", "benchmarks", "examples"):
        base = root / top
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            engine = any(rel == d or rel.startswith(d + "/")
                         for d in ENGINE_DIRS)
            yield path, rel, engine


def lint_repo(root) -> List[Finding]:
    """All AST-lint findings of the repo at ``root``."""
    root = Path(root)
    findings: List[Finding] = []
    for path, rel, engine in _iter_files(root):
        findings += lint_file(path, rel, engine=engine)
    findings += check_kernel_refs(root)
    return findings
