"""``python -m repro.analysis``: run the whole static-analysis pass.

Layers (each can be skipped independently):

* trace-level contracts (``repro.analysis.registry``): jaxpr-hash
  recompile stability, f64 hygiene, host-sync freedom, donation;
* HLO-level checks: compiled donation aliasing, collective freedom,
  copy pressure;
* AST lint over ``src/repro``, ``benchmarks`` and ``examples``.

Exit status is 0 iff every finding is waived by the baseline
(``analysis_baseline.json`` at the repo root by default — committed empty,
so CI is strict).  ``--json`` writes the full machine-readable report.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

from repro.analysis import lint, registry
from repro.analysis.report import Report, load_baseline


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr/HLO contract checker + repo AST lint")
    ap.add_argument("--root", default=".",
                    help="repo root to scan (default: cwd)")
    ap.add_argument("--baseline", default=None,
                    help="waiver baseline JSON (default: "
                         "<root>/analysis_baseline.json if present)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable report here")
    ap.add_argument("--skip-contracts", action="store_true",
                    help="skip the trace/HLO contract checks")
    ap.add_argument("--skip-hlo", action="store_true",
                    help="run contracts but skip lowering/compiling "
                         "(no HLO-level checks)")
    ap.add_argument("--skip-lint", action="store_true",
                    help="skip the AST lint")
    args = ap.parse_args(argv)

    root = Path(args.root).resolve()
    baseline = args.baseline
    if baseline is None:
        cand = root / "analysis_baseline.json"
        baseline = str(cand) if cand.exists() else None

    report = Report(waivers=load_baseline(baseline))
    if not args.skip_lint:
        report.extend(lint.lint_repo(root))
    if not args.skip_contracts:
        report.extend(registry.check_all(skip_hlo=args.skip_hlo))

    if args.json:
        report.write_json(args.json)

    unwaived = report.unwaived()
    n_waived = len(report.findings) - len(unwaived)
    for f in sorted(unwaived, key=lambda f: f.key):
        print(f.render())
    if n_waived:
        print(f"({n_waived} finding(s) waived by {baseline})")
    for w in report.stale_waivers():
        print(f"note: stale waiver (no matching finding): {w}")
    if unwaived:
        print(f"FAIL: {len(unwaived)} unwaived finding(s)")
        return 1
    print(f"OK: {len(report.findings)} finding(s), all waived"
          if report.findings else
          "OK: no findings")
    return 0


if __name__ == "__main__":
    sys.exit(main())
