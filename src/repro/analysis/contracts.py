"""Trace-level contract checks: jaxpr-hash recompile stability, dtype
hygiene, host-sync freedom, donation — plus the shared dispatch-count
assertions the benchmark self-checks call.

The checks operate on **abstract avals only**: every entry point is traced
with ``jax.make_jaxpr`` over ``ShapeDtypeStruct``/host-array arguments, so
proving e.g. that the fused curve engine never retraces across perturbed
``p_miss`` leaves costs two traces and zero device executions — no training
step, no serve tick, no kernel launch.

Rules implemented here (see ``repro.analysis.registry`` for what each entry
point declares):

``recompile-hazard``
    Rebinding the contract's traced leaves (the protocol's ``p_miss``) must
    neither change the argument treedef (a static/meta leaf would) nor the
    canonicalized jaxpr hash (a host-materialized value baked into the
    trace would).  Tracing that *raises* a concretization error is the same
    hazard reported with the trace error attached.

``f64-promotion``
    The entry point is re-traced under ``jax.experimental.enable_x64`` and
    the jaxpr is walked for float64/complex128 *array* avals (scalar weak-
    type f64 intermediates are JAX-internal promotion noise and stay
    legal) and for ``convert_element_type`` ops landing on f64 arrays.
    Code with explicit dtypes everywhere — the repo convention — traces
    identically with and without x64, so this proves an ``JAX_ENABLE_X64``
    host cannot silently double the engines' memory traffic.

``host-sync``
    No callback primitive (``pure_callback``/``io_callback``/
    ``debug_callback``) anywhere in the jaxpr, except an explicit
    per-contract allowlist: callbacks stall the dispatch pipeline on a
    host round-trip.

``donation-alias``
    Arguments the contract declares donated must actually lower as donated
    buffers (``tf.aliasing_output``/``jax.buffer_donor`` input attributes
    in the lowered module); ``repro.analysis.hlo_checks`` additionally
    asserts the compiled executable aliases them (``input_output_alias``).
"""

from __future__ import annotations

import hashlib
import math
import re
from typing import Callable, Dict, Iterable, List, Sequence, Tuple

import jax
import numpy as np

from repro.analysis import report as R
from repro.analysis.report import Finding

try:  # jax >= 0.4.36 moved the IR types to jax.extend.core
    from jax.extend import core as jcore
except ImportError:  # pragma: no cover - older jax
    from jax import core as jcore

CALLBACK_PRIMITIVES = frozenset(
    {"pure_callback", "io_callback", "debug_callback", "callback"})

_ADDR_RE = re.compile(r"0x[0-9a-f]+")
_F64 = (np.dtype(np.float64), np.dtype(np.complex128))


# ---------------------------------------------------------------------------
# jaxpr plumbing
# ---------------------------------------------------------------------------

def _jaxpr_of(x):
    """The raw ``Jaxpr`` behind a ``ClosedJaxpr``/``Jaxpr`` value."""
    return x.jaxpr if hasattr(x, "jaxpr") else x


def iter_jaxprs(closed) -> Iterable:
    """The jaxpr and every sub-jaxpr reachable through eqn params
    (pjit bodies, scan/while carries, cond/switch branches, custom_vjp
    calls, ...), depth-first."""
    seen = []
    stack = [_jaxpr_of(closed)]
    while stack:
        j = stack.pop()
        seen.append(j)
        yield j
        for eqn in j.eqns:
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    stack.append(sub)


def _sub_jaxprs(param) -> List:
    if isinstance(param, (jcore.Jaxpr, jcore.ClosedJaxpr)):
        return [_jaxpr_of(param)]
    if isinstance(param, (list, tuple)):
        out = []
        for p in param:
            out.extend(_sub_jaxprs(p))
        return out
    return []


def iter_eqns(closed) -> Iterable:
    for j in iter_jaxprs(closed):
        for eqn in j.eqns:
            yield eqn


def canonical_jaxpr(closed) -> str:
    """Deterministic jaxpr text: object addresses (callback closures,
    custom_vjp bwd thunks) are scrubbed so two traces of the same program
    hash equal."""
    return _ADDR_RE.sub("0x", str(closed))


def jaxpr_hash(closed) -> str:
    return hashlib.sha256(canonical_jaxpr(closed).encode()).hexdigest()


def _aval_of(var):
    return getattr(var, "aval", None)


def _leaf_aval(x):
    x = np.asarray(x) if not hasattr(x, "shape") else x
    return (tuple(x.shape), np.dtype(x.dtype))


# ---------------------------------------------------------------------------
# the individual checks
# ---------------------------------------------------------------------------

def check_trace_stable(name: str, fn: Callable,
                       argsf: Callable[[float], Tuple],
                       perturb: Sequence[float] = (0.03, 0.11),
                       ) -> List[Finding]:
    """The jaxpr-hash recompile check: ``fn(*argsf(p))`` must trace to the
    same program for every perturbation ``p`` of the rebindable leaves."""
    where = f"contract:{name}"

    def _trace(args):
        # a fresh wrapper per trace defeats jax's tracing cache (keyed on
        # fn identity + avals) — the cache would replay the FIRST trace and
        # mask host values baked in through closures, the exact hazard this
        # check exists to catch
        return jax.make_jaxpr(lambda *a: fn(*a))(*args)

    base, rest = perturb[0], perturb[1:]
    args0 = argsf(base)
    leaves0, tree0 = jax.tree_util.tree_flatten(args0)
    try:
        h0 = jaxpr_hash(_trace(args0))
    except Exception as e:  # concretization of the traced leaf, usually
        return [Finding(
            R.RECOMPILE_HAZARD, where, "trace-error",
            f"tracing with perturbed leaf={base} raised "
            f"{type(e).__name__}: {e}")]
    findings: List[Finding] = []
    for p in rest:
        args1 = argsf(p)
        leaves1, tree1 = jax.tree_util.tree_flatten(args1)
        if tree1 != tree0:
            findings.append(Finding(
                R.RECOMPILE_HAZARD, where, "treedef",
                f"rebinding the traced leaf to {p} changes the argument "
                f"treedef — the leaf is static metadata, every rebind "
                f"retraces"))
            continue
        mismatch = [i for i, (a, b) in enumerate(zip(leaves0, leaves1))
                    if _leaf_aval(a) != _leaf_aval(b)]
        if mismatch:
            findings.append(Finding(
                R.RECOMPILE_HAZARD, where, "aval",
                f"rebinding the traced leaf to {p} changes argument avals "
                f"at flat positions {mismatch} — shape/dtype-unstable "
                f"rebinds retrace"))
            continue
        try:
            h1 = jaxpr_hash(_trace(args1))
        except Exception as e:
            findings.append(Finding(
                R.RECOMPILE_HAZARD, where, "trace-error",
                f"tracing with perturbed leaf={p} raised "
                f"{type(e).__name__}: {e}"))
            continue
        if h1 != h0:
            findings.append(Finding(
                R.RECOMPILE_HAZARD, where, "jaxpr-hash",
                f"jaxpr hash changes when the traced leaf rebinds "
                f"{base} -> {p}: a leaf value is baked into the trace "
                f"(host materialization or static capture)"))
    return findings


def check_no_host_sync(name: str, fn: Callable, args: Tuple,
                       allowlist: Sequence[str] = ()) -> List[Finding]:
    """No callback primitives anywhere in the traced program."""
    where = f"contract:{name}"
    try:
        closed = jax.make_jaxpr(fn)(*args)
    except Exception as e:
        return [_trace_error(name, "host-sync", e)]
    findings = []
    seen = set()
    for eqn in iter_eqns(closed):
        pname = eqn.primitive.name
        if pname in CALLBACK_PRIMITIVES and pname not in allowlist \
                and pname not in seen:
            seen.add(pname)
            findings.append(Finding(
                R.HOST_SYNC, where, pname,
                f"jitted program contains a `{pname}` primitive — a host "
                f"round-trip inside the dispatch (allowlist it in the "
                f"contract if intentional)"))
    return findings


def check_no_f64(name: str, fn: Callable,
                 argsf: Callable[[float], Tuple]) -> List[Finding]:
    """Trace under enable_x64 and walk for f64 *array* avals.

    Entry points with explicit dtypes everywhere are x64-invariant; an
    untyped ``jnp.zeros``/``jnp.asarray``/np-f64 constant shows up here as
    an f64 array the moment someone runs with ``JAX_ENABLE_X64=1``.
    """
    where = f"contract:{name}"
    from jax.experimental import enable_x64
    args = argsf(0.05)
    try:
        with enable_x64():
            closed = jax.make_jaxpr(fn)(*args)
    except Exception as e:
        # the plain trace succeeds (the recompile/host-sync checks ran), so
        # failing only under x64 is itself the dtype instability
        return [Finding(
            R.F64_PROMOTION, where, "x64-trace",
            f"entry point fails to trace under JAX_ENABLE_X64 "
            f"({type(e).__name__}: {e}) — an unpinned dtype promotes and "
            f"collides; pin dtypes explicitly")]
    findings: List[Finding] = []
    seen = set()

    def flag(detail: str, msg: str):
        if detail not in seen:
            seen.add(detail)
            findings.append(Finding(R.F64_PROMOTION, where, detail, msg))

    for eqn in iter_eqns(closed):
        for var in list(eqn.invars) + list(eqn.outvars):
            aval = _aval_of(var)
            if aval is None or not hasattr(aval, "dtype"):
                continue
            try:
                dt = np.dtype(aval.dtype)
            except TypeError:       # extended dtypes (typed PRNG keys)
                continue
            if dt in _F64 and getattr(aval, "ndim", 0) >= 1:
                flag(f"{eqn.primitive.name}:{dt.name}",
                     f"`{eqn.primitive.name}` touches a "
                     f"{dt.name}{list(aval.shape)} array "
                     f"under JAX_ENABLE_X64 — an untyped construction "
                     f"silently promotes (pin the dtype explicitly)")
    return findings


def check_donation(name: str, jitted: Callable, args: Tuple,
                   n_expected: int) -> List[Finding]:
    """Declared donated arguments must lower as donated buffers."""
    where = f"contract:{name}"
    try:
        text = jitted.lower(*args).as_text()
    except Exception as e:
        return [_trace_error(name, "donation", e)]
    donated = text.count("tf.aliasing_output") + text.count("jax.buffer_donor")
    if donated < n_expected:
        return [Finding(
            R.DONATION_ALIAS, where, "lowered",
            f"contract declares {n_expected} donated buffers but only "
            f"{donated} lower with a donation attribute "
            f"(tf.aliasing_output/jax.buffer_donor) — donate_argnums "
            f"dropped or shapes no longer alias")]
    return []


def _trace_error(name: str, what: str, e: Exception) -> Finding:
    return Finding(
        R.CHECK_ERROR, f"contract:{name}", what,
        f"{what} check could not trace the entry point: "
        f"{type(e).__name__}: {e}")


# ---------------------------------------------------------------------------
# shared dispatch-count assertions (the bench self-checks call these; the
# registry documents each entry point's declared bound)
# ---------------------------------------------------------------------------

def fused_dispatch_bound(steps: int, log_every: int) -> int:
    """Host dispatches one fused curve run may cost per ``bits`` value:
    the single fused dispatch plus the logged-buffer fetches."""
    return math.ceil(steps / log_every) + 2


def assert_trace_count(observed: int, expected: int, what: str) -> None:
    """Exactly-N-compilations contract (e.g. one per ``bits`` value)."""
    if observed != expected:
        raise RuntimeError(
            f"{what} recompiled: {observed} traces, expected {expected} — "
            "a traced leaf regressed to static (zero-recompile contract)")


def assert_fused_dispatches(dispatches_per_bits: float, steps: int,
                            log_every: int) -> None:
    """The fused curve engine's one-dispatch contract (per ``bits``)."""
    bound = fused_dispatch_bound(steps, log_every)
    if dispatches_per_bits > bound:
        raise RuntimeError(
            f"fused engine dispatched {dispatches_per_bits}/bits — exceeds "
            f"the ceil(steps/log_every)+2 = {bound} fusion bound")


def assert_single_dispatch(counts: Dict[str, int], key: str,
                           what: str) -> None:
    """Whole-run-in-ONE-dispatch contract (the scheduled curve engine)."""
    if counts.get(key) != 1:
        raise RuntimeError(
            f"{what} cost {counts} dispatches — must fuse to ONE")


def assert_tick_dispatch_bracket(name: str, decode_tokens: int, ticks: int,
                                 batch_slots: int) -> None:
    """One fused dispatch per serve decode tick.

    Every dispatch decodes >=1 active slot (the engine never dispatches an
    empty batch) and <= batch_slots tokens, so the counted dispatches must
    bracket the total decoded-token count: extra per-tick host->device hops
    push the count above the token total, skipped fusions below tokens/B.
    """
    lo = -(-decode_tokens // batch_slots)            # ceil division
    if not lo <= ticks <= decode_tokens:
        raise RuntimeError(
            f"{name}: {ticks} decode dispatches for {decode_tokens} decoded "
            f"tokens over {batch_slots} slots — not one fused dispatch per "
            f"tick (expected in [{lo}, {decode_tokens}])")
