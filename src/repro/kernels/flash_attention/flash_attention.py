"""Block-wise online-softmax (flash) attention forward — Pallas TPU kernel.

TPU-native adaptation (DESIGN.md §2): instead of the CUDA shared-memory /
warp-shuffle structure, the kernel uses the canonical Pallas TPU pattern —
a 4D grid (B, H, Sq/BQ, Sk/BK) whose last dimension executes *sequentially*
per core, carrying the online-softmax state (m, l, acc) in VMEM scratch.
BlockSpecs keep every operand tile MXU-aligned: BQ=BK=128 (multiples of the
128-lane register width), head_dim padded to 128 by the callers' configs.

GQA is handled in the index map (query head h reads KV head h // group).
Causal masking skips fully-masked KV blocks via ``pl.when`` (halves the
work, the same effect as a CUDA early-exit).

Forward-only: serving/prefill path.  Training uses the einsum path (or this
kernel under ``jax.checkpoint`` with the jnp ref as the backward).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import interpret_default

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *,
                  scale: float, causal: bool, bq: int, bk: int):
    i = pl.program_id(2)
    j = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc[...] = jnp.zeros_like(acc)

    q_start = i * bq
    k_start = j * bk
    # skip KV blocks strictly above the causal diagonal
    live = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(live)
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)              # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)              # (BK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_s[...]
        l_prev = l_s[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_s[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc[...] = acc[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_s[...] = m_new

    @pl.when(j == nk - 1)
    def _final():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def _flash_attention_jit(q: jax.Array, k: jax.Array, v: jax.Array,
                         causal: bool, block_q: int, block_k: int,
                         interpret: bool) -> jax.Array:
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = h // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (q.shape, k.shape, bq, bk)
    scale = d ** -0.5
    grid = (b, h, sq // bq, sk // bk)
    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, Hkv, Sk, D) -> (B, H, Sq, D).

    ``interpret=None`` resolves via ``repro.kernels.interpret_default``.
    """
    if interpret is None:
        interpret = interpret_default()
    return _flash_attention_jit(q, k, v, causal, block_q, block_k,
                                interpret=interpret)
