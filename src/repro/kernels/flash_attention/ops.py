"""jit'd wrapper with a recompute (jnp-oracle) backward for training use.

Interpret mode is resolved per call by ``repro.kernels.interpret_default``
(env-overridable; compiled on real TPU, interpreted elsewhere).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import flash_attention as K
from repro.kernels.flash_attention import ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention(q, k, v, causal: bool = True):
    return K.flash_attention(q, k, v, causal=causal)


def _fwd(q, k, v, causal):
    return flash_attention(q, k, v, causal), (q, k, v)


def _bwd(causal, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.flash_attention(
        q_, k_, v_, causal=causal), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
