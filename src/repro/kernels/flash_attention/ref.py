"""Pure-jnp oracle for flash attention (materialized-scores softmax)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True) -> jax.Array:
    """q: (B, H, Sq, D); k/v: (B, Hkv, Sk, D) -> (B, H, Sq, D)."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    qg = q.reshape(b, hkv, group, sq, d)
    s = jnp.einsum("bkgqd,bktd->bkgqt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        sk = k.shape[2]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,bktd->bkgqd", p, v.astype(jnp.float32))
    return o.reshape(b, h, sq, d).astype(q.dtype)
