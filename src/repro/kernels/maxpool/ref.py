"""Pure-jnp oracle for the fused max-pool kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def maxpool_fused(h: jax.Array):
    v = jnp.max(h, axis=0)
    w = jnp.argmax(h, axis=0).astype(jnp.int32)
    return v, w


def maxpool_winner_bwd(winner: jax.Array, g: jax.Array, n: int):
    workers = jnp.arange(n, dtype=jnp.int32).reshape(
        (n,) + (1,) * winner.ndim)
    return jnp.where(workers == winner[None], g[None], 0).astype(g.dtype)
