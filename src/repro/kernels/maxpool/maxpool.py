"""Fused worker-max-pool Pallas TPU kernel (the FedOCS aggregation hot-spot).

Computes, in one VMEM pass over a (N, BM, BK) tile:
  * the pooled feature  v = max_n h[n]                      (paper Eq. 4)
  * the winner index    w = argmax_n h[n] (first winner)    (paper Eq. 6)

so the backward winner-mask needs no second read of ``h`` from HBM.  The
worker axis N (<= TP degree, 16 here) always fits entirely in the tile: the
reduction is over the *leading* axis, so the MXU-aligned (BM, BK) lane/sublane
layout of the payload is preserved — no transposes.

Tiling: grid over (M / BM, K / BK); default BM=256, BK=256 keeps the working
set at N*BM*BK*2B = 2 MiB (bf16, N=16) + outputs, comfortably inside the
~16 MiB VMEM budget while giving full 128-lane vectors.

Validated against ``ref.py`` in interpret mode over a shape/dtype sweep
(tests/test_kernels_maxpool.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import fit_block, interpret_default


def _maxpool_kernel(h_ref, v_ref, w_ref):
    h = h_ref[...]                                   # (N, BM, BK)
    v = jnp.max(h, axis=0)
    w = jnp.argmax(h, axis=0).astype(jnp.int32)      # first max wins
    v_ref[...] = v
    w_ref[...] = w


@functools.partial(jax.jit, static_argnames=("block_m", "block_k",
                                             "interpret"))
def _maxpool_fused_jit(h: jax.Array, block_m: int, block_k: int,
                       interpret: bool):
    n, m, k = h.shape
    bm = fit_block(m, block_m)
    bk = fit_block(k, block_k)
    grid = (m // bm, k // bk)
    return pl.pallas_call(
        _maxpool_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, bm, bk), lambda i, j: (0, i, j))],
        out_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
                   pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((m, k), h.dtype),
                   jax.ShapeDtypeStruct((m, k), jnp.int32)],
        interpret=interpret,
    )(h)


def maxpool_fused(h: jax.Array, block_m: int = 256, block_k: int = 256,
                  interpret: bool | None = None):
    """h: (N, M, K) -> (v (M, K), winner (M, K) int32).

    ``interpret=None`` resolves via ``repro.kernels.interpret_default`` —
    compiled on real TPU, interpreted elsewhere — so parity tests exercise
    whatever the host would actually run.
    """
    if interpret is None:
        interpret = interpret_default()
    return _maxpool_fused_jit(h, block_m, block_k, interpret=interpret)


def _maxpool_bwd_kernel(w_ref, g_ref, out_ref):
    w = w_ref[...]                                   # (BM, BK) int32
    g = g_ref[...]                                   # (BM, BK)
    n = out_ref.shape[0]
    # one-hot scatter of the cotangent to the winning worker rows
    workers = jax.lax.broadcasted_iota(jnp.int32, (n,) + w.shape, 0)
    out_ref[...] = jnp.where(workers == w[None], g[None], 0).astype(
        out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n", "block_m", "block_k",
                                             "interpret"))
def _maxpool_winner_bwd_jit(winner: jax.Array, g: jax.Array, n: int,
                            block_m: int, block_k: int, interpret: bool):
    m, k = winner.shape
    bm = fit_block(m, block_m)
    bk = fit_block(k, block_k)
    grid = (m // bm, k // bk)
    return pl.pallas_call(
        _maxpool_bwd_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
                  pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((n, bm, bk), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m, k), g.dtype),
        interpret=interpret,
    )(winner, g)


def maxpool_winner_bwd(winner: jax.Array, g: jax.Array, n: int,
                       block_m: int = 256, block_k: int = 256,
                       interpret: bool | None = None):
    """(winner (M,K) i32, g (M,K)) -> grad_h (N, M, K), Eq. 6 routing."""
    if interpret is None:
        interpret = interpret_default()
    return _maxpool_winner_bwd_jit(winner, g, n, block_m, block_k,
                                   interpret=interpret)
