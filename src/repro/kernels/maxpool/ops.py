"""jit'd public wrapper: differentiable fused max-pool.

``maxpool(h)`` is a drop-in for ``jnp.max(h, axis=0)`` with the paper's
Eq.-6 single-winner backward, fwd and bwd both running as Pallas kernels.
Interpret mode is resolved per call by ``repro.kernels.interpret_default``
(env-overridable; compiled on real TPU, interpreted elsewhere).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.maxpool import maxpool as K


@functools.lru_cache(maxsize=None)
def _make(n: int):
    @jax.custom_vjp
    def mp(h):
        v, _ = K.maxpool_fused(h)
        return v

    def fwd(h):
        return K.maxpool_fused(h)

    def bwd(w, g):
        return (K.maxpool_winner_bwd(w, g, n),)

    mp.defvjp(fwd, bwd)
    return mp


def maxpool(h: jax.Array) -> jax.Array:
    """h: (N, M, K) -> (M, K), single-winner-routed backward."""
    return _make(h.shape[0])(h)
