"""jit'd public wrapper: differentiable fused max-pool.

``maxpool(h)`` is a drop-in for ``jnp.max(h, axis=0)`` with the paper's
Eq.-6 single-winner backward, fwd and bwd both running as Pallas kernels.
On the CPU dry-run host the kernels execute in interpret mode; flip
``INTERPRET = False`` on real TPU.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.maxpool import maxpool as K

INTERPRET = True   # CPU container: interpret mode; False on real TPU


@functools.lru_cache(maxsize=None)
def _make(n: int):
    @jax.custom_vjp
    def mp(h):
        v, _ = K.maxpool_fused(h, interpret=INTERPRET)
        return v

    def fwd(h):
        v, w = K.maxpool_fused(h, interpret=INTERPRET)
        return v, w

    def bwd(w, g):
        return (K.maxpool_winner_bwd(w, g, n, interpret=INTERPRET),)

    mp.defvjp(fwd, bwd)
    return mp


def maxpool(h: jax.Array) -> jax.Array:
    """h: (N, M, K) -> (M, K), single-winner-routed backward."""
    return _make(h.shape[0])(h)
