"""Pure-jnp oracle: core/quantize.py is the reference implementation."""

from __future__ import annotations

import jax

from repro.core import quantize as qz


def encode(x: jax.Array, bits: int) -> jax.Array:
    return qz.quantize(x, bits)


def decode(c: jax.Array, bits: int, dtype) -> jax.Array:
    return qz.dequantize(c, bits, dtype)
