"""jit'd wrappers for the monotone-code kernels with straight-through grads.

Interpret mode is resolved per call by ``repro.kernels.interpret_default``
(env-overridable; compiled on real TPU, interpreted elsewhere).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.ocs_quant import ocs_quant as K


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def quantize_st(x: jax.Array, bits: int) -> jax.Array:
    """dequantize(encode(x)) with a straight-through gradient."""
    c = K.encode(x, bits)
    return K.decode(c, bits, x.dtype)


def _fwd(x, bits):
    return quantize_st(x, bits), None


def _bwd(bits, _, g):
    return (g,)


quantize_st.defvjp(_fwd, _bwd)


def encode(x, bits):
    return K.encode(x, bits)


def decode(c, bits, dtype):
    return K.decode(c, bits, dtype)
