"""Monotone D-bit code / decode Pallas kernel (paper Eq. 7 on-chip).

Elementwise bit manipulation: IEEE-754 order-embedding (sign-flip trick) and
logical shift to D bits.  On TPU this runs on the VPU at full lane width —
the point of the kernel is fusing code+shift+cast into one VMEM pass so the
quantized max collective's encode/decode adds no HBM round-trip.

Tiling: 2D grid over (M/BM, K/BK), BM=BK=256 (bf16: 128 KiB/tile).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import interpret_default
from repro.kernels import fit_block as _fit

_SIGN = {jnp.dtype(jnp.float32): (jnp.uint32, 0x80000000, 32),
         jnp.dtype(jnp.bfloat16): (jnp.uint16, 0x8000, 16),
         jnp.dtype(jnp.float16): (jnp.uint16, 0x8000, 16)}


def _encode_kernel(x_ref, out_ref, *, bits: int):
    x = x_ref[...]
    utype, sign, width = _SIGN[x.dtype]
    b = jax.lax.bitcast_convert_type(x, utype)
    sign = jnp.array(sign, utype)
    code = jnp.where((b & sign) != 0, ~b, b | sign)
    code = jax.lax.shift_right_logical(code, jnp.array(width - bits, utype))
    out_ref[...] = code.astype(out_ref.dtype)


def _decode_kernel(c_ref, out_ref, *, bits: int):
    utype, sign, width = _SIGN[jnp.dtype(out_ref.dtype)]
    c = c_ref[...].astype(utype)
    full = jax.lax.shift_left(c, jnp.array(width - bits, utype))
    sign = jnp.array(sign, utype)
    b = jnp.where((full & sign) == 0, ~full, full & ~sign)
    out = jax.lax.bitcast_convert_type(b, out_ref.dtype)
    # lowest bucket decodes into negative-NaN bit space -> clamp to -inf
    out_ref[...] = jnp.where(jnp.isnan(out),
                             jnp.array(-jnp.inf, out.dtype), out)


def _code_dtype(bits: int):
    return jnp.uint8 if bits <= 8 else jnp.uint16


@functools.partial(jax.jit, static_argnames=("bits", "block", "interpret"))
def _encode_jit(x: jax.Array, bits: int, block: int,
                interpret: bool) -> jax.Array:
    m, k = x.shape
    bm, bk = _fit(m, block), _fit(k, block)
    return pl.pallas_call(
        functools.partial(_encode_kernel, bits=bits),
        grid=(m // bm, k // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), _code_dtype(bits)),
        interpret=interpret,
    )(x)


def encode(x: jax.Array, bits: int, block: int = 256,
           interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = interpret_default()
    return _encode_jit(x, bits, block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bits", "dtype", "block",
                                             "interpret"))
def _decode_jit(c: jax.Array, bits: int, dtype, block: int,
                interpret: bool) -> jax.Array:
    m, k = c.shape
    bm, bk = _fit(m, block), _fit(k, block)
    return pl.pallas_call(
        functools.partial(_decode_kernel, bits=bits),
        grid=(m // bm, k // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, k), jnp.dtype(dtype)),
        interpret=interpret,
    )(c)


def decode(c: jax.Array, bits: int, dtype, block: int = 256,
           interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = interpret_default()
    return _decode_jit(c, bits, dtype, block, interpret=interpret)
