"""jit'd public wrappers: sensing-draw packing + fused contention dispatch.

``noisy_contention`` is the entry point the protocol core
(``repro.core.ocs.ocs_maxpool_noisy_core(backend="pallas")``) calls: it
pre-draws the carrier-sensing stream with the *identical* per-(round,
sub-slot) Bernoulli calls the reference ``lax.scan`` makes — vmapped into
one batched threefry dispatch instead of ``max_rounds x n_slots`` sequential
ones — packs the draws into uint32 bit-planes, and hands the whole
tournament to the Pallas kernel.  Bit-for-bit parity with the scan backend
is a hard contract (tests/test_kernels_contention.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ocs
from repro.kernels.ocs_contention import ocs_contention as K


def draw_heard_packed(rng: jax.Array, p_keep: jax.Array, n: int, k: int, *,
                      n_slots: int, max_rounds: int) -> jax.Array:
    """Pre-draw the sensing stream, packed along the sub-slot axis.

    Key derivation and draw order replicate the scan backend exactly:
    round r uses ``fold_in(rng, r)``, sub-slot d uses ``fold_in(key_r, d)``,
    and each sub-slot draws an (N, K) block via ``ocs.sensing_heard`` (the
    shared helper, so scalar and per-worker ``p_keep`` behave identically in
    both backends).  Returns (max_rounds, N, K) uint32 where bit
    ``n_slots - 1 - d`` of ``[r, n, k]`` is sub-slot d's draw.
    """
    r_keys = jax.vmap(lambda r: jax.random.fold_in(rng, r))(
        jnp.arange(max_rounds))
    rd_keys = jax.vmap(lambda kr: jax.vmap(
        lambda d: jax.random.fold_in(kr, d))(jnp.arange(n_slots)))(r_keys)
    heard = jax.vmap(jax.vmap(
        lambda key: ocs.sensing_heard(key, p_keep, n, k)))(rd_keys)
    plane = jnp.uint32(1) << (jnp.uint32(n_slots - 1)
                              - jnp.arange(n_slots, dtype=jnp.uint32))
    return jnp.sum(jnp.where(heard, plane[None, :, None, None],
                             jnp.uint32(0)), axis=1, dtype=jnp.uint32)


def contend(word: jax.Array, heard: jax.Array, mask: jax.Array,
            total_bits: jax.Array, *, n_slots: int, max_rounds: int,
            block_k: int = 1024, interpret: bool | None = None):
    """Kernel dispatch + cross-tile reduction of the accounting partials.

    Returns (winner (K,) int32, contending (max_rounds,) int32, collided
    (max_rounds,) int32) — the same contract as ``ref.contend``.
    ``interpret=None`` resolves via ``repro.kernels.interpret_default``.
    """
    winner, cont, coll = K.contend(
        word, heard, mask, total_bits, n_slots=n_slots,
        max_rounds=max_rounds, block_k=block_k, interpret=interpret)
    return winner, jnp.sum(cont, axis=0), jnp.sum(coll, axis=0)


def noisy_contention(word: jax.Array, mask: jax.Array,
                     total_bits: jax.Array, rng: jax.Array,
                     p_keep: jax.Array, *, n_slots: int, max_rounds: int,
                     block_k: int = 1024, interpret: bool | None = None):
    """Draw the sensing stream and run the fused tournament.

    ``p_keep`` is ``ocs.sensing_keep_prob(p_miss, dtype)`` — () or (N, 1).
    """
    n, k = word.shape
    heard = draw_heard_packed(rng, p_keep, n, k, n_slots=n_slots,
                              max_rounds=max_rounds)
    return contend(word, heard, mask, total_bits, n_slots=n_slots,
                   max_rounds=max_rounds, block_k=block_k,
                   interpret=interpret)
