"""Fused noisy-contention Pallas kernel (Alg. 1 + miss detection, on-chip).

The noisy-OCS winner selection used by ``fedocs.maxpool_noisy`` is the curve
runner's dominant step-time cost: a ``lax.scan`` over
``max_rounds x (bits + id_bits)`` sub-slots, each step re-deriving a threefry
sub-key, drawing an (N, K) Bernoulli block, and materializing the alive mask
through HBM.  This kernel runs the entire tournament — every round, every
sub-slot — as one VMEM pass per (N, BK) tile:

  * the *sensing stream* is pre-drawn outside (``ops.draw_heard_packed``
    vmaps the exact per-sub-slot Bernoulli calls the scan makes, so the two
    backends stay bit-for-bit interchangeable) and packed along the sub-slot
    axis into one uint32 **bit-plane word per (round, worker, element)** —
    8-32x less HBM traffic than per-slot boolean blocks, and the in-kernel
    sub-slot loop becomes plain shift/mask arithmetic on registers;
  * the contention itself is a bit-plane reduction over the *leading* worker
    axis: for each sub-slot the transmit set is a shift of the contention
    word, the blocking condition an ``any`` over workers, and the alive mask
    never leaves VMEM;
  * the rounds/slots/collision accounting is emitted as per-tile partial
    sums (unresolved sub-frames at round start, collided sub-frames per
    round) that the wrapper reduces across tiles — integer sums, so the
    accounting is exactly the scan's.

Both loops are unrolled at trace time (``max_rounds <= 4`` and
``n_slots <= 32`` by the 32-bit contention-word guard), which keeps every
memory access statically indexed — no SMEM-resident loop state needed.
``total_bits`` stays a *traced* scalar (a (1, 1) int32 operand) so the
sweep engine's padded scenarios (``max_id_bits > id_bits``) share one
compilation: sub-slots past ``total_bits`` compute but are gated inactive,
exactly like the scan.

Tiling: 1-D grid over K / BK element columns; the worker axis (N <= 64 for
every registered scenario) always fits the tile, so the reduction never
crosses tiles.  Validated bit-for-bit against ``ref.py`` and the scan core
in ``tests/kernel_parity.py`` / ``tests/test_kernels_contention.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import fit_block, interpret_default


def _contention_kernel(word_ref, heard_ref, mask_ref, tb_ref,
                       winner_ref, cont_ref, coll_ref, *,
                       n_slots: int, max_rounds: int):
    word = word_ref[...]                              # (N, BK) uint32
    tb = tb_ref[0, 0]                                 # () int32, traced
    alive = jnp.broadcast_to(mask_ref[...] != 0, word.shape)
    done = jnp.zeros((1, word.shape[1]), dtype=bool)  # resolved sub-frames
    conts, colls = [], []
    one = jnp.uint32(1)
    for r in range(max_rounds):
        heard_r = heard_ref[r]                        # (N, BK) packed planes
        # unresolved sub-frames at round start: these alone bill channel
        # slots (the wrapper multiplies the cross-tile sum by total_bits)
        conts.append(jnp.sum((~done).astype(jnp.int32)))
        for d in range(n_slots):
            active = jnp.int32(d) < tb
            shift = jnp.maximum(tb - 1 - jnp.int32(d), 0).astype(jnp.uint32)
            bit = (word >> shift) & one
            heard = ((heard_r >> jnp.uint32(n_slots - 1 - d)) & one) == one
            tx = alive & (bit == one) & active
            any_tx = jnp.any(tx, axis=0, keepdims=True)
            # a sensing worker quits only if someone transmitted AND it heard
            alive = alive & (tx | ~(any_tx & heard))
        n_surv = jnp.sum(alive.astype(jnp.int32), axis=0, keepdims=True)
        collided = n_surv > 1
        colls.append(jnp.sum(collided.astype(jnp.int32)))
        done = done | ~collided
    # lowest-index capture: first alive worker per element column
    winner_ref[...] = jnp.argmax(alive, axis=0).astype(jnp.int32)[None, :]
    cont_ref[...] = jnp.stack(conts)[None, :]
    coll_ref[...] = jnp.stack(colls)[None, :]


@functools.partial(jax.jit, static_argnames=("n_slots", "max_rounds",
                                             "block_k", "interpret"))
def _contend_jit(word, heard, mask, total_bits, *, n_slots, max_rounds,
                 block_k, interpret):
    n, k = word.shape
    bk = fit_block(k, block_k)
    tiles = k // bk
    winner, cont, coll = pl.pallas_call(
        functools.partial(_contention_kernel, n_slots=n_slots,
                          max_rounds=max_rounds),
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((n, bk), lambda j: (0, j)),
            pl.BlockSpec((max_rounds, n, bk), lambda j: (0, 0, j)),
            pl.BlockSpec((n, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk), lambda j: (0, j)),
            pl.BlockSpec((1, max_rounds), lambda j: (j, 0)),
            pl.BlockSpec((1, max_rounds), lambda j: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, k), jnp.int32),
            jax.ShapeDtypeStruct((tiles, max_rounds), jnp.int32),
            jax.ShapeDtypeStruct((tiles, max_rounds), jnp.int32),
        ],
        interpret=interpret,
    )(word, heard.astype(jnp.uint32),
      mask.astype(jnp.int32).reshape(n, 1),
      jnp.asarray(total_bits, jnp.int32).reshape(1, 1))
    return winner[0], cont, coll


def contend(word: jax.Array, heard: jax.Array, mask: jax.Array,
            total_bits: jax.Array, *, n_slots: int, max_rounds: int,
            block_k: int = 1024, interpret: bool | None = None):
    """Run the full noisy tournament over packed bit-planes.

    Args:
      word:       (N, K) uint32 — [value code | id code] contention words.
      heard:      (max_rounds, N, K) uint32 — sensing draws packed along the
                  sub-slot axis; bit ``n_slots - 1 - d`` of ``heard[r, n, k]``
                  is sub-slot d's draw (see ``ops.draw_heard_packed``).
      mask:       (N,) bool — real (non-padded) workers.
      total_bits: () int32 — live sub-slots ``bits + id_bits``; may be
                  traced.  Sub-slots past it are inert (padded scan bound).
      n_slots:    static sub-slot count per round (``bits + max_id_bits``).
      max_rounds: static re-contention bound.
      interpret:  ``None`` resolves via ``repro.kernels.interpret_default``
                  (compiled on real TPU, interpreted elsewhere).

    Returns:
      winner:    (K,) int32 — surviving worker per element (lowest-index
                 capture among survivors).
      contending: (T, max_rounds) int32 — per-tile unresolved sub-frames at
                 each round start (T = K / block tiles).
      collided:  (T, max_rounds) int32 — per-tile collided sub-frames per
                 round.
    """
    if not (1 <= n_slots <= 32):
        raise ValueError(f"n_slots must be in [1, 32], got {n_slots}")
    if interpret is None:
        interpret = interpret_default()
    return _contend_jit(word, heard, mask, total_bits, n_slots=n_slots,
                        max_rounds=max_rounds, block_k=block_k,
                        interpret=interpret)
