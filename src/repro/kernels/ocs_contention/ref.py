"""Pure-jnp oracle for the fused contention kernel.

Replays the tournament with the same ``lax.scan`` idiom as the protocol core
in ``repro.core.ocs``, but over the kernel's *packed* operands (uint32
bit-plane sensing words), returning globally-reduced accounting so the
parity harness can compare it against the tile-reduced kernel wrapper
(``ops.contend``) bit for bit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def contend(word: jax.Array, heard: jax.Array, mask: jax.Array,
            total_bits: jax.Array, *, n_slots: int, max_rounds: int):
    """Same contract as ``ops.contend``: (winner (K,), contending
    (max_rounds,), collided (max_rounds,)) — counts reduced over all K."""
    n, k = word.shape
    tb = jnp.asarray(total_bits, jnp.int32)
    heard = heard.astype(jnp.uint32)
    one = jnp.uint32(1)

    def round_body(carry, r):
        alive, done = carry
        contending = jnp.sum(~done, dtype=jnp.int32)

        def slot(alive, d):
            active = d < tb
            shift = jnp.maximum(tb - 1 - d, 0).astype(jnp.uint32)
            bit = (word >> shift) & one
            hbit = (heard[r] >> (jnp.uint32(n_slots - 1) - d.astype(
                jnp.uint32))) & one
            tx = alive & (bit == one) & active
            any_tx = jnp.any(tx, axis=0, keepdims=True)
            alive = alive & (tx | ~(any_tx & (hbit == one)))
            return alive, None

        alive, _ = jax.lax.scan(slot, alive, jnp.arange(n_slots))
        collided = jnp.sum(alive, axis=0) > 1
        done = done | ~collided
        return (alive, done), (contending, jnp.sum(collided,
                                                   dtype=jnp.int32))

    alive0 = jnp.broadcast_to(jnp.asarray(mask, bool)[:, None], (n, k))
    done0 = jnp.zeros((k,), dtype=bool)
    (alive, _), (contending, collided) = jax.lax.scan(
        round_body, (alive0, done0), jnp.arange(max_rounds))
    winner = jnp.argmax(alive, axis=0).astype(jnp.int32)
    return winner, contending, collided
