"""Pallas kernel packages (maxpool, ocs_quant, flash_attention,
ocs_contention).  Each package is <name>.py (the kernel) + ops.py (jit'd
differentiable wrapper) + ref.py (pure-jnp oracle the parity suite compares
against, see tests/kernel_parity.py).

Interpret-mode policy: the kernels are written for TPU but every CI container
is CPU-only, so the wrappers run them through the Pallas interpreter there.
Historically each ops.py hardcoded ``INTERPRET = True`` at import time, which
silently interpreted on real TPUs too; :func:`interpret_default` replaces
that with one env-driven resolution shared by all kernel wrappers.
"""

from __future__ import annotations

import os


def fit_block(dim: int, want: int) -> int:
    """Largest divisor of ``dim`` that is <= ``want`` (VMEM tile auto-fit).

    Shared by every kernel package's tiling setup so odd shapes degrade to
    smaller-but-exact tiles instead of requiring padding.
    """
    b = min(want, dim)
    while dim % b != 0:
        b -= 1
    return b


_ENV_VAR = "REPRO_PALLAS_INTERPRET"
_TRUE = ("1", "true", "yes", "on")
_FALSE = ("0", "false", "no", "off")


def interpret_default() -> bool:
    """Should Pallas kernels run in interpreter mode by default?

    Resolution order:
      1. ``REPRO_PALLAS_INTERPRET`` env var (``1/true/yes/on`` or
         ``0/false/no/off``) — explicit operator override, read on every
         resolution (eager calls and each fresh jit trace; a value already
         baked into a cached jit executable persists until retrace);
      2. otherwise: interpret unless JAX is actually running on a TPU
         backend (so real-TPU runs compile the kernels instead of silently
         interpreting, and CPU/GPU hosts keep working out of the box).
    """
    env = os.environ.get(_ENV_VAR)
    if env is not None:
        val = env.strip().lower()
        if val in _TRUE:
            return True
        if val in _FALSE:
            return False
        raise ValueError(
            f"{_ENV_VAR}={env!r}: expected one of {_TRUE + _FALSE}")
    import jax

    return jax.default_backend() != "tpu"
