"""Shared 1-D `shard_map` machinery for batched-lane axes.

Both grid runners shard one leading "lane" axis over the host's local
devices: ``repro.sim.sweep`` shards the *scenario* axis, and
``repro.sim.train_curves`` shards the *p_miss lane* axis of the fused curve
engine.  The mesh construction and the jax-version shims (``jax.shard_map``
vs ``jax.experimental.shard_map``, ``check_vma`` vs ``check_rep``) live here
so every runner gets the identical placement semantics — and the identical
bit-for-bit-vs-vmap property that ``tests/test_sweep.py`` and
``tests/test_train_curves.py`` assert with forced host devices.

Sharding only changes placement, never results: callers pad the lane axis up
to a device-count multiple (:func:`pad_lanes`) and drop the padding rows
after the dispatch.
"""

from __future__ import annotations

import functools

import jax
import numpy as np
from jax.sharding import PartitionSpec as P  # noqa: F401  (re-exported)


@functools.lru_cache(maxsize=None)
def mesh_1d(n_devices: int, axis: str = "s"):
    """1-D device mesh for a lane axis (cached: jit keys on identity)."""
    make_mesh = getattr(jax, "make_mesh", None)
    if make_mesh is not None:
        return make_mesh((n_devices,), (axis,))
    # jax<0.4.35 (pyproject floor is 0.4.30): build the Mesh directly
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:n_devices]), (axis,))


@functools.lru_cache(maxsize=None)
def mesh_2d(n_s: int, n_d: int, axes=("s", "d")):
    """2-D device mesh: ``axes[0]`` lanes x ``axes[1]`` data-parallel ranks
    (cached: jit keys on mesh identity)."""
    make_mesh = getattr(jax, "make_mesh", None)
    if make_mesh is not None:
        return make_mesh((n_s, n_d), tuple(axes))
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:n_s * n_d]).reshape(n_s, n_d),
        tuple(axes))


def _shard_map(fn, mesh, in_specs, out_specs):
    """The jax-version shard_map shim shared by :func:`shard_1d`/:func:`shard_2d`."""
    shard_map = getattr(jax, "shard_map", None)
    kwargs = {}
    if shard_map is None:            # jax<0.6: experimental namespace,
        from jax.experimental.shard_map import shard_map
        kwargs["check_rep"] = False  # replication check kwarg predates
    else:                            # its rename to check_vma
        kwargs["check_vma"] = False
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     **kwargs)


def shard_1d(fn, n_devices: int, in_specs, out_specs, axis: str = "s"):
    """Wrap ``fn`` in shard_map over a 1-D ``axis`` mesh.

    ``in_specs``/``out_specs`` follow the shard_map contract (pytree
    prefixes of the arguments/results); pass ``P(axis)`` for lane-leading
    arguments and ``P()`` for replicated ones.
    """
    return _shard_map(fn, mesh_1d(n_devices, axis), in_specs, out_specs)


def shard_2d(fn, n_s: int, n_d: int, in_specs, out_specs, axes=("s", "d")):
    """Wrap ``fn`` in shard_map over a 2-D (lanes x DP ranks) mesh.

    The DP axis name (``axes[1]``) is visible to collectives inside ``fn``
    (``lax.all_gather``/``lax.psum``), which is how the 2-D curve engine
    all-reduces compressed gradients inside the fused scan.
    """
    return _shard_map(fn, mesh_2d(n_s, n_d, tuple(axes)), in_specs,
                      out_specs)


def dp_mesh_shape(n_devices, n_lanes: int, dp_shards: int):
    """Split ``n_devices`` into (lane-mesh size, DP-mesh size).

    The DP axis is either placed *entirely* on the mesh (``n_d ==
    dp_shards``) or *entirely* vmapped on-device (``n_d == 1``) — never a
    partial block — so the all_gather stacking order is trivially identical
    across topologies and the bit-for-bit parity property holds.  Lanes take
    whatever devices remain.
    """
    if n_devices is None:
        n_devices = jax.local_device_count()
    n_devices = int(n_devices)
    n_d = dp_shards if 1 < dp_shards <= n_devices else 1
    n_s = max(1, min(n_devices // n_d, n_lanes))
    return n_s, n_d


def lane_devices(n_devices, n_lanes: int) -> int:
    """Devices actually used for ``n_lanes`` lanes (``None`` = all local)."""
    if n_devices is None:
        n_devices = jax.local_device_count()
    return max(1, min(int(n_devices), n_lanes))


def pad_lanes(x: np.ndarray, n_devices: int) -> np.ndarray:
    """Pad axis 0 up to a device-count multiple by repeating row 0.

    Padding rows ride along as inert extra lanes (lane computations are
    independent) and are sliced off by the caller after the dispatch.
    """
    pad = (-x.shape[0]) % n_devices
    if not pad:
        return x
    return np.concatenate([x, np.repeat(x[:1], pad, axis=0)], axis=0)
