"""Channel-in-the-loop training curves: accuracy vs channel quality.

This is the paper's actual end-to-end experiment, which the repo previously
validated only in halves: ``repro.sim.sweep`` measured protocol behaviour
while ``repro.train`` trained with ideal pooling.  Here the two meet — the
vertical learner's forward pass fuses embeddings through the *simulated* OCS
channel (``fedocs.maxpool_noisy``: quantized D-bit contention, per-sub-slot
miss detection, lowest-index capture), and short training runs sweep the
``p_miss x bits`` scenario grid into accuracy-vs-p_miss and accuracy-vs-bits
tables (emitted by ``repro.sim.results``).

Compilation contract (mirrors the sweep engine): ``p_miss`` and the sensing
rng are *traced* — the whole miss-probability axis trains as ``vmap`` lanes
of ONE jitted train step per ``bits`` value.  An ideal ``max_q{bits}``
reference run (same init, same data stream, same lane structure) trains
alongside; the ``p_miss=0`` lane must match it bit for bit, which
``benchmarks/bench_curves.py`` and ``tests/test_train_curves.py`` assert.
Compilations are observable via :func:`trace_counts`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fedocs, vertical
from repro.core.vertical import VerticalConfig
from repro.data.vertical_data import PatchTaskConfig, patch_classification
from repro.optim import optimizers, schedules
from repro.train.train_step import make_train_step

# ---------------------------------------------------------------------------
# compilation observability (same contract as repro.sim.sweep)
# ---------------------------------------------------------------------------

_TRACE_COUNTS: Dict[str, int] = {
    "noisy_step": 0, "ideal_step": 0, "noisy_eval": 0, "ideal_eval": 0}


def reset_trace_counts() -> None:
    """Zero the per-engine jit trace counters (used by tests/benchmarks)."""
    for k in _TRACE_COUNTS:
        _TRACE_COUNTS[k] = 0


def trace_counts() -> Dict[str, int]:
    """Times each curve engine has been traced; one full :func:`run_curves`
    costs exactly one ``*_step`` and one ``*_eval`` trace per ``bits``
    value, no matter how many ``p_miss`` lanes the grid has."""
    return dict(_TRACE_COUNTS)


# ---------------------------------------------------------------------------
# configuration + result containers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CurveConfig:
    """One accuracy-vs-channel-quality experiment grid.

    ``p_miss`` lanes are scalars (every worker senses equally) or length-
    ``n_workers`` sequences (heterogeneous near/far users, e.g. from
    ``repro.sim.scenarios.near_far_p_miss``); lanes may mix both — scalars
    broadcast.  ``backend`` picks the noisy-contention engine of the
    channel-in-the-loop forward pass (``"scan"`` or the fused ``"pallas"``
    kernel; bit-for-bit interchangeable).
    """

    bits: Sequence[int] = (8, 16)        # backoff/payload depth axis (static)
    p_miss: Sequence = (0.0, 0.02, 0.05, 0.1)          # traced lane axis
    steps: int = 60
    batch: int = 64
    lr: float = 3e-3
    max_rounds: int = 3                  # noisy re-contention bound
    n_train: int = 2048
    n_val: int = 512
    n_classes: int = 4
    grid: int = 2                        # grid^2 workers (paper §IV-B)
    hw: int = 16                         # image side (patch_dim = (hw/grid)^2)
    sigma: float = 0.5
    encoder_dims: Sequence[int] = (32,)
    embed_dim: int = 16                  # K — transmitted feature width
    head_dims: Sequence[int] = (32,)
    seed: int = 0
    log_every: int = 10
    backend: str = "scan"                # noisy-contention engine

    def __post_init__(self):
        for b in self.bits:
            if b not in (8, 16):
                raise ValueError(
                    f"bits={b}: the ideal reference run needs a max_q{{bits}} "
                    "aggregation mode (8 or 16)")
        if not self.p_miss:
            raise ValueError("p_miss needs at least one lane")
        for p in self.p_miss:
            arr = np.asarray(p, np.float64)
            if arr.ndim not in (0, 1):
                raise ValueError(f"p_miss lane must be scalar or "
                                 f"per-worker, got shape {arr.shape}")
            if arr.ndim == 1 and arr.shape[0] != self.n_workers:
                raise ValueError(
                    f"per-worker p_miss lane needs {self.n_workers} "
                    f"entries, got {arr.shape[0]}")
            if not np.all((0.0 <= arr) & (arr < 1.0)):
                raise ValueError(
                    f"p_miss lanes must be in [0, 1): {self.p_miss}")

    @property
    def n_workers(self) -> int:
        return self.grid * self.grid

    def lane_p_miss(self, dtype=np.float32) -> np.ndarray:
        """Lane axis as an array: (L,) if all lanes are scalar, else the
        per-worker broadcast (L, n_workers)."""
        if all(np.ndim(p) == 0 for p in self.p_miss):
            return np.asarray(self.p_miss, dtype)
        return np.stack([
            np.broadcast_to(np.asarray(p, dtype), (self.n_workers,))
            for p in self.p_miss])


@dataclasses.dataclass
class CurveResult:
    """Stacked outcome of one curve grid.

    Lane axis L == ``len(config.p_miss)``; bits axis follows
    ``config.bits`` order.  ``*_ideal`` rows come from the reference run
    with ideal ``max_q{bits}`` pooling (a single vmap lane — the ideal run
    is deterministic and lane-independent).
    """

    config: CurveConfig
    p_miss: np.ndarray                  # (L,) or (L, N) per-worker lanes
    acc: np.ndarray                     # (n_bits, L) channel-in-the-loop
    nll: np.ndarray                     # (n_bits, L)
    acc_ideal: np.ndarray               # (n_bits,)
    nll_ideal: np.ndarray               # (n_bits,)
    loss_history: np.ndarray            # (n_bits, n_logged, L)
    ideal_loss_history: np.ndarray      # (n_bits, n_logged)
    logged_steps: np.ndarray            # (n_logged,)
    noisy_params: List                  # per-bits lane-stacked trained params
    ideal_params: List                  # per-bits lane-stacked trained params


# ---------------------------------------------------------------------------
# the runner
# ---------------------------------------------------------------------------

def _lane_stack(tree, lanes: int):
    return jax.tree.map(lambda x: jnp.stack([x] * lanes), tree)


def _vertical_config(ccfg: CurveConfig, bits: int, noisy: bool
                     ) -> VerticalConfig:
    patch_dim = (ccfg.hw // ccfg.grid) ** 2
    return VerticalConfig(
        n_workers=ccfg.n_workers, input_dim=patch_dim,
        encoder_dims=tuple(ccfg.encoder_dims), embed_dim=ccfg.embed_dim,
        head_dims=tuple(ccfg.head_dims), output_dim=ccfg.n_classes,
        task="classification",
        aggregation="max_noisy" if noisy else f"max_q{bits}",
        # the OCS winner is the lowest-indexed max-code holder, so the ideal
        # reference must route gradients the same way
        tie_break="first",
        noise_bits=bits, noise_max_rounds=ccfg.max_rounds,
        noise_backend=ccfg.backend)


def run_curves(ccfg: CurveConfig = CurveConfig()) -> CurveResult:
    """Train the p_miss lane axis through the simulated channel, per bits.

    For every ``bits`` value: ONE jitted train step (lane-vmapped over
    traced ``(rng, p_miss)``) trains all miss-probability lanes
    simultaneously from identical inits on an identical batch stream, and
    one ideal ``max_q{bits}`` reference trains beside it.  Evaluation runs
    channel-in-the-loop as well (fresh sensing keys, same ``p_miss`` lanes).
    """
    lanes = len(ccfg.p_miss)
    p_lanes = ccfg.lane_p_miss()                 # (L,) or (L, N)
    p_vec = jnp.asarray(p_lanes)

    task = PatchTaskConfig(n_classes=ccfg.n_classes, grid=ccfg.grid,
                           hw=ccfg.hw, sigma=ccfg.sigma)
    views, labels = patch_classification(task, ccfg.n_train, seed=ccfg.seed)
    v_views, v_labels = patch_classification(task, ccfg.n_val,
                                             seed=ccfg.seed + 1)
    views_j, labels_j = jnp.asarray(views), jnp.asarray(labels)
    vv_j, vl_j = jnp.asarray(v_views), jnp.asarray(v_labels)

    logged = sorted(set(range(0, ccfg.steps, ccfg.log_every))
                    | {ccfg.steps - 1})
    acc = np.zeros((len(ccfg.bits), lanes), np.float64)
    nll = np.zeros_like(acc)
    acc_ideal = np.zeros((len(ccfg.bits),), np.float64)
    nll_ideal = np.zeros_like(acc_ideal)
    hist = np.zeros((len(ccfg.bits), len(logged), lanes), np.float64)
    hist_ideal = np.zeros((len(ccfg.bits), len(logged)), np.float64)
    noisy_params_out, ideal_params_out = [], []

    for bi, bits in enumerate(ccfg.bits):
        vcfg_n = _vertical_config(ccfg, bits, noisy=True)
        vcfg_i = _vertical_config(ccfg, bits, noisy=False)

        def noisy_loss(values, batch, noise, _cfg=vcfg_n):
            bviews, blabels = batch
            return vertical.loss_fn(_cfg, values, bviews, blabels,
                                    noise=noise)

        def ideal_loss(values, batch, _cfg=vcfg_i):
            bviews, blabels = batch
            return vertical.loss_fn(_cfg, values, bviews, blabels)

        warmup = max(1, ccfg.steps // 10)
        opt = optimizers.adamw(
            schedules.linear_warmup_cosine(ccfg.lr, warmup, ccfg.steps),
            weight_decay=0.01)
        step_n = make_train_step(noisy_loss, opt, with_rng=True)
        step_i = make_train_step(ideal_loss, opt)

        def jit_noisy(values, opt_state, batch, noise):
            _TRACE_COUNTS["noisy_step"] += 1
            return jax.vmap(step_n, in_axes=(0, 0, None, 0))(
                values, opt_state, batch, noise)

        def jit_ideal(values, opt_state, batch):
            _TRACE_COUNTS["ideal_step"] += 1
            return jax.vmap(step_i, in_axes=(0, 0, None))(
                values, opt_state, batch)

        def eval_noisy(values, noise, _cfg=vcfg_n):
            _TRACE_COUNTS["noisy_eval"] += 1
            return jax.vmap(
                lambda v, nz: vertical.loss_fn(_cfg, v, vv_j, vl_j,
                                               noise=nz)[1],
                in_axes=(0, 0))(values, noise)

        def eval_ideal(values, _cfg=vcfg_i):
            _TRACE_COUNTS["ideal_eval"] += 1
            return jax.vmap(
                lambda v: vertical.loss_fn(_cfg, v, vv_j, vl_j)[1])(values)

        jit_noisy = jax.jit(jit_noisy)
        jit_ideal = jax.jit(jit_ideal)
        eval_noisy = jax.jit(eval_noisy)
        eval_ideal = jax.jit(eval_ideal)

        # identical init + identical batch stream for noisy lanes and the
        # ideal reference: any divergence is the channel's doing.  The ideal
        # run is deterministic and lane-independent, so a single vmap lane
        # suffices (it keeps the batched program structure at 1/lanes cost).
        params0 = vertical.init(vcfg_n, jax.random.PRNGKey(ccfg.seed))
        vals_n = _lane_stack(params0, lanes)
        vals_i = _lane_stack(params0, 1)
        opt0 = opt.init(params0)
        opt_n = _lane_stack(opt0, lanes)
        opt_i = _lane_stack(opt0, 1)

        base_key = jax.random.PRNGKey(ccfg.seed + 7919 * bits)
        batch_rng = np.random.default_rng(ccfg.seed)
        for step in range(ccfg.steps):
            idx = batch_rng.integers(0, ccfg.n_train, ccfg.batch)
            batch = (views_j[:, idx], labels_j[idx])
            noise = fedocs.ChannelNoise(
                rng=jax.random.split(jax.random.fold_in(base_key, step),
                                     lanes),
                p_miss=p_vec)
            vals_n, opt_n, met_n = jit_noisy(vals_n, opt_n, batch, noise)
            vals_i, opt_i, met_i = jit_ideal(vals_i, opt_i, batch)
            if step in logged:
                li = logged.index(step)
                hist[bi, li] = np.asarray(met_n["loss_mean"])
                hist_ideal[bi, li] = float(np.asarray(met_i["loss_mean"])[0])

        eval_key = jax.random.fold_in(base_key, ccfg.steps)  # unused in train
        eval_noise = fedocs.ChannelNoise(
            rng=jax.random.split(eval_key, lanes), p_miss=p_vec)
        m_n = eval_noisy(vals_n, eval_noise)
        m_i = eval_ideal(vals_i)
        acc[bi] = np.asarray(m_n["acc"])
        nll[bi] = np.asarray(m_n["nll"])
        acc_ideal[bi] = float(np.asarray(m_i["acc"])[0])
        nll_ideal[bi] = float(np.asarray(m_i["nll"])[0])
        noisy_params_out.append(vals_n)
        ideal_params_out.append(vals_i)

    return CurveResult(
        config=ccfg, p_miss=ccfg.lane_p_miss(np.float64),
        acc=acc, nll=nll, acc_ideal=acc_ideal, nll_ideal=nll_ideal,
        loss_history=hist, ideal_loss_history=hist_ideal,
        logged_steps=np.asarray(logged), noisy_params=noisy_params_out,
        ideal_params=ideal_params_out)
