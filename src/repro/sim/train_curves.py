"""Channel-in-the-loop training curves: accuracy vs channel quality.

This is the paper's actual end-to-end experiment, which the repo previously
validated only in halves: ``repro.sim.sweep`` measured protocol behaviour
while ``repro.train`` trained with ideal pooling.  Here the two meet — the
vertical learner's forward pass fuses embeddings through the *simulated* OCS
channel (``fedocs.maxpool_noisy``: quantized D-bit contention, per-sub-slot
miss detection, lowest-index capture), and short training runs sweep the
``p_miss x bits`` scenario grid into accuracy-vs-p_miss and accuracy-vs-bits
tables (emitted by ``repro.sim.results``).

Compilation contract (mirrors the sweep engine): ``p_miss`` and the sensing
rng are *traced* — the whole miss-probability axis trains as ``vmap`` lanes
of ONE compiled train step per ``bits`` value.  An ideal ``max_q{bits}``
reference run (same init, same data stream) trains alongside; the
``p_miss=0`` lane must match it bit for bit, which
``benchmarks/bench_curves.py`` and ``tests/test_train_curves.py`` assert.

Two engines drive that compiled step (``CurveConfig.engine``):

``"scan"`` (default)
    The fused on-device engine: the whole ``steps`` loop is one ``lax.scan``
    inside ONE jitted dispatch per ``bits`` value.  Batch indices are drawn
    on device from a threaded PRNG key, the noisy lanes, the ideal reference
    and the final channel-in-the-loop evaluation all run in that single
    dispatch, and the logged losses accumulate into an on-device
    ``(lanes, n_logged)`` buffer fetched once at the end — no per-step
    dispatch or host sync.  On multi-device hosts the ``p_miss`` lane axis
    is sharded over a 1-D mesh via ``repro.sim.shard`` (the same machinery
    as ``run_sweep``'s scenario sharding; vmap fallback on one device,
    bit-for-bit identical either way).  The scan carries the train state on
    device, so params/opt-state never cross the host boundary mid-run.

``"python"``
    The legacy per-step driver (2 jitted dispatches per step from a Python
    loop, train-state carries donated across dispatches).  Kept for one
    release so scan-vs-python bit-for-bit parity is assertable; the batch
    and noise streams are defined by the same key-derivation formulas, so
    both engines train the exact same trajectory.

Compilations are observable via :func:`trace_counts`, host dispatches via
:func:`dispatch_counts` — the scan engine costs ONE dispatch per ``bits``
value where the python engine costs ``2*steps + 2``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import fedocs, vertical
from repro.core.vertical import VerticalConfig
from repro.data.vertical_data import PatchTaskConfig, patch_classification
from repro.optim import optimizers, schedules
from repro.sim import shard as sim_shard
from repro.train.train_step import make_train_step

ENGINES = ("scan", "python")

# ---------------------------------------------------------------------------
# compilation + dispatch observability (same contract as repro.sim.sweep)
# ---------------------------------------------------------------------------

_COUNTER_KEYS = ("fused", "noisy_step", "ideal_step", "noisy_eval",
                 "ideal_eval")
_TRACE_COUNTS: Dict[str, int] = {k: 0 for k in _COUNTER_KEYS}
_DISPATCH_COUNTS: Dict[str, int] = {k: 0 for k in _COUNTER_KEYS}


def reset_trace_counts() -> None:
    """Zero the per-engine jit trace counters (used by tests/benchmarks)."""
    for k in _TRACE_COUNTS:
        _TRACE_COUNTS[k] = 0


def trace_counts() -> Dict[str, int]:
    """Times each curve engine has been traced.  One :func:`run_curves`
    costs exactly one ``fused`` trace per ``bits`` value on the scan engine
    (one ``*_step`` + one ``*_eval`` on the python engine), no matter how
    many ``p_miss`` lanes the grid has."""
    return dict(_TRACE_COUNTS)


def reset_dispatch_counts() -> None:
    """Zero the per-engine host-dispatch counters."""
    for k in _DISPATCH_COUNTS:
        _DISPATCH_COUNTS[k] = 0


def dispatch_counts() -> Dict[str, int]:
    """Jitted-engine dispatches issued from the host by each curve driver.

    The scan engine issues ONE ``fused`` dispatch per ``bits`` value (train
    loop + ideal reference + eval, all on device); the python engine issues
    one ``noisy_step`` + one ``ideal_step`` per training step plus one
    ``*_eval`` each per ``bits`` value (the small eager index/key ops it
    also issues per step are not counted — this tracks the engine's own
    call structure, it is not a profiler).  ``benchmarks/bench_curves.py``
    asserts the ratio and the scan engine's
    ``<= ceil(steps/log_every) + 2`` per-bits bound, guarding the fused
    call structure against falling back to per-step driving.
    """
    return dict(_DISPATCH_COUNTS)


# ---------------------------------------------------------------------------
# configuration + result containers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CurveConfig:
    """One accuracy-vs-channel-quality experiment grid.

    ``p_miss`` lanes are scalars (every worker senses equally) or length-
    ``n_workers`` sequences (heterogeneous near/far users, e.g. from
    ``repro.sim.scenarios.near_far_p_miss``); lanes may mix both — scalars
    broadcast.  ``backend`` picks the noisy-contention engine of the
    channel-in-the-loop forward pass (``"scan"`` or the fused ``"pallas"``
    kernel; bit-for-bit interchangeable).  ``engine`` picks the driver:
    the fused on-device ``"scan"`` engine (default) or the legacy per-step
    ``"python"`` loop — bit-for-bit interchangeable as well.
    """

    bits: Sequence[int] = (8, 16)        # backoff/payload depth axis (static)
    p_miss: Sequence = (0.0, 0.02, 0.05, 0.1)          # traced lane axis
    steps: int = 60
    batch: int = 64
    lr: float = 3e-3
    max_rounds: int = 3                  # noisy re-contention bound
    n_train: int = 2048
    n_val: int = 512
    n_classes: int = 4
    grid: int = 2                        # grid^2 workers (paper §IV-B)
    hw: int = 16                         # image side (patch_dim = (hw/grid)^2)
    sigma: float = 0.5
    encoder_dims: Sequence[int] = (32,)
    embed_dim: int = 16                  # K — transmitted feature width
    head_dims: Sequence[int] = (32,)
    seed: int = 0
    log_every: int = 10
    backend: str = "scan"                # noisy-contention engine
    engine: str = "scan"                 # curve driver: "scan" | "python"

    def __post_init__(self):
        for b in self.bits:
            if b not in (8, 16):
                raise ValueError(
                    f"bits={b}: the ideal reference run needs a max_q{{bits}} "
                    "aggregation mode (8 or 16)")
        if self.engine not in ENGINES:
            raise ValueError(
                f"engine={self.engine!r}: valid engines are {ENGINES}")
        if not self.p_miss:
            raise ValueError("p_miss needs at least one lane")
        for p in self.p_miss:
            arr = np.asarray(p, np.float64)
            if arr.ndim not in (0, 1):
                raise ValueError(f"p_miss lane must be scalar or "
                                 f"per-worker, got shape {arr.shape}")
            if arr.ndim == 1 and arr.shape[0] != self.n_workers:
                raise ValueError(
                    f"per-worker p_miss lane needs {self.n_workers} "
                    f"entries, got {arr.shape[0]}")
            if not np.all((0.0 <= arr) & (arr < 1.0)):
                raise ValueError(
                    f"p_miss lanes must be in [0, 1): {self.p_miss}")

    @property
    def n_workers(self) -> int:
        return self.grid * self.grid

    def lane_p_miss(self, dtype=np.float32) -> np.ndarray:
        """Lane axis as an array: (L,) if all lanes are scalar, else the
        per-worker broadcast (L, n_workers)."""
        if all(np.ndim(p) == 0 for p in self.p_miss):
            return np.asarray(self.p_miss, dtype)
        return np.stack([
            np.broadcast_to(np.asarray(p, dtype), (self.n_workers,))
            for p in self.p_miss])

    def logged_steps(self) -> List[int]:
        """Steps whose train loss lands in ``CurveResult.loss_history``."""
        return sorted(set(range(0, self.steps, self.log_every))
                      | {self.steps - 1})


@dataclasses.dataclass
class CurveResult:
    """Stacked outcome of one curve grid.

    Lane axis L == ``len(config.p_miss)``; bits axis follows
    ``config.bits`` order.  ``*_ideal`` rows come from the reference run
    with ideal ``max_q{bits}`` pooling (a single vmap lane — the ideal run
    is deterministic and lane-independent).  ``p_miss`` is the float32 lane
    array the engines trace (``config.lane_p_miss()``), so the reported
    operating points are exactly the compiled ones.
    """

    config: CurveConfig
    p_miss: np.ndarray                  # (L,) or (L, N) per-worker lanes
    acc: np.ndarray                     # (n_bits, L) channel-in-the-loop
    nll: np.ndarray                     # (n_bits, L)
    acc_ideal: np.ndarray               # (n_bits,)
    nll_ideal: np.ndarray               # (n_bits,)
    loss_history: np.ndarray            # (n_bits, n_logged, L)
    ideal_loss_history: np.ndarray      # (n_bits, n_logged)
    logged_steps: np.ndarray            # (n_logged,)
    noisy_params: List                  # per-bits lane-stacked trained params
    ideal_params: List                  # per-bits lane-stacked trained params


# ---------------------------------------------------------------------------
# shared engine pieces: data/key streams, losses, per-bits train steps
# ---------------------------------------------------------------------------

def _lane_stack(tree, lanes: int):
    """Add a leading lane axis without materializing per-lane host copies."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (lanes,) + jnp.shape(x)), tree)


def _vertical_config(ccfg: CurveConfig, bits: int, noisy: bool
                     ) -> VerticalConfig:
    patch_dim = (ccfg.hw // ccfg.grid) ** 2
    return VerticalConfig(
        n_workers=ccfg.n_workers, input_dim=patch_dim,
        encoder_dims=tuple(ccfg.encoder_dims), embed_dim=ccfg.embed_dim,
        head_dims=tuple(ccfg.head_dims), output_dim=ccfg.n_classes,
        task="classification",
        aggregation="max_noisy" if noisy else f"max_q{bits}",
        # the OCS winner is the lowest-indexed max-code holder, so the ideal
        # reference must route gradients the same way
        tie_break="first",
        noise_bits=bits, noise_max_rounds=ccfg.max_rounds,
        noise_backend=ccfg.backend)


def _stream_keys(ccfg: CurveConfig, bits: int):
    """Root keys of the (engine-independent) batch and sensing streams.

    Both engines derive every stochastic input from these by the same
    formulas — ``_batch_indices(k_data, step)`` for the shared batch stream,
    ``fold_in(lane_keys[l], step)`` for lane ``l``'s per-step sensing key
    (``step == steps`` is the held-out evaluation key) — so the scan and
    python engines train bit-for-bit identical trajectories.
    """
    base = jax.random.PRNGKey(ccfg.seed + 7919 * bits)
    k_data, k_noise = jax.random.split(base)
    lane_keys = jax.random.split(k_noise, len(ccfg.p_miss))
    return k_data, lane_keys


def _batch_indices(k_data, step, batch: int, n_train: int):
    """On-device minibatch draw: a pure function of (k_data, step)."""
    return jax.random.randint(jax.random.fold_in(k_data, step),
                              (batch,), 0, n_train)


def _fold_lanes(lane_keys, step):
    """Per-lane sensing keys for one step: fold the step into every lane."""
    return jax.vmap(jax.random.fold_in, in_axes=(0, None))(lane_keys, step)


def _make_data(ccfg: CurveConfig):
    task = PatchTaskConfig(n_classes=ccfg.n_classes, grid=ccfg.grid,
                           hw=ccfg.hw, sigma=ccfg.sigma)
    views, labels = patch_classification(task, ccfg.n_train, seed=ccfg.seed)
    v_views, v_labels = patch_classification(task, ccfg.n_val,
                                             seed=ccfg.seed + 1)
    return (jnp.asarray(views), jnp.asarray(labels),
            jnp.asarray(v_views), jnp.asarray(v_labels))


def _make_steps(ccfg: CurveConfig, bits: int):
    """Per-bits vertical configs, optimizer, and train-step closures."""
    vcfg_n = _vertical_config(ccfg, bits, noisy=True)
    vcfg_i = _vertical_config(ccfg, bits, noisy=False)

    def noisy_loss(values, batch, noise, _cfg=vcfg_n):
        bviews, blabels = batch
        return vertical.loss_fn(_cfg, values, bviews, blabels, noise=noise)

    def ideal_loss(values, batch, _cfg=vcfg_i):
        bviews, blabels = batch
        return vertical.loss_fn(_cfg, values, bviews, blabels)

    warmup = max(1, ccfg.steps // 10)
    opt = optimizers.adamw(
        schedules.linear_warmup_cosine(ccfg.lr, warmup, ccfg.steps),
        weight_decay=0.01)
    step_n = make_train_step(noisy_loss, opt, with_rng=True)
    step_i = make_train_step(ideal_loss, opt)
    return vcfg_n, vcfg_i, opt, step_n, step_i


def _log_slots(ccfg: CurveConfig, logged: List[int]) -> np.ndarray:
    """(steps,) map step -> loss_history slot; unlogged steps point one past
    the buffer and are dropped by the scatter's ``mode="drop"``."""
    slots = np.full((ccfg.steps,), len(logged), np.int32)
    for i, s in enumerate(logged):
        slots[s] = i
    return slots


# ---------------------------------------------------------------------------
# the fused on-device engine: the whole curve run is one dispatch per bits
# ---------------------------------------------------------------------------

def _make_fused(ccfg: CurveConfig, per_bits, n_logged: int, n_dev: int):
    """Build the jitted fused engine for one ``bits`` value.

    ``per_bits`` is that value's ``_make_steps`` tuple (shared with the
    caller, which needs its optimizer for the init).  One dispatch runs:
    the ``lax.scan`` over all training steps (noisy lanes vmapped over
    traced ``(rng, p_miss)``, batch indices drawn on device), the
    single-lane ideal reference scan, and both channel-in-the-loop
    evaluations.  Logged losses accumulate in carried on-device buffers
    (scattered by the precomputed step->slot map), so nothing syncs to the
    host until the caller fetches the results.  With ``n_dev > 1`` the lane
    axis runs under ``shard_map`` (lane-leading args sharded, data/keys
    replicated) — bit-for-bit the vmap path, as with ``run_sweep``.
    """
    vcfg_n, vcfg_i, _opt, step_n, step_i = per_bits
    steps, batch, n_train = ccfg.steps, ccfg.batch, ccfg.n_train

    def scan_lanes(step_fn, vals, opts, hist, k_data, views, labels, slots):
        """Shared steps-scan: train ``vals`` lanes, scatter logged losses."""
        def body(carry, x):
            vals, opts, hist = carry
            step, slot = x
            idx = _batch_indices(k_data, step, batch, n_train)
            b = (views[:, idx], labels[idx])
            vals, opts, met = step_fn(vals, opts, b, step)
            hist = hist.at[:, slot].set(met["loss_mean"], mode="drop")
            return (vals, opts, hist), None

        (vals, opts, hist), _ = jax.lax.scan(
            body, (vals, opts, hist),
            (jnp.arange(steps, dtype=jnp.int32), slots))
        return vals, opts, hist

    def noisy_lanes(params0, opt0, lane_keys, p, k_data, views, labels,
                    vviews, vlabels, slots):
        lanes = lane_keys.shape[0]          # shard-local lane count
        vals, opts = _lane_stack(params0, lanes), _lane_stack(opt0, lanes)
        hist = jnp.zeros((lanes, n_logged), jnp.float32)

        def step_fn(vals, opts, b, step):
            noise = fedocs.ChannelNoise(rng=_fold_lanes(lane_keys, step),
                                        p_miss=p)
            return jax.vmap(step_n, in_axes=(0, 0, None, 0))(
                vals, opts, b, noise)

        vals, _opts, hist = scan_lanes(step_fn, vals, opts, hist,
                                       k_data, views, labels, slots)
        eval_noise = fedocs.ChannelNoise(rng=_fold_lanes(lane_keys, steps),
                                         p_miss=p)
        met = jax.vmap(
            lambda v, nz: vertical.loss_fn(vcfg_n, v, vviews, vlabels,
                                           noise=nz)[1],
            in_axes=(0, 0))(vals, eval_noise)
        return vals, hist, met["acc"], met["nll"]

    def ideal_lanes(params0, opt0, k_data, views, labels, vviews, vlabels,
                    slots):
        vals, opts = _lane_stack(params0, 1), _lane_stack(opt0, 1)
        hist = jnp.zeros((1, n_logged), jnp.float32)

        def step_fn(vals, opts, b, step):
            return jax.vmap(step_i, in_axes=(0, 0, None))(vals, opts, b)

        vals, _opts, hist = scan_lanes(step_fn, vals, opts, hist,
                                       k_data, views, labels, slots)
        met = jax.vmap(
            lambda v: vertical.loss_fn(vcfg_i, v, vviews, vlabels)[1])(vals)
        return vals, hist, met["acc"], met["nll"]

    noisy_engine = noisy_lanes
    if n_dev > 1:
        noisy_engine = sim_shard.shard_1d(
            noisy_lanes, n_dev,
            in_specs=(P(), P(), P("s"), P("s"), P(), P(), P(), P(), P(),
                      P()),
            out_specs=(P("s"), P("s"), P("s"), P("s")))

    def fused(params0, opt0, lane_keys, p, k_data, views, labels, vviews,
              vlabels, slots):
        _TRACE_COUNTS["fused"] += 1
        n_out = noisy_engine(params0, opt0, lane_keys, p, k_data, views,
                             labels, vviews, vlabels, slots)
        i_out = ideal_lanes(params0, opt0, k_data, views, labels, vviews,
                            vlabels, slots)
        return n_out, i_out

    return jax.jit(fused)


def _run_curves_scan(ccfg: CurveConfig, n_devices) -> CurveResult:
    lanes = len(ccfg.p_miss)
    p_lanes = ccfg.lane_p_miss()                 # float32 (L,) or (L, N)
    n_dev = sim_shard.lane_devices(n_devices, lanes)
    p_pad = jnp.asarray(sim_shard.pad_lanes(p_lanes, n_dev))

    views_j, labels_j, vv_j, vl_j = _make_data(ccfg)
    logged = ccfg.logged_steps()
    slots = jnp.asarray(_log_slots(ccfg, logged))

    acc = np.zeros((len(ccfg.bits), lanes), np.float64)
    nll = np.zeros_like(acc)
    acc_ideal = np.zeros((len(ccfg.bits),), np.float64)
    nll_ideal = np.zeros_like(acc_ideal)
    hist = np.zeros((len(ccfg.bits), len(logged), lanes), np.float64)
    hist_ideal = np.zeros((len(ccfg.bits), len(logged)), np.float64)
    noisy_params_out, ideal_params_out = [], []

    for bi, bits in enumerate(ccfg.bits):
        per_bits = _make_steps(ccfg, bits)
        vcfg_n, opt = per_bits[0], per_bits[2]
        k_data, lane_keys = _stream_keys(ccfg, bits)
        keys_pad = jnp.asarray(
            sim_shard.pad_lanes(np.asarray(lane_keys), n_dev))

        # identical init + identical batch stream for noisy lanes and the
        # ideal reference: any divergence is the channel's doing
        params0 = vertical.init(vcfg_n, jax.random.PRNGKey(ccfg.seed))
        opt0 = opt.init(params0)

        fused = _make_fused(ccfg, per_bits, len(logged), n_dev)
        _DISPATCH_COUNTS["fused"] += 1
        n_out, i_out = fused(params0, opt0, keys_pad, p_pad, k_data,
                             views_j, labels_j, vv_j, vl_j, slots)
        vals_n, hist_n, acc_n, nll_n = n_out
        vals_i, hist_i, acc_i, nll_i = i_out

        # results come back to the host only here, after the single fused
        # dispatch — no per-step sync anywhere above
        acc[bi] = np.asarray(acc_n)[:lanes]
        nll[bi] = np.asarray(nll_n)[:lanes]
        acc_ideal[bi] = float(np.asarray(acc_i)[0])
        nll_ideal[bi] = float(np.asarray(nll_i)[0])
        hist[bi] = np.asarray(hist_n)[:lanes].T
        hist_ideal[bi] = np.asarray(hist_i)[0]
        noisy_params_out.append(
            jax.tree.map(lambda x: x[:lanes], vals_n))
        ideal_params_out.append(vals_i)

    return CurveResult(
        config=ccfg, p_miss=ccfg.lane_p_miss(),
        acc=acc, nll=nll, acc_ideal=acc_ideal, nll_ideal=nll_ideal,
        loss_history=hist, ideal_loss_history=hist_ideal,
        logged_steps=np.asarray(logged), noisy_params=noisy_params_out,
        ideal_params=ideal_params_out)


# ---------------------------------------------------------------------------
# the legacy per-step python engine (kept one release for parity assertions)
# ---------------------------------------------------------------------------

def _run_curves_python(ccfg: CurveConfig) -> CurveResult:
    lanes = len(ccfg.p_miss)
    p_vec = jnp.asarray(ccfg.lane_p_miss())      # (L,) or (L, N)

    views_j, labels_j, vv_j, vl_j = _make_data(ccfg)
    logged = ccfg.logged_steps()
    slot_of = {step: i for i, step in enumerate(logged)}

    acc = np.zeros((len(ccfg.bits), lanes), np.float64)
    nll = np.zeros_like(acc)
    acc_ideal = np.zeros((len(ccfg.bits),), np.float64)
    nll_ideal = np.zeros_like(acc_ideal)
    hist = np.zeros((len(ccfg.bits), len(logged), lanes), np.float64)
    hist_ideal = np.zeros((len(ccfg.bits), len(logged)), np.float64)
    noisy_params_out, ideal_params_out = [], []

    for bi, bits in enumerate(ccfg.bits):
        vcfg_n, vcfg_i, opt, step_n, step_i = _make_steps(ccfg, bits)

        def jit_noisy(values, opt_state, batch, noise):
            _TRACE_COUNTS["noisy_step"] += 1
            return jax.vmap(step_n, in_axes=(0, 0, None, 0))(
                values, opt_state, batch, noise)

        def jit_ideal(values, opt_state, batch):
            _TRACE_COUNTS["ideal_step"] += 1
            return jax.vmap(step_i, in_axes=(0, 0, None))(
                values, opt_state, batch)

        def eval_noisy(values, noise, _cfg=vcfg_n):
            _TRACE_COUNTS["noisy_eval"] += 1
            return jax.vmap(
                lambda v, nz: vertical.loss_fn(_cfg, v, vv_j, vl_j,
                                               noise=nz)[1],
                in_axes=(0, 0))(values, noise)

        def eval_ideal(values, _cfg=vcfg_i):
            _TRACE_COUNTS["ideal_eval"] += 1
            return jax.vmap(
                lambda v: vertical.loss_fn(_cfg, v, vv_j, vl_j)[1])(values)

        # the train-state carries are donated: params/opt-state update in
        # place across the per-step dispatches instead of double-buffering
        jit_noisy = jax.jit(jit_noisy, donate_argnums=(0, 1))
        jit_ideal = jax.jit(jit_ideal, donate_argnums=(0, 1))
        eval_noisy = jax.jit(eval_noisy)
        eval_ideal = jax.jit(eval_ideal)

        # identical init + identical batch stream for noisy lanes and the
        # ideal reference: any divergence is the channel's doing.  The ideal
        # run is deterministic and lane-independent, so a single vmap lane
        # suffices (it keeps the batched program structure at 1/lanes cost).
        params0 = vertical.init(vcfg_n, jax.random.PRNGKey(ccfg.seed))
        vals_n = _lane_stack(params0, lanes)
        vals_i = _lane_stack(params0, 1)
        opt0 = opt.init(params0)
        opt_n = _lane_stack(opt0, lanes)
        opt_i = _lane_stack(opt0, 1)

        k_data, lane_keys = _stream_keys(ccfg, bits)
        for step in range(ccfg.steps):
            idx = _batch_indices(k_data, step, ccfg.batch, ccfg.n_train)
            batch = (views_j[:, idx], labels_j[idx])
            noise = fedocs.ChannelNoise(rng=_fold_lanes(lane_keys, step),
                                        p_miss=p_vec)
            _DISPATCH_COUNTS["noisy_step"] += 1
            vals_n, opt_n, met_n = jit_noisy(vals_n, opt_n, batch, noise)
            _DISPATCH_COUNTS["ideal_step"] += 1
            vals_i, opt_i, met_i = jit_ideal(vals_i, opt_i, batch)
            if step in slot_of:
                li = slot_of[step]
                hist[bi, li] = np.asarray(met_n["loss_mean"])
                hist_ideal[bi, li] = float(np.asarray(met_i["loss_mean"])[0])

        eval_noise = fedocs.ChannelNoise(
            rng=_fold_lanes(lane_keys, ccfg.steps), p_miss=p_vec)
        _DISPATCH_COUNTS["noisy_eval"] += 1
        m_n = eval_noisy(vals_n, eval_noise)
        _DISPATCH_COUNTS["ideal_eval"] += 1
        m_i = eval_ideal(vals_i)
        acc[bi] = np.asarray(m_n["acc"])
        nll[bi] = np.asarray(m_n["nll"])
        acc_ideal[bi] = float(np.asarray(m_i["acc"])[0])
        nll_ideal[bi] = float(np.asarray(m_i["nll"])[0])
        noisy_params_out.append(vals_n)
        ideal_params_out.append(vals_i)

    return CurveResult(
        config=ccfg, p_miss=ccfg.lane_p_miss(),
        acc=acc, nll=nll, acc_ideal=acc_ideal, nll_ideal=nll_ideal,
        loss_history=hist, ideal_loss_history=hist_ideal,
        logged_steps=np.asarray(logged), noisy_params=noisy_params_out,
        ideal_params=ideal_params_out)


# ---------------------------------------------------------------------------
# the public runner
# ---------------------------------------------------------------------------

def run_curves(ccfg: CurveConfig = CurveConfig(), *,
               n_devices: Optional[int] = None) -> CurveResult:
    """Train the p_miss lane axis through the simulated channel, per bits.

    For every ``bits`` value: ONE compiled train step (lane-vmapped over
    traced ``(rng, p_miss)``) trains all miss-probability lanes
    simultaneously from identical inits on an identical batch stream, and
    one ideal ``max_q{bits}`` reference trains beside it.  Evaluation runs
    channel-in-the-loop as well (fresh sensing keys, same ``p_miss`` lanes).

    ``ccfg.engine`` picks the driver: the fused on-device ``"scan"`` engine
    (one dispatch per ``bits`` value; default) or the legacy per-step
    ``"python"`` loop — bit-for-bit identical trajectories either way.

    ``n_devices`` (scan engine only) shards the ``p_miss`` lane axis over
    local devices.  ``None`` (the default) uses every local device; ``1``
    forces the single-device vmap path.  Results are identical either way —
    sharding only changes placement (lanes are padded up to a device-count
    multiple and the padding is dropped before results are returned).
    """
    if ccfg.engine == "python":
        if n_devices not in (None, 1):
            raise ValueError(
                "engine='python' is the legacy single-device driver; use "
                "the scan engine for sharded lanes")
        return _run_curves_python(ccfg)
    return _run_curves_scan(ccfg, n_devices)
