"""Channel-in-the-loop training curves: accuracy vs channel quality.

This is the paper's actual end-to-end experiment, which the repo previously
validated only in halves: ``repro.sim.sweep`` measured protocol behaviour
while ``repro.train`` trained with ideal pooling.  Here the two meet — the
vertical learner's forward pass fuses embeddings through the *simulated* OCS
channel (``repro.protocol.Protocol.ocs``: quantized D-bit contention,
per-sub-slot miss detection, lowest-index capture), and short training runs
sweep the ``p_miss x bits`` scenario grid into accuracy-vs-p_miss and
accuracy-vs-bits tables (emitted by ``repro.sim.results``).

Compilation contract (mirrors the sweep engine): the protocol's ``p_miss``
leaf and the sensing rng are *traced* — the whole miss-probability axis
trains as ``vmap`` lanes of ONE compiled train step per ``bits`` value,
each lane carrying its own ``Protocol`` pytree (same static metadata, its
own ``p_miss`` leaf).  An ideal ``Protocol.ideal_max(bits)`` reference run
(same init, same data stream) trains alongside; the ``p_miss=0`` lane must
match it bit for bit, which ``benchmarks/bench_curves.py`` and
``tests/test_train_curves.py`` assert.

The fused on-device engine drives everything: the whole ``steps`` loop is
one ``lax.scan`` inside ONE jitted dispatch per ``bits`` value.  Batch
indices are drawn on device from a threaded PRNG key, the noisy lanes, the
ideal reference and the final channel-in-the-loop evaluation all run in
that single dispatch, and the logged losses accumulate into an on-device
``(lanes, n_logged)`` buffer fetched once at the end — no per-step dispatch
or host sync.  On multi-device hosts the ``p_miss`` lane axis is sharded
over a 1-D mesh via ``repro.sim.shard`` (vmap fallback on one device,
bit-for-bit identical either way).  (The legacy per-step ``engine="python"``
driver was removed after its one-release parity window — the scan engine
had been property-tested bit-for-bit against it since it landed.)

:func:`run_scheduled_curves` additionally threads a
``repro.protocol.BitsSchedule`` through the same fused scan: one compiled
training-step branch per candidate depth, ``lax.switch``-ed per round by
the schedule's pure on-device policy consuming the protocol accounting
(collision/round telemetry) of the previous round — channel-aware backoff
depth scheduling in ONE host dispatch for the whole run.

:func:`run_curves_dp` is the 2-D generalization: p_miss lanes x
data-parallel batch shards, with each rank's top-k-sparsified gradients
(``repro.optim.compressed_allreduce.CompressedAllReduce``, error feedback
carried through the scan) all-reduced over the ``"d"`` axis *inside* the
fused scan and the DP payload bits measured from actual kept-element
counts — the complement of the uplink accounting, reported together by
``repro.sim.results.summarize_dp_curves``.  The DP axis runs on a 2-D mesh
(``repro.sim.shard.mesh_2d``) when devices allow, else on a named vmap
axis, bit-for-bit identical either way.

Compilations are observable via :func:`trace_counts`, host dispatches via
:func:`dispatch_counts` — the fused engine costs ONE dispatch per ``bits``
value (``fused``; ``fused_dp`` for the 2-D engine), a scheduled run ONE
dispatch total (``sched``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import vertical
from repro.core.vertical import VerticalConfig
from repro.data.vertical_data import PatchTaskConfig, patch_classification
from repro.optim import optimizers, schedules
from repro.optim.compressed_allreduce import CompressedAllReduce
from repro.protocol import BitsSchedule, Protocol
from repro.sim import shard as sim_shard
from repro.train.train_step import make_train_step

# ---------------------------------------------------------------------------
# compilation + dispatch observability (same contract as repro.sim.sweep)
# ---------------------------------------------------------------------------

_COUNTER_KEYS = ("fused", "sched", "fused_dp", "fused_faults")
_TRACE_COUNTS: Dict[str, int] = {k: 0 for k in _COUNTER_KEYS}
_DISPATCH_COUNTS: Dict[str, int] = {k: 0 for k in _COUNTER_KEYS}


def reset_trace_counts() -> None:
    """Zero the per-engine jit trace counters (used by tests/benchmarks)."""
    for k in _TRACE_COUNTS:
        _TRACE_COUNTS[k] = 0


def trace_counts() -> Dict[str, int]:
    """Times each curve engine has been traced.  One :func:`run_curves`
    costs exactly one ``fused`` trace per ``bits`` value (one ``sched``
    trace per :func:`run_scheduled_curves`), no matter how many ``p_miss``
    lanes the grid has."""
    return dict(_TRACE_COUNTS)


def reset_dispatch_counts() -> None:
    """Zero the per-engine host-dispatch counters."""
    for k in _DISPATCH_COUNTS:
        _DISPATCH_COUNTS[k] = 0


def dispatch_counts() -> Dict[str, int]:
    """Jitted-engine dispatches issued from the host by each curve driver.

    The fused engine issues ONE ``fused`` dispatch per ``bits`` value
    (train loop + ideal reference + eval, all on device); a scheduled run
    issues ONE ``sched`` dispatch for the whole training run, every
    candidate depth included.  ``benchmarks/bench_curves.py`` asserts the
    ``<= ceil(steps/log_every) + 2`` per-bits bound, guarding the fused
    call structure against falling back to per-step driving.
    """
    return dict(_DISPATCH_COUNTS)


# ---------------------------------------------------------------------------
# configuration + result containers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CurveConfig:
    """One accuracy-vs-channel-quality experiment grid.

    ``p_miss`` lanes are scalars (every worker senses equally) or length-
    ``n_workers`` sequences (heterogeneous near/far users, e.g. from
    ``repro.sim.scenarios.near_far_p_miss``); lanes may mix both — scalars
    broadcast.  ``backend`` picks the noisy-contention engine of the
    channel-in-the-loop forward pass (``"scan"`` or the fused ``"pallas"``
    kernel; bit-for-bit interchangeable) — it becomes the static
    ``Protocol.backend`` of every lane's protocol object.
    """

    bits: Sequence[int] = (8, 16)        # backoff/payload depth axis (static)
    p_miss: Sequence = (0.0, 0.02, 0.05, 0.1)          # traced lane axis
    steps: int = 60
    batch: int = 64
    lr: float = 3e-3
    max_rounds: int = 3                  # noisy re-contention bound
    n_train: int = 2048
    n_val: int = 512
    n_classes: int = 4
    grid: int = 2                        # grid^2 workers (paper §IV-B)
    hw: int = 16                         # image side (patch_dim = (hw/grid)^2)
    sigma: float = 0.5
    encoder_dims: Sequence[int] = (32,)
    embed_dim: int = 16                  # K — transmitted feature width
    head_dims: Sequence[int] = (32,)
    seed: int = 0
    log_every: int = 10
    backend: str = "scan"                # noisy-contention engine
    dp_shards: int = 1                   # data-parallel batch shards
    #   (run_curves_dp: each rank trains batch/dp_shards samples and the
    #   compressed gradients all-reduce inside the fused scan)

    def __post_init__(self):
        if self.dp_shards < 1:
            raise ValueError(f"dp_shards must be >= 1, got {self.dp_shards}")
        if self.batch % self.dp_shards:
            raise ValueError(
                f"batch={self.batch} must divide evenly into "
                f"dp_shards={self.dp_shards} ranks")
        for b in self.bits:
            if b not in (8, 16):
                raise ValueError(
                    f"bits={b}: the ideal reference run needs a "
                    "Protocol.ideal_max(bits) aggregation (8 or 16)")
        if not self.p_miss:
            raise ValueError("p_miss needs at least one lane")
        for p in self.p_miss:
            arr = np.asarray(p, np.float64)
            if arr.ndim not in (0, 1):
                raise ValueError(f"p_miss lane must be scalar or "
                                 f"per-worker, got shape {arr.shape}")
            if arr.ndim == 1 and arr.shape[0] != self.n_workers:
                raise ValueError(
                    f"per-worker p_miss lane needs {self.n_workers} "
                    f"entries, got {arr.shape[0]}")
            if not np.all((0.0 <= arr) & (arr < 1.0)):
                raise ValueError(
                    f"p_miss lanes must be in [0, 1): {self.p_miss}")

    @property
    def n_workers(self) -> int:
        return self.grid * self.grid

    def protocol(self, bits: int) -> Protocol:
        """The (p_miss-unbound) OCS protocol template of one ``bits`` cell."""
        return Protocol.ocs(bits=bits, max_rounds=self.max_rounds,
                            backend=self.backend)

    def lane_p_miss(self, dtype=np.float32) -> np.ndarray:
        """Lane axis as an array: (L,) if all lanes are scalar, else the
        per-worker broadcast (L, n_workers)."""
        if all(np.ndim(p) == 0 for p in self.p_miss):
            return np.asarray(self.p_miss, dtype)
        return np.stack([
            np.broadcast_to(np.asarray(p, dtype), (self.n_workers,))
            for p in self.p_miss])

    def logged_steps(self) -> List[int]:
        """Steps whose train loss lands in ``CurveResult.loss_history``."""
        return sorted(set(range(0, self.steps, self.log_every))
                      | {self.steps - 1})


@dataclasses.dataclass
class CurveResult:
    """Stacked outcome of one curve grid.

    Lane axis L == ``len(config.p_miss)``; bits axis follows
    ``config.bits`` order.  ``*_ideal`` rows come from the reference run
    with ideal ``Protocol.ideal_max(bits)`` pooling (a single vmap lane —
    the ideal run is deterministic and lane-independent).  ``p_miss`` is
    the float32 lane array the engine traces (``config.lane_p_miss()``), so
    the reported operating points are exactly the compiled ones.
    """

    config: CurveConfig
    p_miss: np.ndarray                  # (L,) or (L, N) per-worker lanes
    acc: np.ndarray                     # (n_bits, L) channel-in-the-loop
    nll: np.ndarray                     # (n_bits, L)
    acc_ideal: np.ndarray               # (n_bits,)
    nll_ideal: np.ndarray               # (n_bits,)
    loss_history: np.ndarray            # (n_bits, n_logged, L)
    ideal_loss_history: np.ndarray      # (n_bits, n_logged)
    logged_steps: np.ndarray            # (n_logged,)
    noisy_params: List                  # per-bits lane-stacked trained params
    ideal_params: List                  # per-bits lane-stacked trained params


@dataclasses.dataclass
class ScheduledCurveResult:
    """Outcome of one ``BitsSchedule``-driven curve run.

    The schedule picks one candidate depth per training round from the
    previous round's protocol accounting; ``bits_per_step`` records the
    depth every step actually trained with (``bits_per_step[0]`` is always
    ``schedule.candidates[schedule.init_index]``).  ``collision_frac`` is
    the lane-mean collision fraction at the logged steps — the telemetry
    the policy consumed.
    """

    config: CurveConfig
    schedule: BitsSchedule
    p_miss: np.ndarray                  # (L,) or (L, N)
    acc: np.ndarray                     # (L,) channel-in-the-loop eval
    nll: np.ndarray                     # (L,)
    loss_history: np.ndarray            # (n_logged, L)
    collision_frac: np.ndarray          # (n_logged,)
    bits_per_step: np.ndarray           # (steps,) chosen depth per round
    logged_steps: np.ndarray            # (n_logged,)
    params: object                      # lane-stacked trained params


@dataclasses.dataclass
class FaultCurveResult:
    """Outcome of one fault-injection curve grid (``run_fault_curves``).

    The lane axis L indexes ``fault_lanes`` — one ``repro.faults.FaultModel``
    per lane, all sharing one (static) ``DegradePolicy`` so the whole grid
    compiles once.  Degradation telemetry rides beside accuracy:
    ``stale_age`` is the staleness (frames since the last resolved frame) at
    the logged steps, and the ``*_frames``/``retry_slots`` arrays are whole-
    run totals billed by ``FaultAccounting``.
    """

    config: CurveConfig
    fault_lanes: Sequence               # the FaultModel lanes, as given
    acc: np.ndarray                     # (n_bits, L) channel-in-the-loop
    nll: np.ndarray                     # (n_bits, L)
    loss_history: np.ndarray            # (n_bits, n_logged, L)
    stale_age: np.ndarray               # (n_bits, n_logged, L) int64
    dropped_frames: np.ndarray          # (n_bits, L) int64 run totals
    outage_frames: np.ndarray           # (n_bits, L) int64 run totals
    retry_slots: np.ndarray             # (n_bits, L) int64 run totals
    logged_steps: np.ndarray            # (n_logged,)
    params: List                        # per-bits lane-stacked trained params


@dataclasses.dataclass
class DPCurveResult:
    """Outcome of one 2-D (p_miss lanes x DP shards) compressed-comms run.

    The DP payload numbers are MEASURED inside the fused scan — per step,
    the kept-element counts of every rank's exact-k masks are billed through
    ``CompressedAllReduce.reduce``'s :class:`DPAccounting` and psum'd over
    ranks.  ``dp_payload_bits_step`` / ``dp_dense_bits_step`` are the
    analytic per-step totals (all ranks) the measurement must equal — the
    tie-exact ``topk_mask`` guarantees it, and ``tests/test_dp_curves.py``
    asserts it.
    """

    config: CurveConfig
    compress: CompressedAllReduce
    p_miss: np.ndarray                  # (L,) or (L, N) per-worker lanes
    acc: np.ndarray                     # (n_bits, L) channel-in-the-loop
    nll: np.ndarray                     # (n_bits, L)
    loss_history: np.ndarray            # (n_bits, n_logged, L) rank-mean loss
    dp_payload_bits: np.ndarray         # (n_bits, n_logged, L) measured/step
    dp_payload_bits_total: np.ndarray   # (n_bits, L) int64, whole run
    dp_payload_bits_step: int           # analytic bits/step, all ranks
    dp_dense_bits_step: int             # uncompressed bits/step, all ranks
    logged_steps: np.ndarray            # (n_logged,)
    params: List                        # per-bits lane-stacked trained params


# ---------------------------------------------------------------------------
# shared engine pieces: data/key streams, losses, per-bits train steps
# ---------------------------------------------------------------------------

def _lane_stack(tree, lanes: int):
    """Add a leading lane axis without materializing per-lane host copies."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (lanes,) + jnp.shape(x)), tree)


def _vertical_config(ccfg: CurveConfig, bits: int, noisy: bool
                     ) -> VerticalConfig:
    patch_dim = (ccfg.hw // ccfg.grid) ** 2
    # the OCS winner is the lowest-indexed max-code holder, so the ideal
    # reference must route gradients the same way (tie_break="first")
    proto = (ccfg.protocol(bits) if noisy
             else Protocol.ideal_max(bits, tie_break="first"))
    return VerticalConfig(
        n_workers=ccfg.n_workers, input_dim=patch_dim,
        encoder_dims=tuple(ccfg.encoder_dims), embed_dim=ccfg.embed_dim,
        head_dims=tuple(ccfg.head_dims), output_dim=ccfg.n_classes,
        task="classification", aggregation=proto)


def _stream_keys(ccfg: CurveConfig, bits: int):
    """Root keys of the batch and sensing streams of one ``bits`` cell.

    Every stochastic input derives from these by fixed formulas —
    ``_batch_indices(k_data, step)`` for the shared batch stream,
    ``fold_in(lane_keys[l], step)`` for lane ``l``'s per-step sensing key
    (``step == steps`` is the held-out evaluation key) — so runs are
    reproducible and a scheduled run whose schedule never switches away
    from depth ``bits`` trains bit-for-bit the plain ``run_curves``
    trajectory of that depth.
    """
    base = jax.random.PRNGKey(ccfg.seed + 7919 * bits)
    k_data, k_noise = jax.random.split(base)
    lane_keys = jax.random.split(k_noise, len(ccfg.p_miss))
    return k_data, lane_keys


def _batch_indices(k_data, step, batch: int, n_train: int):
    """On-device minibatch draw: a pure function of (k_data, step)."""
    return jax.random.randint(jax.random.fold_in(k_data, step),
                              (batch,), 0, n_train)


def _fold_lanes(lane_keys, step):
    """Per-lane sensing keys for one step: fold the step into every lane."""
    return jax.vmap(jax.random.fold_in, in_axes=(0, None))(lane_keys, step)


def _make_data(ccfg: CurveConfig):
    task = PatchTaskConfig(n_classes=ccfg.n_classes, grid=ccfg.grid,
                           hw=ccfg.hw, sigma=ccfg.sigma)
    views, labels = patch_classification(task, ccfg.n_train, seed=ccfg.seed)
    v_views, v_labels = patch_classification(task, ccfg.n_val,
                                             seed=ccfg.seed + 1)
    return (jnp.asarray(views), jnp.asarray(labels),
            jnp.asarray(v_views), jnp.asarray(v_labels))


def _make_steps(ccfg: CurveConfig, bits: int):
    """Per-bits vertical configs, optimizer, and train-step closures.

    The noisy loss takes the channel state as ``chan = (rng, protocol)`` —
    the per-lane sensing key plus the lane's ``Protocol`` pytree (its
    ``p_miss`` leaf is the only traced difference between lanes).
    """
    vcfg_n = _vertical_config(ccfg, bits, noisy=True)
    vcfg_i = _vertical_config(ccfg, bits, noisy=False)

    def noisy_loss(values, batch, chan, _cfg=vcfg_n):
        bviews, blabels = batch
        rng, proto = chan
        return vertical.loss_fn(_cfg, values, bviews, blabels, rng=rng,
                                protocol=proto)

    def ideal_loss(values, batch, _cfg=vcfg_i):
        bviews, blabels = batch
        return vertical.loss_fn(_cfg, values, bviews, blabels)

    warmup = max(1, ccfg.steps // 10)
    opt = optimizers.adamw(
        schedules.linear_warmup_cosine(ccfg.lr, warmup, ccfg.steps),
        weight_decay=0.01)
    step_n = make_train_step(noisy_loss, opt, with_rng=True)
    step_i = make_train_step(ideal_loss, opt)
    return vcfg_n, vcfg_i, opt, step_n, step_i


def _log_slots(ccfg: CurveConfig, logged: List[int]) -> np.ndarray:
    """(steps,) map step -> loss_history slot; unlogged steps point one past
    the buffer and are dropped by the scatter's ``mode="drop"``."""
    slots = np.full((ccfg.steps,), len(logged), np.int32)
    for i, s in enumerate(logged):
        slots[s] = i
    return slots


# ---------------------------------------------------------------------------
# the fused on-device engine: the whole curve run is one dispatch per bits
# ---------------------------------------------------------------------------

def _make_fused(ccfg: CurveConfig, per_bits, n_logged: int, n_dev: int):
    """Build the jitted fused engine for one ``bits`` value.

    ``per_bits`` is that value's ``_make_steps`` tuple (shared with the
    caller, which needs its optimizer for the init).  One dispatch runs:
    the ``lax.scan`` over all training steps (noisy lanes vmapped over the
    traced ``(rng, Protocol)`` channel state, batch indices drawn on
    device), the single-lane ideal reference scan, and both
    channel-in-the-loop evaluations.  Logged losses accumulate in carried
    on-device buffers (scattered by the precomputed step->slot map), so
    nothing syncs to the host until the caller fetches the results.  With
    ``n_dev > 1`` the lane axis runs under ``shard_map`` (lane-leading args
    sharded, data/keys replicated) — bit-for-bit the vmap path, as with
    ``run_sweep``.
    """
    vcfg_n, vcfg_i, _opt, step_n, step_i = per_bits
    proto_tmpl = vcfg_n.resolve_protocol()
    steps, batch, n_train = ccfg.steps, ccfg.batch, ccfg.n_train

    def scan_lanes(step_fn, vals, opts, hist, k_data, views, labels, slots):
        """Shared steps-scan: train ``vals`` lanes, scatter logged losses."""
        def body(carry, x):
            vals, opts, hist = carry
            step, slot = x
            idx = _batch_indices(k_data, step, batch, n_train)
            b = (views[:, idx], labels[idx])
            vals, opts, met = step_fn(vals, opts, b, step)
            hist = hist.at[:, slot].set(met["loss_mean"], mode="drop")
            return (vals, opts, hist), None

        (vals, opts, hist), _ = jax.lax.scan(
            body, (vals, opts, hist),
            (jnp.arange(steps, dtype=jnp.int32), slots))
        return vals, opts, hist

    def noisy_lanes(params0, opt0, lane_keys, p, k_data, views, labels,
                    vviews, vlabels, slots):
        lanes = lane_keys.shape[0]          # shard-local lane count
        vals, opts = _lane_stack(params0, lanes), _lane_stack(opt0, lanes)
        hist = jnp.zeros((lanes, n_logged), jnp.float32)

        def step_fn(vals, opts, b, step):
            chan = (_fold_lanes(lane_keys, step), proto_tmpl.with_p_miss(p))
            return jax.vmap(step_n, in_axes=(0, 0, None, (0, 0)))(
                vals, opts, b, chan)

        vals, _opts, hist = scan_lanes(step_fn, vals, opts, hist,
                                       k_data, views, labels, slots)
        eval_chan = (_fold_lanes(lane_keys, steps), proto_tmpl.with_p_miss(p))
        met = jax.vmap(
            lambda v, ch: vertical.loss_fn(vcfg_n, v, vviews, vlabels,
                                           rng=ch[0], protocol=ch[1])[1],
            in_axes=(0, (0, 0)))(vals, eval_chan)
        return vals, hist, met["acc"], met["nll"]

    def ideal_lanes(params0, opt0, k_data, views, labels, vviews, vlabels,
                    slots):
        vals, opts = _lane_stack(params0, 1), _lane_stack(opt0, 1)
        hist = jnp.zeros((1, n_logged), jnp.float32)

        def step_fn(vals, opts, b, step):
            return jax.vmap(step_i, in_axes=(0, 0, None))(vals, opts, b)

        vals, _opts, hist = scan_lanes(step_fn, vals, opts, hist,
                                       k_data, views, labels, slots)
        met = jax.vmap(
            lambda v: vertical.loss_fn(vcfg_i, v, vviews, vlabels)[1])(vals)
        return vals, hist, met["acc"], met["nll"]

    noisy_engine = noisy_lanes
    if n_dev > 1:
        noisy_engine = sim_shard.shard_1d(
            noisy_lanes, n_dev,
            in_specs=(P(), P(), P("s"), P("s"), P(), P(), P(), P(), P(),
                      P()),
            out_specs=(P("s"), P("s"), P("s"), P("s")))

    def fused(params0, opt0, lane_keys, p, k_data, views, labels, vviews,
              vlabels, slots):
        _TRACE_COUNTS["fused"] += 1
        n_out = noisy_engine(params0, opt0, lane_keys, p, k_data, views,
                             labels, vviews, vlabels, slots)
        i_out = ideal_lanes(params0, opt0, k_data, views, labels, vviews,
                            vlabels, slots)
        return n_out, i_out

    return jax.jit(fused)


def _run_curves_scan(ccfg: CurveConfig, n_devices) -> CurveResult:
    lanes = len(ccfg.p_miss)
    p_lanes = ccfg.lane_p_miss()                 # float32 (L,) or (L, N)
    n_dev = sim_shard.lane_devices(n_devices, lanes)
    p_pad = jnp.asarray(sim_shard.pad_lanes(p_lanes, n_dev))

    views_j, labels_j, vv_j, vl_j = _make_data(ccfg)
    logged = ccfg.logged_steps()
    slots = jnp.asarray(_log_slots(ccfg, logged))

    acc = np.zeros((len(ccfg.bits), lanes), np.float64)
    nll = np.zeros_like(acc)
    acc_ideal = np.zeros((len(ccfg.bits),), np.float64)
    nll_ideal = np.zeros_like(acc_ideal)
    hist = np.zeros((len(ccfg.bits), len(logged), lanes), np.float64)
    hist_ideal = np.zeros((len(ccfg.bits), len(logged)), np.float64)
    noisy_params_out, ideal_params_out = [], []

    for bi, bits in enumerate(ccfg.bits):
        per_bits = _make_steps(ccfg, bits)
        vcfg_n, opt = per_bits[0], per_bits[2]
        k_data, lane_keys = _stream_keys(ccfg, bits)
        keys_pad = jnp.asarray(
            sim_shard.pad_lanes(np.asarray(lane_keys), n_dev))

        # identical init + identical batch stream for noisy lanes and the
        # ideal reference: any divergence is the channel's doing
        params0 = vertical.init(vcfg_n, jax.random.PRNGKey(ccfg.seed))
        opt0 = opt.init(params0)

        fused = _make_fused(ccfg, per_bits, len(logged), n_dev)
        _DISPATCH_COUNTS["fused"] += 1
        n_out, i_out = fused(params0, opt0, keys_pad, p_pad, k_data,
                             views_j, labels_j, vv_j, vl_j, slots)
        vals_n, hist_n, acc_n, nll_n = n_out
        vals_i, hist_i, acc_i, nll_i = i_out

        # results come back to the host only here, after the single fused
        # dispatch — no per-step sync anywhere above
        acc[bi] = np.asarray(acc_n)[:lanes]
        nll[bi] = np.asarray(nll_n)[:lanes]
        acc_ideal[bi] = float(np.asarray(acc_i)[0])
        nll_ideal[bi] = float(np.asarray(nll_i)[0])
        hist[bi] = np.asarray(hist_n)[:lanes].T
        hist_ideal[bi] = np.asarray(hist_i)[0]
        noisy_params_out.append(
            jax.tree.map(lambda x: x[:lanes], vals_n))
        ideal_params_out.append(vals_i)

    return CurveResult(
        config=ccfg, p_miss=ccfg.lane_p_miss(),
        acc=acc, nll=nll, acc_ideal=acc_ideal, nll_ideal=nll_ideal,
        loss_history=hist, ideal_loss_history=hist_ideal,
        logged_steps=np.asarray(logged), noisy_params=noisy_params_out,
        ideal_params=ideal_params_out)


# ---------------------------------------------------------------------------
# the public runners
# ---------------------------------------------------------------------------

def run_curves(ccfg: Optional[CurveConfig] = None, *,
               n_devices: Optional[int] = None) -> CurveResult:
    """Train the p_miss lane axis through the simulated channel, per bits.

    ``ccfg=None`` runs the default :class:`CurveConfig` grid.

    For every ``bits`` value: ONE compiled train step (lane-vmapped over
    the traced ``(rng, Protocol)`` channel state) trains all
    miss-probability lanes simultaneously from identical inits on an
    identical batch stream, and one ideal ``Protocol.ideal_max(bits)``
    reference trains beside it.  Evaluation runs channel-in-the-loop as
    well (fresh sensing keys, same ``p_miss`` lanes).  The whole run is
    ONE host dispatch per ``bits`` value.

    ``n_devices`` shards the ``p_miss`` lane axis over local devices.
    ``None`` (the default) uses every local device; ``1`` forces the
    single-device vmap path.  Results are identical either way — sharding
    only changes placement (lanes are padded up to a device-count multiple
    and the padding is dropped before results are returned).
    """
    return _run_curves_scan(ccfg if ccfg is not None else CurveConfig(),
                            n_devices)


# ---------------------------------------------------------------------------
# the fault engine: FaultModel lanes inside the fused scan, one dispatch
# ---------------------------------------------------------------------------

def _fault_stream_keys(ccfg: CurveConfig, bits: int, lanes: int):
    """Same key-derivation formula as :func:`_stream_keys`, lane count from
    the fault grid: with ``lanes == len(ccfg.p_miss)`` the streams are
    bitwise identical, which is what makes an ``FaultModel.iid(p)`` lane
    reproduce the corresponding :func:`run_curves` noisy lane bit for bit
    (property-tested in ``tests/test_faults.py``)."""
    base = jax.random.PRNGKey(ccfg.seed + 7919 * bits)
    k_data, k_noise = jax.random.split(base)
    lane_keys = jax.random.split(k_noise, lanes)
    return k_data, lane_keys


def _make_fault_steps(ccfg: CurveConfig, bits: int):
    """Per-bits config, optimizer and fault-aware train step.

    The channel state is ``chan = (rng, protocol, fault, fault_state)`` —
    the protocol template carries only static contention metadata (its
    ``p_miss``/``online`` leaves stay ``None``; the fault model supersedes
    them), and the evolved ``FaultState`` comes back through the metrics
    (``metrics["fault_state"]``) to be re-carried by the engine's scan.
    """
    vcfg_n = _vertical_config(ccfg, bits, noisy=True)

    def fault_loss(values, batch, chan, _cfg=vcfg_n):
        bviews, blabels = batch
        rng, proto, fm, fs = chan
        return vertical.loss_fn(_cfg, values, bviews, blabels, rng=rng,
                                protocol=proto, fault=fm, fault_state=fs)

    warmup = max(1, ccfg.steps // 10)
    opt = optimizers.adamw(
        schedules.linear_warmup_cosine(ccfg.lr, warmup, ccfg.steps),
        weight_decay=0.01)
    step_f = make_train_step(fault_loss, opt, with_rng=True)
    return vcfg_n, opt, step_f


def _make_fused_faults(ccfg: CurveConfig, per_bits, n_logged: int):
    """Build the jitted fault engine for one ``bits`` value.

    Same one-dispatch shape as :func:`_make_fused`: the whole ``steps``
    loop is one ``lax.scan``, the fault lanes are vmapped over the stacked
    ``FaultModel`` leaves and the carried per-lane ``FaultState`` (Markov
    burst/dropout chains persist across rounds *through the scan carry*),
    and the degradation telemetry accumulates on device beside the loss
    history.  Evaluation runs channel-in-the-loop under the final chain
    state with a fresh eval-shaped stale cache.
    """
    from repro import faults

    vcfg_n, _opt, step_f = per_bits
    proto_tmpl = vcfg_n.resolve_protocol()
    steps, batch, n_train = ccfg.steps, ccfg.batch, ccfg.n_train

    def fault_lanes_fn(params0, opt0, lane_keys, fm, fs0, k_data, views,
                       labels, vviews, vlabels, slots):
        lanes = lane_keys.shape[0]
        vals, opts = _lane_stack(params0, lanes), _lane_stack(opt0, lanes)
        hist = jnp.zeros((lanes, n_logged), jnp.float32)
        stale_hist = jnp.zeros((lanes, n_logged), jnp.int32)
        drop_tot = jnp.zeros((lanes,), jnp.int32)
        outage_tot = jnp.zeros((lanes,), jnp.int32)
        retry_tot = jnp.zeros((lanes,), jnp.int32)

        def body(carry, x):
            (vals, opts, fs, hist, stale_hist, drop_tot, outage_tot,
             retry_tot) = carry
            step, slot = x
            idx = _batch_indices(k_data, step, batch, n_train)
            b = (views[:, idx], labels[idx])
            chan = (_fold_lanes(lane_keys, step), proto_tmpl, fm, fs)
            vals, opts, met = jax.vmap(
                step_f, in_axes=(0, 0, None, (0, None, 0, 0)))(
                    vals, opts, b, chan)
            met = dict(met)
            fs = met.pop("fault_state")
            hist = hist.at[:, slot].set(met["loss_mean"], mode="drop")
            stale_hist = stale_hist.at[:, slot].set(met["fault_stale_age"],
                                                    mode="drop")
            drop_tot = drop_tot + met["fault_dropped_frames"]
            outage_tot = outage_tot + met["fault_outage"]
            retry_tot = retry_tot + met["fault_retry_slots"]
            return (vals, opts, fs, hist, stale_hist, drop_tot, outage_tot,
                    retry_tot), None

        (vals, _opts, fs, hist, stale_hist, drop_tot, outage_tot,
         retry_tot), _ = jax.lax.scan(
            body, (vals, opts, fs0, hist, stale_hist, drop_tot, outage_tot,
                   retry_tot),
            (jnp.arange(steps, dtype=jnp.int32), slots))

        # evaluate under the final chain state (bursts/outages carry over)
        # with a fresh eval-batch-shaped stale cache
        n_val = vviews.shape[1]
        eval_fs = faults.FaultState(
            bad=fs.bad, offline=fs.offline,
            stale=jnp.zeros((lanes, n_val, ccfg.embed_dim), jnp.float32),
            age=jnp.zeros((lanes,), jnp.int32),
            consec=jnp.zeros((lanes,), jnp.int32))
        met = jax.vmap(
            lambda v, r, fm_l, fs_l: vertical.loss_fn(
                vcfg_n, v, vviews, vlabels, rng=r, protocol=proto_tmpl,
                fault=fm_l, fault_state=fs_l)[1],
            in_axes=(0, 0, 0, 0))(
                vals, _fold_lanes(lane_keys, steps), fm, eval_fs)
        return (vals, hist, stale_hist, drop_tot, outage_tot, retry_tot,
                met["acc"], met["nll"])

    def fused(params0, opt0, lane_keys, fm, fs0, k_data, views, labels,
              vviews, vlabels, slots):
        _TRACE_COUNTS["fused_faults"] += 1
        return fault_lanes_fn(params0, opt0, lane_keys, fm, fs0, k_data,
                              views, labels, vviews, vlabels, slots)

    return jax.jit(fused)


def run_fault_curves(ccfg: CurveConfig, fault_lanes: Sequence
                     ) -> FaultCurveResult:
    """Train a grid of channel-fault lanes through the fused engine.

    ``fault_lanes`` is a sequence of ``repro.faults.FaultModel`` values —
    e.g. a burst-length sweep — all sharing one ``DegradePolicy`` (the
    policy is static metadata; mixed policies would need one compile each,
    so they are rejected — run one grid per policy instead).  Every fault
    parameter is a traced leaf: the whole grid trains as vmap lanes of ONE
    compiled dispatch per ``bits`` value (``trace_counts()["fused_faults"]``
    stays at one per bits no matter how many lanes), the same contract as
    :func:`run_curves`.

    Stream derivation matches :func:`run_curves` (see
    :func:`_fault_stream_keys`): with ``len(fault_lanes) ==
    len(ccfg.p_miss)``, an ``FaultModel.iid(p)`` lane trains bit-for-bit
    the ``run_curves`` noisy lane of the same ``p``.  Runs single-device
    (vmap lanes; lane sharding can follow the ``_make_fused`` pattern when
    fault grids outgrow one device).
    """
    from repro import faults

    lanes = len(fault_lanes)
    if lanes == 0:
        raise ValueError("fault_lanes needs at least one FaultModel")
    policies = {fm.policy for fm in fault_lanes}
    if len(policies) != 1:
        raise ValueError(
            f"all fault lanes must share one DegradePolicy (static "
            f"metadata — one compile per policy), got {policies}")
    fm_stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *fault_lanes)

    views_j, labels_j, vv_j, vl_j = _make_data(ccfg)
    logged = ccfg.logged_steps()
    slots = jnp.asarray(_log_slots(ccfg, logged))

    acc = np.zeros((len(ccfg.bits), lanes), np.float64)
    nll = np.zeros_like(acc)
    hist = np.zeros((len(ccfg.bits), len(logged), lanes), np.float64)
    stale = np.zeros((len(ccfg.bits), len(logged), lanes), np.int64)
    dropped = np.zeros((len(ccfg.bits), lanes), np.int64)
    outages = np.zeros_like(dropped)
    retries = np.zeros_like(dropped)
    params_out = []

    for bi, bits in enumerate(ccfg.bits):
        per_bits = _make_fault_steps(ccfg, bits)
        vcfg_n, opt = per_bits[0], per_bits[1]
        k_data, lane_keys = _fault_stream_keys(ccfg, bits, lanes)

        params0 = vertical.init(vcfg_n, jax.random.PRNGKey(ccfg.seed))
        opt0 = opt.init(params0)
        fs0 = _lane_stack(
            faults.init_state(ccfg.n_workers,
                              (ccfg.batch, ccfg.embed_dim)), lanes)

        fused = _make_fused_faults(ccfg, per_bits, len(logged))
        _DISPATCH_COUNTS["fused_faults"] += 1
        (vals, hist_b, stale_b, drop_b, out_b, retry_b, acc_b,
         nll_b) = fused(params0, opt0, jnp.asarray(lane_keys), fm_stacked,
                        fs0, k_data, views_j, labels_j, vv_j, vl_j, slots)

        acc[bi] = np.asarray(acc_b)
        nll[bi] = np.asarray(nll_b)
        hist[bi] = np.asarray(hist_b).T
        stale[bi] = np.asarray(stale_b, np.int64).T
        dropped[bi] = np.asarray(drop_b, np.int64)
        outages[bi] = np.asarray(out_b, np.int64)
        retries[bi] = np.asarray(retry_b, np.int64)
        params_out.append(vals)

    return FaultCurveResult(
        config=ccfg, fault_lanes=tuple(fault_lanes),
        acc=acc, nll=nll, loss_history=hist, stale_age=stale,
        dropped_frames=dropped, outage_frames=outages, retry_slots=retries,
        logged_steps=np.asarray(logged), params=params_out)


# ---------------------------------------------------------------------------
# the scheduled engine: BitsSchedule inside the fused scan, one dispatch
# ---------------------------------------------------------------------------

def _make_sched_fused(ccfg: CurveConfig, schedule: BitsSchedule, per_cand,
                      n_logged: int):
    """Build the jitted scheduled engine (all candidate depths, one jit).

    One training-step branch is compiled per candidate ``bits`` (the depth
    is static inside each branch — it fixes code dtypes and the contention
    scan length) and ``lax.switch`` picks the branch per round from the
    schedule's carried index.  The schedule's ``update`` consumes the
    round's protocol accounting (lane-mean collision fraction / rounds /
    correctness from the train-step metrics) and emits the next round's
    index — policy and training both stay on device.
    """
    steps, batch, n_train = ccfg.steps, ccfg.batch, ccfg.n_train
    cand_bits = jnp.asarray(schedule.candidates, jnp.int32)

    def make_branch(ci):
        vcfg_n, _vi, _opt, step_n, _si = per_cand[ci]
        proto_tmpl = vcfg_n.resolve_protocol()

        def branch(vals, opts, b, rngs, p):
            chan = (rngs, proto_tmpl.with_p_miss(p))
            return jax.vmap(step_n, in_axes=(0, 0, None, (0, 0)))(
                vals, opts, b, chan)
        return branch

    def make_eval_branch(ci, vviews, vlabels):
        vcfg_n = per_cand[ci][0]
        proto_tmpl = vcfg_n.resolve_protocol()

        def branch(vals, rngs, p):
            chan = (rngs, proto_tmpl.with_p_miss(p))
            return jax.vmap(
                lambda v, ch: vertical.loss_fn(vcfg_n, v, vviews, vlabels,
                                               rng=ch[0],
                                               protocol=ch[1])[1],
                in_axes=(0, (0, 0)))(vals, chan)
        return branch

    branches = [make_branch(ci) for ci in range(len(schedule.candidates))]

    def fused(params0, opt0, lane_keys, p, k_data, views, labels, vviews,
              vlabels, slots):
        _TRACE_COUNTS["sched"] += 1
        eval_branches = [make_eval_branch(ci, vviews, vlabels)
                         for ci in range(len(schedule.candidates))]
        lanes = lane_keys.shape[0]
        vals, opts = _lane_stack(params0, lanes), _lane_stack(opt0, lanes)
        hist = jnp.zeros((lanes, n_logged), jnp.float32)
        coll_hist = jnp.zeros((n_logged,), jnp.float32)
        st0 = schedule.init_state()
        idx0 = jnp.int32(schedule.init_index)

        def body(carry, x):
            vals, opts, hist, coll_hist, st, idx = carry
            step, slot = x
            bidx = _batch_indices(k_data, step, batch, n_train)
            b = (views[:, bidx], labels[bidx])
            rngs = _fold_lanes(lane_keys, step)
            vals, opts, met = jax.lax.switch(idx, branches, vals, opts, b,
                                             rngs, p)
            telemetry = {
                "collision_frac": jnp.mean(met["chan_collision_frac"]),
                "rounds": jnp.mean(met["chan_rounds"]),
                "correct_frac": jnp.mean(met["chan_correct_frac"]),
            }
            st, next_idx = schedule.update(st, telemetry)
            hist = hist.at[:, slot].set(met["loss_mean"], mode="drop")
            coll_hist = coll_hist.at[slot].set(
                telemetry["collision_frac"], mode="drop")
            return ((vals, opts, hist, coll_hist, st, next_idx),
                    (cand_bits[idx], idx))

        carry0 = (vals, opts, hist, coll_hist, st0, idx0)
        (vals, _opts, hist, coll_hist, _st, _idx), (bits_seq, idx_seq) = \
            jax.lax.scan(
                body, carry0, (jnp.arange(steps, dtype=jnp.int32), slots))

        # evaluate at the depth the final round actually trained with, so
        # the reported accuracy and bits_per_step[-1] name the same
        # operating point (the post-final-update index is never trained)
        rngs = _fold_lanes(lane_keys, steps)
        met = jax.lax.switch(idx_seq[-1], eval_branches, vals, rngs, p)
        return vals, hist, coll_hist, bits_seq, met["acc"], met["nll"]

    return jax.jit(fused)


def run_scheduled_curves(ccfg: CurveConfig, schedule: BitsSchedule
                         ) -> ScheduledCurveResult:
    """Train the ``p_miss`` lanes with a channel-aware ``BitsSchedule``.

    The backoff depth is re-chosen every round by ``schedule.update`` from
    the previous round's protocol accounting; all candidate depths compile
    into ONE jitted program (one ``lax.switch`` branch each) and the whole
    run — training scan, per-round policy, final channel-in-the-loop
    evaluation — is ONE host dispatch (``dispatch_counts()["sched"]``).

    The stochastic streams derive from
    ``_stream_keys(ccfg, candidates[init_index])``, so a schedule that
    never leaves its initial depth ``b`` (e.g. ``FixedBits(b)``) trains
    bit-for-bit the ``run_curves(bits=(b,))`` noisy lanes (property-tested
    in ``tests/test_protocol.py``).  Runs single-device (vmap lanes).
    """
    lanes = len(ccfg.p_miss)
    p_lanes = jnp.asarray(ccfg.lane_p_miss())

    views_j, labels_j, vv_j, vl_j = _make_data(ccfg)
    logged = ccfg.logged_steps()
    slots = jnp.asarray(_log_slots(ccfg, logged))

    per_cand = [_make_steps(ccfg, b) for b in schedule.candidates]
    init_bits = schedule.candidates[schedule.init_index]
    k_data, lane_keys = _stream_keys(ccfg, init_bits)

    # identical init for every candidate branch: the model is depth-
    # independent (bits only changes the fused forward), so one train state
    # serves the whole switch
    vcfg0, opt = per_cand[0][0], per_cand[0][2]
    params0 = vertical.init(vcfg0, jax.random.PRNGKey(ccfg.seed))
    opt0 = opt.init(params0)

    fused = _make_sched_fused(ccfg, schedule, per_cand, len(logged))
    _DISPATCH_COUNTS["sched"] += 1
    vals, hist, coll_hist, bits_seq, acc, nll = fused(
        params0, opt0, jnp.asarray(lane_keys), p_lanes, k_data, views_j,
        labels_j, vv_j, vl_j, slots)

    return ScheduledCurveResult(
        config=ccfg, schedule=schedule, p_miss=ccfg.lane_p_miss(),
        acc=np.asarray(acc, np.float64)[:lanes],
        nll=np.asarray(nll, np.float64)[:lanes],
        loss_history=np.asarray(hist, np.float64)[:lanes].T,
        collision_frac=np.asarray(coll_hist, np.float64),
        bits_per_step=np.asarray(bits_seq, np.int64),
        logged_steps=np.asarray(logged), params=vals)


# ---------------------------------------------------------------------------
# the 2-D engine: p_miss lanes x data-parallel shards, compressed all-reduce
# ---------------------------------------------------------------------------

def _make_fused_dp(ccfg: CurveConfig, compress: CompressedAllReduce,
                   per_bits, n_logged: int, n_s: int, n_d: int):
    """Build the jitted 2-D engine for one ``bits`` value.

    Every training step, each DP rank draws its slice of the shared batch
    stream, runs the channel-in-the-loop forward on its own sensing key
    (``fold_in(lane_step_key, rank)``), and the sparse gradients all-reduce
    over the ``"d"`` axis via ``compress.reduce`` — all inside the single
    ``lax.scan``/dispatch of the fused-engine contract.  Per-step measured
    payload bits ride the scan carry next to the loss history.

    The ``"d"`` axis is either a mesh axis (``n_d == dp_shards``, gradients
    cross devices) or a ``vmap(axis_name="d")`` axis on one device —
    ``compress.reduce``'s gather+fixed-order-sum makes the two bit-for-bit
    identical (``dp_mesh_shape`` never splits the DP axis between the two).
    Lanes shard over ``"s"`` exactly as in :func:`_make_fused`.
    """
    vcfg_n = per_bits[0]
    opt = per_bits[2]
    proto_tmpl = vcfg_n.resolve_protocol()
    steps, batch, n_train = ccfg.steps, ccfg.batch, ccfg.n_train
    dp_shards = ccfg.dp_shards
    shard_b = batch // dp_shards
    mesh_dp = n_d > 1

    grad_fn = jax.value_and_grad(
        lambda v, bv, bl, rng, p_l: vertical.loss_fn(
            vcfg_n, v, bv, bl, rng=rng,
            protocol=proto_tmpl.with_p_miss(p_l)),
        has_aux=True)

    def dp_lanes(params0, opt0, err0, lane_keys, p, shard_ids, k_data,
                 views, labels, vviews, vlabels, slots):
        lanes = lane_keys.shape[0]          # shard-local lane count
        d_local = shard_ids.shape[0]        # 1 on the mesh path, D vmapped
        vals = _lane_stack(_lane_stack(params0, d_local), lanes)
        opts = _lane_stack(_lane_stack(opt0, d_local), lanes)
        hist = jnp.zeros((lanes, n_logged), jnp.float32)
        pay_hist = jnp.zeros((lanes, n_logged), jnp.int32)
        pay_total = jnp.zeros((lanes,), jnp.int32)

        def rank_step(vals, opts, err, shard_id, rng_lane, p_l, idx):
            """One DP rank of one lane: local grads -> compressed all-reduce
            over "d" -> rank-mean update.  Params/opt stay bitwise identical
            across ranks (same reduced gradient); only ``err`` diverges."""
            rng = jax.random.fold_in(rng_lane, shard_id)
            idx_s = jax.lax.dynamic_slice(idx, (shard_id * shard_b,),
                                          (shard_b,))
            (loss, _met), grads = grad_fn(vals, views[:, idx_s],
                                          labels[idx_s], rng, p_l)
            reduced, err, acct = compress.reduce(grads, err, axis_name="d")
            n_ranks = jax.lax.psum(jnp.int32(1), "d")
            reduced = jax.tree.map(lambda g: g / n_ranks, reduced)
            vals, opts, _stats = opt.update(reduced, opts, vals)
            loss_mean = jnp.mean(jax.lax.all_gather(loss, "d"))
            return vals, opts, err, loss_mean, acct.payload_bits

        if mesh_dp:
            # the mesh carries "d": each device holds one rank (d_local==1);
            # only the lane axis is vmapped — collectives hit the mesh axis
            def step_all(vals, opts, errs, rngs, idx):
                v, o, e = (jax.tree.map(lambda x: x[:, 0], t)
                           for t in (vals, opts, errs))
                v, o, e, lm, pay = jax.vmap(
                    rank_step, in_axes=(0, 0, 0, None, 0, 0, None))(
                        v, o, e, shard_ids[0], rngs, p, idx)
                v, o, e = (jax.tree.map(lambda x: x[:, None], t)
                           for t in (v, o, e))
                return v, o, e, lm, pay
        else:
            # single-device DP: the "d" axis is a named vmap axis — the
            # collectives see the identical (D, ...) stacking order
            ranks = jax.vmap(rank_step, in_axes=(0, 0, 0, 0, None, None,
                                                 None), axis_name="d")

            def step_all(vals, opts, errs, rngs, idx):
                v, o, e, lm, pay = jax.vmap(
                    ranks, in_axes=(0, 0, 0, None, 0, 0, None))(
                        vals, opts, errs, shard_ids, rngs, p, idx)
                # per-rank outputs are rank-invariant (post-psum): take rank 0
                return v, o, e, lm[:, 0], pay[:, 0]

        def body(carry, x):
            vals, opts, errs, hist, pay_hist, pay_total = carry
            step, slot = x
            idx = _batch_indices(k_data, step, batch, n_train)
            rngs = _fold_lanes(lane_keys, step)
            vals, opts, errs, lm, pay = step_all(vals, opts, errs, rngs, idx)
            hist = hist.at[:, slot].set(lm, mode="drop")
            pay_hist = pay_hist.at[:, slot].set(pay, mode="drop")
            pay_total = pay_total + pay
            return (vals, opts, errs, hist, pay_hist, pay_total), None

        carry0 = (vals, opts, err0, hist, pay_hist, pay_total)
        (vals, _opts, _errs, hist, pay_hist, pay_total), _ = jax.lax.scan(
            body, carry0, (jnp.arange(steps, dtype=jnp.int32), slots))

        # rank replicas are bitwise identical: evaluate the local rank's copy
        vals_l = jax.tree.map(lambda x: x[:, 0], vals)
        eval_rngs = _fold_lanes(lane_keys, steps)
        met = jax.vmap(
            lambda v, r, p_l: vertical.loss_fn(
                vcfg_n, v, vviews, vlabels, rng=r,
                protocol=proto_tmpl.with_p_miss(p_l))[1],
            in_axes=(0, 0, 0))(vals_l, eval_rngs, p)
        return vals_l, hist, pay_hist, pay_total, met["acc"], met["nll"]

    dp_engine = dp_lanes
    if n_d > 1:
        dp_engine = sim_shard.shard_2d(
            dp_lanes, n_s, n_d,
            in_specs=(P(), P(), P("s", "d"), P("s"), P("s"), P("d"), P(),
                      P(), P(), P(), P(), P()),
            out_specs=(P("s"),) * 6)
    elif n_s > 1:
        dp_engine = sim_shard.shard_1d(
            dp_lanes, n_s,
            in_specs=(P(), P(), P("s"), P("s"), P("s"), P(), P(), P(), P(),
                      P(), P(), P()),
            out_specs=(P("s"),) * 6)

    def fused(params0, opt0, err0, lane_keys, p, shard_ids, k_data, views,
              labels, vviews, vlabels, slots):
        _TRACE_COUNTS["fused_dp"] += 1
        return dp_engine(params0, opt0, err0, lane_keys, p, shard_ids,
                         k_data, views, labels, vviews, vlabels, slots)

    return jax.jit(fused)


def _run_curves_dp(ccfg: CurveConfig, compress: CompressedAllReduce,
                   n_devices) -> DPCurveResult:
    lanes = len(ccfg.p_miss)
    p_lanes = ccfg.lane_p_miss()
    n_s, n_d = sim_shard.dp_mesh_shape(n_devices, lanes, ccfg.dp_shards)
    p_pad = jnp.asarray(sim_shard.pad_lanes(p_lanes, n_s))
    l_pad = p_pad.shape[0]
    shard_ids = jnp.arange(ccfg.dp_shards, dtype=jnp.int32)

    views_j, labels_j, vv_j, vl_j = _make_data(ccfg)
    logged = ccfg.logged_steps()
    slots = jnp.asarray(_log_slots(ccfg, logged))

    acc = np.zeros((len(ccfg.bits), lanes), np.float64)
    nll = np.zeros_like(acc)
    hist = np.zeros((len(ccfg.bits), len(logged), lanes), np.float64)
    pay = np.zeros((len(ccfg.bits), len(logged), lanes), np.int64)
    pay_total = np.zeros((len(ccfg.bits), lanes), np.int64)
    params_out = []
    pay_step = dense_step = 0

    for bi, bits in enumerate(ccfg.bits):
        per_bits = _make_steps(ccfg, bits)
        vcfg_n, opt = per_bits[0], per_bits[2]
        k_data, lane_keys = _stream_keys(ccfg, bits)
        keys_pad = jnp.asarray(
            sim_shard.pad_lanes(np.asarray(lane_keys), n_s))

        params0 = vertical.init(vcfg_n, jax.random.PRNGKey(ccfg.seed))
        opt0 = opt.init(params0)
        # per-(lane, rank) error-feedback memory, a traced scan carry
        err0 = jax.tree.map(
            lambda x: jnp.zeros((l_pad, ccfg.dp_shards) + x.shape,
                                jnp.float32), params0)
        # the analytic per-step bill every measured step must equal
        pay_step = compress.payload_bits(params0) * ccfg.dp_shards
        dense_step = compress.dense_bits(params0) * ccfg.dp_shards

        fused = _make_fused_dp(ccfg, compress, per_bits, len(logged), n_s,
                               n_d)
        _DISPATCH_COUNTS["fused_dp"] += 1
        vals, hist_b, pay_b, pay_tot_b, acc_b, nll_b = fused(
            params0, opt0, err0, keys_pad, p_pad, shard_ids, k_data,
            views_j, labels_j, vv_j, vl_j, slots)

        acc[bi] = np.asarray(acc_b)[:lanes]
        nll[bi] = np.asarray(nll_b)[:lanes]
        hist[bi] = np.asarray(hist_b)[:lanes].T
        pay[bi] = np.asarray(pay_b, np.int64)[:lanes].T
        pay_total[bi] = np.asarray(pay_tot_b, np.int64)[:lanes]
        params_out.append(jax.tree.map(lambda x: x[:lanes], vals))

    return DPCurveResult(
        config=ccfg, compress=compress, p_miss=ccfg.lane_p_miss(),
        acc=acc, nll=nll, loss_history=hist,
        dp_payload_bits=pay, dp_payload_bits_total=pay_total,
        dp_payload_bits_step=int(pay_step),
        dp_dense_bits_step=int(dense_step),
        logged_steps=np.asarray(logged), params=params_out)


def run_curves_dp(ccfg: CurveConfig, compress: CompressedAllReduce, *,
                  n_devices: Optional[int] = None) -> DPCurveResult:
    """Train the 2-D (p_miss lanes x DP shards) grid with compressed comms.

    Each lane's training step splits the shared batch stream across
    ``ccfg.dp_shards`` data-parallel ranks; every rank sparsifies its
    gradients (top-k + error feedback, per-rank EF memory) and the sparse
    trees all-reduce via ``compress.reduce`` *inside* the fused scan — the
    whole run stays ONE host dispatch per ``bits`` value
    (``dispatch_counts()["fused_dp"]``), with the measured DP payload bits
    accumulated on device alongside the loss history.

    Placement follows :func:`repro.sim.shard.dp_mesh_shape`: the DP axis
    lands entirely on the device mesh (when ``dp_shards`` divides into the
    available devices) or entirely on a named vmap axis, never split —
    results are bit-for-bit identical across ``n_devices`` (the
    forced-multi-device subprocess test in ``tests/test_dp_curves.py``).

    Feed the result to ``repro.sim.results.summarize_dp_curves`` for the
    unified uplink + DP all-reduce communication report.
    """
    return _run_curves_dp(ccfg, compress, n_devices)
