"""Batched OCS scenario-sweep engine.

``scenarios`` — registry of named wireless scenarios and grid builders.
``sweep``     — the vmap/jit grid runner over the batched protocol cores.
``results``   — table/JSON emission with channel-accounting merge.
"""

from repro.sim.scenarios import (  # noqa: F401
    Scenario, get, names, register, scenario_grid,
)
from repro.sim.sweep import (  # noqa: F401
    SweepResult, run_sweep, reset_trace_counts, trace_counts,
)
from repro.sim.results import summarize, to_json, to_rows, write_json  # noqa: F401
