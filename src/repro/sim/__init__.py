"""Batched OCS scenario-sweep engine + channel-in-the-loop training curves.

``scenarios``    — registry of named wireless scenarios and grid builders.
``sweep``        — the vmap/jit (and shard_map-sharded) grid runner over the
                   batched protocol cores.
``train_curves`` — accuracy-vs-p_miss/bits curve runner: the fused on-device
                   scan engine (one dispatch per ``bits`` value, lane axis
                   device-sharded), plus the ``BitsSchedule``-driven
                   scheduled engine (``run_scheduled_curves``).
``shard``        — the shared 1-D shard_map machinery both runners use.
``results``      — table/JSON emission with channel-accounting merge.
"""

from repro.sim.scenarios import (  # noqa: F401
    Scenario, get, names, register, scenario_grid,
)
from repro.sim.sweep import (  # noqa: F401
    SweepResult, run_sweep, reset_trace_counts, trace_counts,
)
from repro.sim.train_curves import (  # noqa: F401
    CurveConfig, CurveResult, ScheduledCurveResult, dispatch_counts,
    reset_dispatch_counts, run_curves, run_scheduled_curves,
)
from repro.sim.results import (  # noqa: F401
    curve_rows, summarize, summarize_curves, to_json, to_rows, write_json,
)
