"""Named wireless scenarios for the OCS sweep engine.

A :class:`Scenario` pins the protocol-side knobs the paper argues over:
worker count N, backoff quantization depth D (``bits``), the imperfect
carrier-sensing miss probability (our beyond-paper extension), and the number
of orthogonal OFDMA channels (paper §III ref [16]).

The registry gives reproducible names to the operating points used by the
benchmarks; :func:`scenario_grid` builds dense cartesian grids for the
batched sweep (``repro.sim.sweep``), which evaluates every cell in one
compiled computation per ``bits`` value.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.ocs import host_id_bits
from repro.protocol import Protocol

PMiss = Union[float, Tuple[float, ...]]


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Hashable fault-process parameters of one scenario (plain floats —
    the registry stays host-side; :meth:`model` builds the traced
    ``repro.faults.FaultModel`` on demand).

    ``burst_len``/``gap_len`` are the Gilbert–Elliott mean sojourns (frames
    spent in the bad/good sensing state), ``p_miss_bad``/``p_miss_good``
    the per-state miss probabilities, ``p_drop``/``p_recover`` the worker
    dropout/recovery rates, and ``policy``/``retry_budget`` the degrade
    policy applied when a frame resolves nothing.
    """

    burst_len: float = 4.0
    gap_len: float = 16.0
    p_miss_bad: float = 0.5
    p_miss_good: float = 0.0
    p_drop: float = 0.0
    p_recover: float = 0.25
    policy: str = "stale"
    retry_budget: int = 0

    def __post_init__(self):
        if self.burst_len < 1.0 or self.gap_len < 1.0:
            raise ValueError("burst_len/gap_len are mean sojourns >= 1")
        for p in (self.p_miss_bad, self.p_miss_good, self.p_drop,
                  self.p_recover):
            if not (0.0 <= p <= 1.0):
                raise ValueError(f"fault probabilities must be in [0, 1], "
                                 f"got {p}")

    def model(self):
        """The traced ``repro.faults.FaultModel`` of this spec."""
        from repro import faults
        policy = (faults.DegradePolicy.retry(self.retry_budget)
                  if self.policy == "retry"
                  else faults.DegradePolicy(kind=self.policy))
        fm = faults.FaultModel.burst(
            burst_len=self.burst_len, gap_len=self.gap_len,
            p_miss_bad=self.p_miss_bad, p_miss_good=self.p_miss_good,
            policy=policy)
        if self.p_drop > 0.0:
            fm = fm.with_dropout(self.p_drop, self.p_recover)
        return fm


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One operating point of the wireless max-pooling channel.

    ``p_miss`` is either one probability shared by every worker or a
    per-worker tuple of length ``n_workers`` (heterogeneous near/far users:
    a far worker overhears blocking signals with lower probability, so its
    entry is larger).
    """

    name: str
    n_workers: int
    bits: int = 16          # D, backoff quantization depth (paper Eq. 7)
    p_miss: PMiss = 0.0     # per-sub-slot carrier-sensing miss probability
    n_channels: int = 1     # orthogonal OFDMA channels (latency divider)
    fault: Optional[FaultSpec] = None   # bursty/dropout fault process
    #   (None = the plain i.i.d. p_miss channel; see repro.faults)

    def __post_init__(self):
        if self.n_workers < 1:
            raise ValueError(f"{self.name}: n_workers must be >= 1")
        if not (1 <= self.bits <= 32):
            raise ValueError(f"{self.name}: bits must be in [1, 32]")
        if self.bits + host_id_bits(self.n_workers) > 32:
            raise ValueError(
                f"{self.name}: bits={self.bits} + "
                f"{host_id_bits(self.n_workers)} tie-break bits overflow the "
                f"32-bit contention word (reduce bits or n_workers)")
        if isinstance(self.p_miss, (list, tuple)):
            object.__setattr__(self, "p_miss", tuple(
                float(p) for p in self.p_miss))
            if len(self.p_miss) != self.n_workers:
                raise ValueError(
                    f"{self.name}: per-worker p_miss needs "
                    f"{self.n_workers} entries, got {len(self.p_miss)}")
        for p in self.p_miss_per_worker():
            if not (0.0 <= p < 1.0):
                raise ValueError(f"{self.name}: p_miss must be in [0, 1)")
        if self.n_channels < 1:
            raise ValueError(f"{self.name}: n_channels must be >= 1")

    def p_miss_per_worker(self) -> Tuple[float, ...]:
        """Broadcast ``p_miss`` to one probability per worker."""
        if isinstance(self.p_miss, tuple):
            return self.p_miss
        return (float(self.p_miss),) * self.n_workers

    def protocol(self, max_rounds: int = 3,
                 backend: str = "scan") -> Protocol:
        """This operating point as a first-class ``repro.protocol.Protocol``.

        ``p_miss`` becomes the protocol's traced leaf (scalar, or the
        per-worker vector for heterogeneous cells).  ``payload_bits`` is
        pinned to 32: sweep cells follow the paper's §IV accounting where
        the D-bit codes drive contention only and the winner transmits its
        full float payload (``OCSResult.value``) — unlike the
        channel-in-the-loop training protocol, whose winner transmits the
        D-bit code itself.
        """
        p = (np.asarray(self.p_miss, np.float32)
             if isinstance(self.p_miss, tuple)
             else np.float32(self.p_miss))
        return Protocol.ocs(bits=self.bits, p_miss=p,
                            max_rounds=max_rounds, backend=backend,
                            n_channels=self.n_channels, payload_bits=32)


_REGISTRY: Dict[str, Scenario] = {}


def register(scenario: Scenario, overwrite: bool = False) -> Scenario:
    """Add a scenario to the global registry (name must be unique)."""
    if not overwrite and scenario.name in _REGISTRY:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    _REGISTRY[scenario.name] = scenario
    return scenario


def get(name: str) -> Scenario:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; known: {sorted(_REGISTRY)}") from None


def names() -> List[str]:
    return sorted(_REGISTRY)


def scenario_grid(n_workers: Sequence[int],
                  bits: Sequence[int] = (16,),
                  p_miss: Sequence[float] = (0.0,),
                  n_channels: Sequence[int] = (1,),
                  name_prefix: str = "grid") -> List[Scenario]:
    """Dense cartesian scenario grid: N x bits x p_miss x n_channels.

    Cell names are deterministic (``grid/N16_b8_p0.02_c4``) so sweep rows are
    stable across runs.  The grid is *not* auto-registered — pass it straight
    to ``repro.sim.sweep.run_sweep``.
    """
    out = []
    for n, b, p, c in itertools.product(n_workers, bits, p_miss, n_channels):
        out.append(Scenario(
            name=f"{name_prefix}/N{n}_b{b}_p{p:g}_c{c}",
            n_workers=n, bits=b, p_miss=p, n_channels=c))
    return out


def near_far_p_miss(n_workers: int, p_near: float = 0.0,
                    p_far: float = 0.1) -> Tuple[float, ...]:
    """Two-tier per-worker miss profile: the first half of the workers are
    cell-center (near) users sensing at ``p_near``, the second half are
    cell-edge (far) users at ``p_far`` — the heterogeneous-channel setting
    surveyed in *Collaborative Learning over Wireless Networks*."""
    far = n_workers // 2
    return (p_near,) * (n_workers - far) + (p_far,) * far


# ---------------------------------------------------------------------------
# default registry: the operating points the benchmarks report
# ---------------------------------------------------------------------------

for _s in (
    # clean-sensing points along the paper's O(K)-vs-O(N*K) axis
    Scenario("lab_bench",      n_workers=2),
    Scenario("small_cell",     n_workers=4),
    Scenario("campus_cell",    n_workers=16),
    Scenario("dense_cell",     n_workers=64),
    # coarser backoff codes: fewer contention slots, more ties
    Scenario("lowrate_sensor", n_workers=16, bits=8),
    Scenario("massive_iot",    n_workers=64, bits=8),
    # imperfect carrier sensing (beyond-paper extension)
    Scenario("noisy_urban",    n_workers=16, p_miss=0.02),
    Scenario("noisy_dense",    n_workers=64, p_miss=0.05),
    # heterogeneous near/far users: per-worker miss probabilities
    Scenario("near_far_cell",  n_workers=16,
             p_miss=near_far_p_miss(16, 0.01, 0.1)),
    Scenario("near_far_dense", n_workers=64, bits=8,
             p_miss=near_far_p_miss(64, 0.0, 0.05)),
    # OFDMA striping: same transmissions, latency / n_channels
    Scenario("ofdma_wideband", n_workers=16, n_channels=8),
    Scenario("ofdma_noisy",    n_workers=64, bits=8, p_miss=0.02, n_channels=4),
    # channel faults (repro.faults): bursty sensing fades and worker
    # dropout spans with explicit degradation policies
    Scenario("burst_cell",     n_workers=16,
             fault=FaultSpec(burst_len=8.0, gap_len=32.0, p_miss_bad=0.5,
                             p_miss_good=0.01, policy="stale")),
    Scenario("worker_outage_cell", n_workers=16,
             fault=FaultSpec(burst_len=4.0, gap_len=64.0, p_miss_bad=0.3,
                             p_miss_good=0.0, p_drop=0.05, p_recover=0.25,
                             policy="zero_fill")),
):
    register(_s)
