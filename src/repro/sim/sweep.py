"""Vectorized OCS scenario-grid runner.

Evaluates a full scenario grid — rounds x workers (padded/masked to a common
max-N) x ``p_miss`` x ``n_channels`` — in ONE compiled computation per
``bits`` value, instead of one Python dispatch per ``(N, K)`` round.  The
worker count and miss probability enter the batched protocol cores
(``repro.core.ocs.ocs_maxpool_core`` / ``ocs_maxpool_noisy_core``) as traced
values, so a grid with ``bits`` in {8, 16} costs exactly two compilations of
each engine no matter how many cells it has.  Compilations are observable via
:func:`trace_counts` (a counter bumped on every jit trace), which the
property tests and the benchmark smoke row assert on.

On multi-device hosts the scenario axis is additionally sharded over a 1-D
``("s",)`` mesh with ``shard_map`` (groups are padded up to a device-count
multiple; the padding rows are dropped before results are returned), so a
grid scales with hardware while staying bit-for-bit identical to the
single-device vmap path (property-tested with forced host devices).  Pass
``n_devices=1`` to force the plain vmap path.  The mesh/shard_map machinery
is shared with the curve engine's lane sharding via ``repro.sim.shard``.

The padded accounting is bit-for-bit identical to unpadded per-round calls
(``tests/test_sweep.py``), so ``benchmarks/bench_comm.py`` reproduces its
historical O(K)-vs-O(N*K) rows from one sweep.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import ocs
from repro.sim import shard as sim_shard
from repro.sim.scenarios import Scenario

# ---------------------------------------------------------------------------
# compilation observability
# ---------------------------------------------------------------------------

_TRACE_COUNTS: Dict[str, int] = {"clean": 0, "noisy": 0}


def reset_trace_counts() -> None:
    """Zero the per-engine jit trace counters (used by tests/benchmarks)."""
    for k in _TRACE_COUNTS:
        _TRACE_COUNTS[k] = 0


def trace_counts() -> Dict[str, int]:
    """Number of times each sweep engine has been traced (== compiled).

    The counters are bumped by a Python side effect inside the jitted
    functions, which only executes while JAX traces — cache hits leave them
    untouched.
    """
    return dict(_TRACE_COUNTS)


# ---------------------------------------------------------------------------
# jitted engines: vmap(rounds) o vmap(scenarios) over the batched cores,
# optionally shard_map-ped over the scenario axis on multi-device hosts
# ---------------------------------------------------------------------------

def _ceil_div(a: jax.Array, b: jax.Array) -> jax.Array:
    return (a + b - 1) // b


def _shard_scenarios(fn, n_devices: int, n_args: int):
    """Wrap an all-scenario-leading engine in shard_map over the ``s`` mesh."""
    return sim_shard.shard_1d(fn, n_devices,
                              in_specs=(P("s"),) * n_args, out_specs=P("s"))


@functools.partial(jax.jit,
                   static_argnames=("bits", "max_id_bits", "n_devices"))
def _sweep_clean(h, mask, id_bits, n_channels, *, bits, max_id_bits,
                 n_devices=1):
    """h: (S, R, N_max, K); mask: (S, N_max); id_bits/n_channels: (S,)."""
    _TRACE_COUNTS["clean"] += 1
    core = functools.partial(ocs.ocs_maxpool_core,
                             bits=bits, max_id_bits=max_id_bits)
    per_round = jax.vmap(core, in_axes=(0, None, None))
    engine = jax.vmap(per_round, in_axes=(0, 0, 0))
    if n_devices > 1:
        engine = _shard_scenarios(engine, n_devices, n_args=3)
    res = engine(h, mask, id_bits)
    latency = _ceil_div(res.contention_slots, n_channels[:, None])
    return res, latency


@functools.partial(jax.jit,
                   static_argnames=("bits", "max_id_bits", "max_rounds",
                                    "backend", "n_devices"))
def _sweep_noisy(h, mask, id_bits, rng, p_miss, n_channels, *,
                 bits, max_id_bits, max_rounds, backend="scan", n_devices=1):
    """As `_sweep_clean` plus rng: (S, R, 2) keys and p_miss: (S, N_max)
    per-worker miss probabilities, traced (homogeneous scenarios carry the
    scalar broadcast — bit-for-bit the historical scalar path).
    ``backend`` selects the contention engine (``Protocol.backend``:
    ``"scan"`` or the fused ``"pallas"`` kernel, bit-for-bit identical)."""
    _TRACE_COUNTS["noisy"] += 1
    core = functools.partial(ocs.ocs_maxpool_noisy_core, bits=bits,
                             max_id_bits=max_id_bits, max_rounds=max_rounds,
                             backend=backend)
    per_round = jax.vmap(core, in_axes=(0, None, None, 0, None))
    engine = jax.vmap(per_round, in_axes=(0, 0, 0, 0, 0))
    if n_devices > 1:
        engine = _shard_scenarios(engine, n_devices, n_args=5)
    res = engine(h, mask, id_bits, rng, p_miss)
    latency = _ceil_div(res.contention_slots, n_channels[:, None])
    return res, latency


# ---------------------------------------------------------------------------
# host-side packing + the public grid runner
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SweepResult:
    """Stacked outcome of one grid sweep.

    Pytree leaves of ``clean``/``noisy`` carry leading (S, R) axes: scenario
    (in the order passed to :func:`run_sweep`) then aggregation round.
    ``h``/``mask`` are the padded inputs, kept so per-cell results can be
    cross-checked against unbatched oracles.
    """

    scenarios: List[Scenario]
    k_elems: int
    rounds: int
    n_max: int
    h: np.ndarray                                   # (S, R, N_max, K)
    mask: np.ndarray                                # (S, N_max)
    clean: Optional[ocs.OCSResult] = None           # leaves (S, R, ...)
    clean_latency_slots: Optional[np.ndarray] = None    # (S, R)
    noisy: Optional[ocs.NoisyOCSResult] = None      # leaves (S, R, ...)
    noisy_latency_slots: Optional[np.ndarray] = None    # (S, R)

    def scenario_h(self, i: int) -> np.ndarray:
        """Unpadded (R, n_workers, K) features of scenario ``i``."""
        return self.h[i, :, :self.scenarios[i].n_workers, :]

    def clean_cell(self, i: int, r: int = 0) -> ocs.OCSResult:
        return jax.tree.map(lambda x: x[i, r], self.clean)

    def noisy_cell(self, i: int, r: int = 0) -> ocs.NoisyOCSResult:
        return jax.tree.map(lambda x: x[i, r], self.noisy)


def _default_features(scenarios: Sequence[Scenario], rounds: int,
                      k_elems: int, seed: int) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((rounds, s.n_workers, k_elems))
            .astype(np.float32) for s in scenarios]


def _scatter(groups):
    """Reassemble per-bits group pytrees into original scenario order."""
    order = np.concatenate([np.asarray(idx) for idx, _ in groups])
    cat = jax.tree.map(
        lambda *xs: np.concatenate([np.asarray(x) for x in xs], axis=0),
        *(tree for _, tree in groups))
    inv = np.argsort(order, kind="stable")
    return jax.tree.map(lambda x: x[inv], cat)


def run_sweep(scenarios: Sequence[Scenario], *,
              k_elems: int = 64,
              rounds: int = 1,
              seed: int = 0,
              h_by_scenario: Optional[Sequence[np.ndarray]] = None,
              rng_seed: int = 0,
              max_rounds: int = 3,
              backend: str = "scan",
              include_clean: bool = True,
              include_noisy: bool = True,
              n_devices: Optional[int] = None) -> SweepResult:
    """Evaluate every scenario x round cell in one dispatch per ``bits`` value.

    Args:
      scenarios:     grid cells (see ``repro.sim.scenarios``).
      k_elems:       K, feature elements per aggregation round.
      rounds:        R, independent aggregation rounds per scenario.
      seed:          feature-generation seed (ignored if ``h_by_scenario``).
      h_by_scenario: optional per-scenario features, each (R, n_workers, K) —
                     lets benchmarks replay an exact historical rng stream.
      rng_seed:      sensing-noise key seed for the noisy engine.
      max_rounds:    re-contention bound of the noisy protocol.
      backend:       contention engine of the noisy protocol
                     (``repro.protocol.Protocol.backend``: ``"scan"`` or
                     ``"pallas"``; bit-for-bit interchangeable).
      include_clean / include_noisy: which engines to run.  The noisy engine
                     subsumes clean behaviour at ``p_miss=0`` but reports the
                     collision/accuracy accounting instead of the blocking-tx
                     accounting.
      n_devices:     devices to shard the scenario axis over.  ``None`` (the
                     default) uses every local device; ``1`` forces the
                     single-device vmap path.  Results are identical either
                     way — sharding only changes placement.

    Returns:
      SweepResult with (S, R)-stacked pytrees, in the scenario order given.
    """
    scenarios = list(scenarios)
    if not scenarios:
        raise ValueError("run_sweep needs at least one scenario")
    if h_by_scenario is None:
        h_by_scenario = _default_features(scenarios, rounds, k_elems, seed)
    if len(h_by_scenario) != len(scenarios):
        raise ValueError("h_by_scenario must match scenarios 1:1")

    n_max = max(s.n_workers for s in scenarios)
    s_total = len(scenarios)
    h_pad = np.zeros((s_total, rounds, n_max, k_elems), dtype=np.float32)
    mask = np.zeros((s_total, n_max), dtype=bool)
    id_bits = np.zeros((s_total,), dtype=np.int32)
    # per-worker miss probabilities (padded rows are masked-out in the core,
    # so their p_miss entries are inert)
    p_miss = np.zeros((s_total, n_max), dtype=np.float32)
    n_channels = np.zeros((s_total,), dtype=np.int32)
    for i, (s, h) in enumerate(zip(scenarios, h_by_scenario)):
        h = np.asarray(h, dtype=np.float32)
        if h.shape != (rounds, s.n_workers, k_elems):
            raise ValueError(
                f"scenario {s.name!r}: h shape {h.shape} != "
                f"{(rounds, s.n_workers, k_elems)}")
        h_pad[i, :, :s.n_workers, :] = h
        mask[i, :s.n_workers] = True
        id_bits[i] = ocs.host_id_bits(s.n_workers)
        p_miss[i, :s.n_workers] = s.p_miss_per_worker()
        n_channels[i] = s.n_channels

    # independent noise keys per (scenario, round), stable under regrouping
    keys = jax.random.split(
        jax.random.PRNGKey(rng_seed), s_total * rounds
    ).reshape(s_total, rounds, -1)

    # group cells by the only static axis: the quantization depth
    by_bits: Dict[int, List[int]] = {}
    for i, s in enumerate(scenarios):
        by_bits.setdefault(s.bits, []).append(i)

    clean_groups, noisy_groups = [], []
    for bits, idx in sorted(by_bits.items()):
        sel = np.asarray(idx)
        # the scan-length bound (and its 32-bit-word guard) is per bits-group:
        # a global max over *all* scenarios would make a wide-bits cell raise
        # on the id_bits of an unrelated large-N narrow-bits cell.
        max_id_bits = int(id_bits[sel].max())
        n_dev = sim_shard.lane_devices(n_devices, len(sel))

        def dev_pad(x: np.ndarray) -> jax.Array:
            return jnp.asarray(sim_shard.pad_lanes(x, n_dev))

        def unpad(tree):
            return jax.tree.map(lambda x: np.asarray(x)[:len(sel)], tree)

        args = (dev_pad(h_pad[sel]), dev_pad(mask[sel]),
                dev_pad(id_bits[sel]))
        nch = dev_pad(n_channels[sel])
        if include_clean:
            res, lat = _sweep_clean(*args, nch, bits=bits,
                                    max_id_bits=max_id_bits, n_devices=n_dev)
            clean_groups.append((sel, unpad((res, lat))))
        if include_noisy:
            res, lat = _sweep_noisy(*args, dev_pad(keys[sel]),
                                    dev_pad(p_miss[sel]),
                                    nch, bits=bits, max_id_bits=max_id_bits,
                                    max_rounds=max_rounds, backend=backend,
                                    n_devices=n_dev)
            noisy_groups.append((sel, unpad((res, lat))))

    out = SweepResult(scenarios=scenarios, k_elems=k_elems, rounds=rounds,
                      n_max=n_max, h=h_pad, mask=mask)
    if clean_groups:
        out.clean, out.clean_latency_slots = _scatter(clean_groups)
    if noisy_groups:
        out.noisy, out.noisy_latency_slots = _scatter(noisy_groups)
    return out
