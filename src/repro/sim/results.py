"""Sweep/curve-result emission: per-scenario records, benchmark rows, JSON.

Merges the *measured* counters from the batched simulation (payload /
blocking transmissions, contention slots, noisy-sensing accuracy) with the
*analytic* channel accounting of ``repro.core.channel`` (uplink message and
overhead-bit model, paper §I / §IV), so every emitted record carries both
sides of the O(K)-vs-O(N*K) argument.  :func:`summarize_curves` does the
same merge for channel-in-the-loop training curves
(``repro.sim.train_curves``): every accuracy row carries the uplink cost of
the operating point that produced it.
"""

from __future__ import annotations

import json
from typing import Dict, List

import numpy as np

from repro.protocol import Protocol
from repro.sim.sweep import SweepResult

Record = Dict[str, object]


def _fmt_p_miss(p) -> str:
    """Row label for a scalar or per-worker miss probability."""
    arr = np.asarray(p, np.float64).ravel()
    if arr.size == 1 or np.all(arr == arr[0]):
        return f"{arr[0]:g}"
    return f"{arr.min():g}..{arr.max():g}"


def summarize(sweep: SweepResult) -> List[Record]:
    """One merged record per scenario (measured counters + analytic loads)."""
    records: List[Record] = []
    for i, s in enumerate(sweep.scenarios):
        # analytic accounting off the scenario's Protocol object (float
        # payloads, the paper's §IV convention — see Scenario.protocol)
        fed = s.protocol().comm_load(s.n_workers, sweep.k_elems)
        cat = Protocol.concat(n_channels=s.n_channels).comm_load(
            s.n_workers, sweep.k_elems)
        rec: Record = {
            "scenario": s.name,
            "n_workers": s.n_workers,
            "bits": s.bits,
            "p_miss": s.p_miss,
            "n_channels": s.n_channels,
            "rounds": sweep.rounds,
            "k_elems": sweep.k_elems,
            # analytic accounting (channel.py)
            "uplink_msgs_fedocs": fed.uplink_payload_msgs,
            "uplink_msgs_concat": cat.uplink_payload_msgs,
            "uplink_ratio": cat.uplink_payload_msgs / fed.uplink_payload_msgs,
            "uplink_overhead_bits": fed.uplink_overhead_bits,
            "analytic_latency_slots": fed.latency_slots,
        }
        if sweep.clean is not None:
            rec.update({
                # deterministic per round: report round 0 counters
                "payload_tx": int(np.asarray(sweep.clean.payload_tx)[i, 0]),
                "concat_payload_tx": int(
                    np.asarray(sweep.clean.concat_payload_tx)[i, 0]),
                "contention_slots": int(
                    np.asarray(sweep.clean.contention_slots)[i, 0]),
                "latency_slots": int(sweep.clean_latency_slots[i, 0]),
                # varies with the drawn features: average over rounds
                "blocking_tx_mean": float(
                    np.asarray(sweep.clean.blocking_tx)[i].mean()),
                "ties_mean": float(np.asarray(sweep.clean.ties)[i].mean()),
            })
        if sweep.noisy is not None:
            rec.update({
                "frac_correct_mean": float(
                    np.asarray(sweep.noisy.correct)[i].mean()),
                "collisions_mean": float(
                    np.asarray(sweep.noisy.collisions)[i].mean()),
                "noisy_rounds_mean": float(
                    np.asarray(sweep.noisy.rounds)[i].mean()),
                "noisy_contention_slots_mean": float(
                    np.asarray(sweep.noisy.contention_slots)[i].mean()),
                "noisy_latency_slots_mean": float(
                    sweep.noisy_latency_slots[i].mean()),
            })
        records.append(rec)
    return records


def summarize_curves(curves) -> List[Record]:
    """One record per (bits, p_miss) cell of a train-curve grid.

    ``curves`` is a ``repro.sim.train_curves.CurveResult``.  The flat record
    list serves both tables: filter on ``bits`` for accuracy-vs-p_miss, on
    ``p_miss`` for accuracy-vs-bits.  Uplink accounting uses the D-bit code
    payload the ``max_noisy`` winner actually transmits.  Records label
    lanes by the configured operating points (``config.p_miss``);
    ``CurveResult.p_miss`` carries their float32 traced counterparts.
    """
    ccfg = curves.config
    records: List[Record] = []
    for bi, bits in enumerate(ccfg.bits):
        # the curve protocol's winner transmits its D-bit code: payload
        # bits come from the Protocol itself (one source of truth)
        fed = ccfg.protocol(bits).comm_load(ccfg.n_workers, ccfg.embed_dim)
        cat = Protocol.concat().comm_load(ccfg.n_workers, ccfg.embed_dim)
        for li in range(curves.p_miss.shape[0]):
            p = ccfg.p_miss[li]
            records.append({
                "curve": f"b{bits}_p{_fmt_p_miss(p)}",
                "bits": bits,
                "p_miss": float(p) if np.ndim(p) == 0
                else [float(x) for x in p],
                "n_workers": ccfg.n_workers,
                "k_elems": ccfg.embed_dim,
                "steps": ccfg.steps,
                "acc": float(curves.acc[bi, li]),
                "nll": float(curves.nll[bi, li]),
                "acc_ideal": float(curves.acc_ideal[bi]),
                "nll_ideal": float(curves.nll_ideal[bi]),
                "acc_gap": float(curves.acc_ideal[bi] - curves.acc[bi, li]),
                "uplink_bits_fedocs": fed.uplink_bits,
                "uplink_bits_concat": cat.uplink_bits,
                "uplink_ratio": cat.uplink_bits / fed.uplink_bits,
            })
    return records


def summarize_fault_curves(fc) -> List[Record]:
    """One record per (bits, fault-lane) cell of a fault-injection grid.

    ``fc`` is a ``repro.sim.train_curves.FaultCurveResult``.  Accuracy rows
    carry the degradation telemetry beside them — whole-run dropped-frame /
    outage / retry-slot totals and the final staleness — so "how much worse
    under bursts" and "how much airtime the policy spent" read off one row.
    ``burst_len``/``gap_len`` are reported as the mean sojourns implied by
    the lane's transition probabilities (``1/p_bg`` / ``1/p_gb``; ``inf``
    for an i.i.d. lane, which never enters the bad state).
    """
    ccfg = fc.config
    records: List[Record] = []
    for bi, bits in enumerate(ccfg.bits):
        fed = ccfg.protocol(bits).comm_load(ccfg.n_workers, ccfg.embed_dim)
        for li, fm in enumerate(fc.fault_lanes):
            p_bg = float(np.asarray(fm.p_bg))
            p_gb = float(np.asarray(fm.p_gb))
            burst_len = (1.0 / p_bg) if p_bg > 0 else float("inf")
            gap_len = (1.0 / p_gb) if p_gb > 0 else float("inf")
            records.append({
                "curve": f"b{bits}_burst{burst_len:g}_"
                         f"{fm.policy.kind}_l{li}",
                "bits": bits,
                "lane": li,
                "policy": fm.policy.kind,
                "retry_budget": fm.policy.retry_budget,
                "burst_len": burst_len,
                "gap_len": gap_len,
                "p_miss_bad": float(np.asarray(fm.p_miss_bad)),
                "p_miss_good": float(np.asarray(fm.p_miss_good)),
                "p_drop": float(np.asarray(fm.p_drop)),
                "p_recover": float(np.asarray(fm.p_recover)),
                "n_workers": ccfg.n_workers,
                "k_elems": ccfg.embed_dim,
                "steps": ccfg.steps,
                "acc": float(fc.acc[bi, li]),
                "nll": float(fc.nll[bi, li]),
                # degradation telemetry (whole-run totals)
                "dropped_frames": int(fc.dropped_frames[bi, li]),
                "outage_frames": int(fc.outage_frames[bi, li]),
                "retry_slots": int(fc.retry_slots[bi, li]),
                "stale_age_final": int(fc.stale_age[bi, -1, li]),
                "stale_age_max": int(fc.stale_age[bi, :, li].max()),
                "uplink_bits_fedocs": fed.uplink_bits,
            })
    return records


def fault_curve_rows(records: List[Record], prefix: str = "fault_curves"
                     ) -> List[str]:
    """Benchmark-harness CSV rows for fault-injection curve records."""
    rows = []
    for rec in records:
        derived = [
            f"bits={rec['bits']}", f"policy={rec['policy']}",
            f"burst={rec['burst_len']:g}",
            f"p_bad={rec['p_miss_bad']:g}",
            f"acc={rec['acc']:.4f}", f"nll={rec['nll']:.4f}",
            f"dropped={rec['dropped_frames']}",
            f"outages={rec['outage_frames']}",
            f"retry_slots={rec['retry_slots']}",
            f"stale_max={rec['stale_age_max']}",
        ]
        rows.append(f"{prefix}/{rec['curve']},0," + ";".join(derived))
    return rows


def summarize_dp_curves(dp) -> List[Record]:
    """One record per (bits, p_miss) cell of a 2-D compressed-comms run —
    THE unified communication report.

    ``dp`` is a ``repro.sim.train_curves.DPCurveResult``.  Every accuracy
    point carries both halves of the communication bill:

    * **uplink** — the analytic FedOCS airtime of the operating point
      (``Protocol.comm_load``, per aggregated sample), scaled to the
      training run: ``batch`` samples aggregate per step, ``steps`` steps;
    * **DP all-reduce** — the payload bits *measured* inside the fused scan
      from the exact-k kept-element counts, totalled over ranks and steps
      (``dp_payload_bits_total``), plus the per-step analytic bill and the
      dense baseline it compresses against;

    and their sum ``total_comm_bits`` — accuracy vs total communication as
    one number, which is the ROADMAP's compressed-comms unification.
    """
    ccfg = dp.config
    records: List[Record] = []
    for bi, bits in enumerate(ccfg.bits):
        fed = ccfg.protocol(bits).comm_load(ccfg.n_workers, ccfg.embed_dim)
        # one channel aggregation per training sample, batch per step
        uplink_step = fed.uplink_bits * ccfg.batch
        for li in range(dp.p_miss.shape[0]):
            p = ccfg.p_miss[li]
            dp_total = int(dp.dp_payload_bits_total[bi, li])
            uplink_total = uplink_step * ccfg.steps
            records.append({
                "curve": f"b{bits}_p{_fmt_p_miss(p)}",
                "bits": bits,
                "p_miss": float(p) if np.ndim(p) == 0
                else [float(x) for x in p],
                "n_workers": ccfg.n_workers,
                "dp_shards": ccfg.dp_shards,
                "k_elems": ccfg.embed_dim,
                "steps": ccfg.steps,
                "k_frac": dp.compress.k_frac,
                "acc": float(dp.acc[bi, li]),
                "nll": float(dp.nll[bi, li]),
                # uplink half (analytic, per paper §I/§IV)
                "uplink_bits_step": uplink_step,
                "uplink_bits_total": uplink_total,
                # DP half (measured kept-element counts, all ranks)
                "dp_payload_bits_step": dp.dp_payload_bits_step,
                "dp_payload_bits_total": dp_total,
                "dp_dense_bits_step": dp.dp_dense_bits_step,
                "dp_payload_frac": (dp.dp_payload_bits_step
                                    / dp.dp_dense_bits_step),
                # the one number
                "total_comm_bits": uplink_total + dp_total,
            })
    return records


def dp_curve_rows(records: List[Record], prefix: str = "dp_curves"
                  ) -> List[str]:
    """Benchmark-harness CSV rows for the unified comm report."""
    rows = []
    for rec in records:
        derived = [
            f"bits={rec['bits']}", f"p_miss={_fmt_p_miss(rec['p_miss'])}",
            f"dp={rec['dp_shards']}", f"k_frac={rec['k_frac']:g}",
            f"acc={rec['acc']:.4f}", f"nll={rec['nll']:.4f}",
            f"uplink_bits={rec['uplink_bits_total']}",
            f"dp_bits={rec['dp_payload_bits_total']}",
            f"dp_frac={rec['dp_payload_frac']:.3f}",
            f"total_bits={rec['total_comm_bits']}",
        ]
        rows.append(f"{prefix}/{rec['curve']},0," + ";".join(derived))
    return rows


def curve_rows(records: List[Record], prefix: str = "curves") -> List[str]:
    """Benchmark-harness CSV rows for train-curve records."""
    rows = []
    for rec in records:
        derived = [
            f"bits={rec['bits']}", f"p_miss={_fmt_p_miss(rec['p_miss'])}",
            f"acc={rec['acc']:.4f}", f"acc_ideal={rec['acc_ideal']:.4f}",
            f"acc_gap={rec['acc_gap']:+.4f}", f"nll={rec['nll']:.4f}",
            f"uplink_bits={rec['uplink_bits_fedocs']}",
            f"ratio={rec['uplink_ratio']:.0f}",
        ]
        rows.append(f"{prefix}/{rec['curve']},0," + ";".join(derived))
    return rows


def to_rows(records: List[Record], prefix: str = "sweep") -> List[str]:
    """Benchmark-harness CSV rows: ``name,us_per_call,k=v;k=v;...``."""
    rows = []
    for rec in records:
        derived = [f"N={rec['n_workers']}", f"bits={rec['bits']}"]
        if np.any(np.asarray(rec["p_miss"])):
            derived.append(f"p_miss={_fmt_p_miss(rec['p_miss'])}")
        if rec["n_channels"] != 1:
            derived.append(f"ch={rec['n_channels']}")
        if "payload_tx" in rec:
            derived += [
                f"payload_tx={rec['payload_tx']}",
                f"blocking_tx={rec['blocking_tx_mean']:.1f}",
                f"slots={rec['contention_slots']}",
                f"latency={rec['latency_slots']}",
                f"concat_tx={rec['concat_payload_tx']}",
            ]
        derived.append(f"ratio={rec['uplink_ratio']:.0f}")
        if "frac_correct_mean" in rec:
            derived += [
                f"frac_correct={rec['frac_correct_mean']:.3f}",
                f"collisions={rec['collisions_mean']:.1f}",
            ]
        rows.append(f"{prefix}/{rec['scenario']},0," + ";".join(derived))
    return rows


def to_json(records: List[Record]) -> str:
    return json.dumps(records, indent=2, sort_keys=True)


def write_json(records: List[Record], path: str) -> None:
    with open(path, "w") as f:
        f.write(to_json(records) + "\n")
