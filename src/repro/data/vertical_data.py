"""Synthetic stand-ins for the paper's MNIST / CIFAR experiments.

The container is offline, so we generate deterministic datasets with the same
tensor shapes and — crucially — the same *task structure* the paper relies on:

* :func:`multiview_denoising` (paper §IV-A): a clean 28x28 "digit-like"
  image (random smooth blob mixture); each of N sensors observes the SAME
  image corrupted by independent Gaussian noise of sigma=2 (the paper's
  setting).  Reconstruction must fuse all views to denoise.

* :func:`patch_classification` (paper §IV-B): a 32x32 "image" partitioned
  into a grid of N cells, one per worker.  The class is a function of the
  WHOLE image (prototype matching with per-class global templates plus
  per-patch distractors), so no single patch suffices — matching the paper's
  observation that individual workers do poorly while fused embeddings
  approach the centralized model.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


def _blob_image(rng: np.random.Generator, hw: int = 28, k: int = 3
                ) -> np.ndarray:
    """Smooth normalized blob mixture in [0, 1] — a 'digit-like' image."""
    yy, xx = np.mgrid[0:hw, 0:hw] / hw
    img = np.zeros((hw, hw))
    for _ in range(k):
        cx, cy = rng.random(2) * 0.8 + 0.1
        sx, sy = rng.random(2) * 0.12 + 0.04
        img += np.exp(-((xx - cx) ** 2 / (2 * sx ** 2)
                        + (yy - cy) ** 2 / (2 * sy ** 2)))
    img /= max(img.max(), 1e-6)
    return img


def multiview_denoising(n_samples: int, n_workers: int = 4, hw: int = 28,
                        sigma: float = 2.0, seed: int = 0
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (views (N, M, hw*hw), clean (M, hw*hw)) — paper §IV-A."""
    rng = np.random.default_rng(seed)
    clean = np.stack([_blob_image(rng, hw) for _ in range(n_samples)])
    clean = clean.reshape(n_samples, hw * hw).astype(np.float32)
    noise = rng.normal(0.0, sigma, size=(n_workers,) + clean.shape)
    views = (clean[None] + noise).astype(np.float32)
    return views, clean


@dataclasses.dataclass(frozen=True)
class PatchTaskConfig:
    n_classes: int = 4
    grid: int = 2              # grid x grid workers (paper: 2x2 / 3x3)
    hw: int = 32               # full image side
    sigma: float = 0.5         # per-patch observation noise
    seed: int = 0


def pattern_bank(cfg: PatchTaskConfig) -> np.ndarray:
    """Fixed bank of n_classes patch patterns (shared across patches)."""
    ph = cfg.hw // cfg.grid
    rng_t = np.random.default_rng(cfg.seed)
    return rng_t.normal(0, 1, size=(cfg.n_classes, ph, ph))


def patch_classification(cfg: PatchTaskConfig, n_samples: int, seed: int = 0
                         ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (views (N, M, patch_dim), labels (M,)).

    Relational task: patch i displays pattern k_i from a shared bank; the
    label is ``(sum_i k_i) mod n_classes``.  Every patch's marginal is
    uniform over the bank regardless of class, so a single worker — and any
    fusion of *per-worker posteriors* (the paper's 'Best Worker' and
    'Avg. Workers Preds' baselines) — is at chance by construction, while
    embedding-level fusion (concat / mean / FedOCS max) can decode every
    k_i and learn the relation.  This reproduces the paper's Table-I
    separation structurally rather than through noise levels.
    """
    bank = pattern_bank(cfg)
    ph = cfg.hw // cfg.grid
    n_workers = cfg.grid * cfg.grid
    rng = np.random.default_rng([cfg.seed + 1, seed])
    ks = rng.integers(0, cfg.n_classes, size=(n_workers, n_samples))
    labels = np.mod(ks.sum(axis=0), cfg.n_classes)
    views = []
    for i in range(n_workers):
        patch = bank[ks[i]] + rng.normal(
            0, cfg.sigma, size=(n_samples, ph, ph))
        views.append(patch.reshape(n_samples, ph * ph))
    return (np.stack(views).astype(np.float32),
            labels.astype(np.int32))
