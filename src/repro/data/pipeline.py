"""Deterministic, stateless synthetic data pipeline.

Every batch is *index-derived*: ``batch_for_step(step)`` regenerates the same
batch from (seed, step) with a counter-based RNG — no iterator state to
checkpoint, restart-safe by construction, and a straggling host can
substitute any step's batch deterministically (train/fault_tolerance.py).

The synthetic "language" has learnable structure (affine next-token map with
noise) so convergence tests can verify loss actually falls.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    vocab_size: int
    batch: int                  # global batch
    seq_len: int
    seed: int = 0
    noise: float = 0.05         # fraction of random next tokens
    frontend: str = "token"     # token | patch | audio
    frontend_dim: int = 0
    decoder_len: int = 0        # enc-dec: decoder token length


def _rng(cfg: PipelineConfig, step: int) -> np.random.Generator:
    # counter-style determinism: the (seed, step) pair fully determines the
    # batch — no iterator state exists anywhere.
    return np.random.default_rng([cfg.seed, step])


def _token_batch(cfg: PipelineConfig, rng: np.random.Generator,
                 batch: int, seq: int) -> np.ndarray:
    v = cfg.vocab_size
    a = 31337 % v or 1
    b = 17
    x0 = rng.integers(0, v, size=(batch, 1))
    toks = [x0]
    for _ in range(seq):
        nxt = (a * toks[-1] + b) % v
        noise = rng.integers(0, v, size=(batch, 1))
        use_noise = rng.random((batch, 1)) < cfg.noise
        toks.append(np.where(use_noise, noise, nxt))
    return np.concatenate(toks, axis=1).astype(np.int32)   # (B, seq+1)


def batch_for_step(cfg: PipelineConfig, step: int) -> Dict[str, jnp.ndarray]:
    rng = _rng(cfg, step)
    out: Dict[str, jnp.ndarray] = {}
    if cfg.frontend == "token":
        seq = _token_batch(cfg, rng, cfg.batch, cfg.seq_len)
        out["tokens"] = jnp.asarray(seq[:, :-1])
        out["targets"] = jnp.asarray(seq[:, 1:])
    elif cfg.decoder_len:                                   # enc-dec
        feats = rng.standard_normal(
            (cfg.batch, cfg.seq_len, cfg.frontend_dim)).astype(np.float32)
        seq = _token_batch(cfg, rng, cfg.batch, cfg.decoder_len)
        out["feats"] = jnp.asarray(feats)
        out["tokens"] = jnp.asarray(seq[:, :-1])
        out["targets"] = jnp.asarray(seq[:, 1:])
    else:                                                   # patch/audio LM
        feats = rng.standard_normal(
            (cfg.batch, cfg.seq_len, cfg.frontend_dim)).astype(np.float32)
        seq = _token_batch(cfg, rng, cfg.batch, cfg.seq_len)
        out["feats"] = jnp.asarray(feats)
        out["targets"] = jnp.asarray(seq[:, 1:])
    return out


def for_model(mcfg, batch: int, seq_len: int, seed: int = 0
              ) -> PipelineConfig:
    from repro.models.model import WHISPER_DECODER_LEN
    return PipelineConfig(
        vocab_size=mcfg.vocab_size,
        batch=batch,
        seq_len=seq_len,
        seed=seed,
        frontend=mcfg.frontend,
        frontend_dim=mcfg.frontend_dim,
        decoder_len=(min(WHISPER_DECODER_LEN, seq_len)
                     if mcfg.encoder_decoder else 0),
    )
