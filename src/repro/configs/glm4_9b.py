"""glm4-9b: dense, RoPE (partial rotary), GQA kv=2. [hf:THUDM/glm-4-9b]"""

from repro.configs.base import ModelConfig

ID = "glm4-9b"


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name=ID,
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=2,
        d_ff=13696,
        vocab_size=151552,
        rope_theta=10000.0,
        rotary_frac=0.5,
        act="silu",
        norm="rmsnorm",
        n_workers=16,
    ).with_(**overrides)


def reduced(**overrides) -> ModelConfig:
    import jax.numpy as jnp
    defaults = dict(
                n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, n_workers=2, dtype=jnp.float32,
        param_dtype=jnp.float32, remat=False)
    defaults.update(overrides)
    return config().with_(**defaults)
