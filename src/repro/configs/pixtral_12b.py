"""pixtral-12b: VLM — pixtral-ViT frontend (STUB: input_specs provides
precomputed 1024-d patch embeddings) + mistral-nemo-like decoder backbone.
[hf:mistralai/Pixtral-12B-2409; unverified]"""

from repro.configs.base import ModelConfig

ID = "pixtral-12b"


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name=ID,
        family="vlm",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        frontend="patch",
        frontend_dim=1024,
        rope_theta=1_000_000.0,
        act="silu",
        norm="rmsnorm",
        n_workers=16,
    ).with_(**overrides)


def reduced(**overrides) -> ModelConfig:
    import jax.numpy as jnp
    defaults = dict(
                n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, frontend_dim=16, n_workers=2,
        dtype=jnp.float32, param_dtype=jnp.float32, remat=False)
    defaults.update(overrides)
    return config().with_(**defaults)
