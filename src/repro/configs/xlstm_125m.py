"""xlstm-125m: sLSTM + mLSTM blocks (3 mLSTM : 1 sLSTM per period), no
separate FFN (d_ff=0 in the assignment; expansion lives inside the blocks).
Recurrent state is O(1) -> long_500k capable. [arXiv:2405.04517]

use_rope=True here means "no absolute positional embedding is added" — the
recurrence provides order; there is no attention for RoPE to act on.
"""

from repro.configs.base import ModelConfig

ID = "xlstm-125m"


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name=ID,
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        ffn_pattern=("none",),
        ssm_expand=2,
        act="gelu",
        norm="layernorm",
        tie_embeddings=True,
        subquadratic=True,
        n_workers=16,
    ).with_(**overrides)


def reduced(**overrides) -> ModelConfig:
    import jax.numpy as jnp
    defaults = dict(
                n_layers=4, d_model=64, n_heads=2, n_kv_heads=2,
        vocab_size=256, n_workers=2, dtype=jnp.float32,
        param_dtype=jnp.float32, remat=False)
    defaults.update(overrides)
    return config().with_(**defaults)
