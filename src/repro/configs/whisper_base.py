"""whisper-base: encoder-decoder; conv audio frontend is a STUB
(input_specs provides precomputed 80->512-d frame embeddings).  The real
448-token positional cap is lifted to the assigned decode shapes via config
(DESIGN.md §5). [arXiv:2212.04356; unverified]

8 heads < 16-way TP axis -> plain attention layout (padded head sharding).
"""

from repro.configs.base import ModelConfig

ID = "whisper-base"


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name=ID,
        family="audio",
        n_layers=6,
        n_encoder_layers=6,
        encoder_decoder=True,
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab_size=51865,
        frontend="audio",
        frontend_dim=80,
        use_rope=False,          # sinusoidal absolute positions
        use_abs_pos=True,
        act="gelu",
        norm="layernorm",
        tie_embeddings=True,
        n_workers=16,
    ).with_(**overrides)


def reduced(**overrides) -> ModelConfig:
    import jax.numpy as jnp
    defaults = dict(
        n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, frontend_dim=16, n_workers=2,
        dtype=jnp.float32, param_dtype=jnp.float32, remat=False)
    defaults.update(overrides)
    return config().with_(**defaults)
