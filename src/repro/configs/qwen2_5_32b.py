"""qwen2.5-32b: dense, GQA kv=8, QKV bias. [hf:Qwen/Qwen2.5-32B]

40 heads do not divide the 16-way TP axis -> plain attention layout
(GSPMD-padded head sharding); FedOCS fusion applies to the MLPs.
"""

from repro.configs.base import ModelConfig

ID = "qwen2.5-32b"


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name=ID,
        family="dense",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=27648,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        act="silu",
        norm="rmsnorm",
        n_workers=16,
    ).with_(**overrides)


def reduced(**overrides) -> ModelConfig:
    import jax.numpy as jnp
    defaults = dict(
                n_layers=2, d_model=64, n_heads=5, n_kv_heads=1, d_ff=128,
        vocab_size=256, n_workers=2, dtype=jnp.float32,
        param_dtype=jnp.float32, remat=False)
    defaults.update(overrides)
    return config().with_(**defaults)
