"""Model / shape / run configuration dataclasses.

One :class:`ModelConfig` covers all ten assigned architectures via a cyclic
``block_pattern`` (mixer kind per layer position) x ``ffn_pattern`` (ffn kind
per layer position).  The FedOCS technique enters through ``tp_fusion``
(DESIGN.md §2.1), selectable per config / CLI.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax.numpy as jnp

MIXERS = ("attn", "attn_nocausal", "mamba", "mlstm", "slstm")
FFNS = ("mlp", "moe", "none")
TP_FUSIONS = ("sum", "max", "max_q16", "max_q8", "concat")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|ssm|vlm|hybrid|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # layer plan: patterns are cycled over the layer index
    block_pattern: Tuple[str, ...] = ("attn",)
    ffn_pattern: Tuple[str, ...] = ("mlp",)
    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_shared_expert: bool = False
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # attention
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    rotary_frac: float = 1.0          # glm4 rotates half the head dim
    use_rope: bool = True             # rotary embeddings inside attention
    use_abs_pos: bool = False         # additive sinusoidal PE (whisper)
    # SSM (mamba / xlstm)
    ssm_state_dim: int = 16
    ssm_expand: int = 2
    conv_width: int = 4
    dt_rank: int = 0                  # 0 => ceil(d_model / 16)
    # encoder-decoder
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_block_pattern: Tuple[str, ...] = ("attn_nocausal",)
    # modality frontend (stub: consumes precomputed patch/frame embeddings)
    frontend: str = "token"           # token|patch|audio
    frontend_dim: int = 0
    # numerics
    norm: str = "rmsnorm"             # rmsnorm|layernorm
    act: str = "silu"                 # silu|gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # FedOCS integration (the paper's technique as a TP fusion law)
    tp_fusion: str = "sum"
    tie_break: str = "all"
    # execution
    n_workers: int = 1                # TP worker count == model-axis size
    scan_layers: bool = True
    remat: bool = True
    use_flash: bool = False           # Pallas flash-attention path
    mamba_assoc_scan: bool = False    # associative-scan SSM recurrence
    loss_chunk: int = 512             # xent seq chunking (activation memory)
    # hillclimb levers (EXPERIMENTS.md §Perf)
    scores_dtype: str = "f32"         # attention scores: f32 | bf16
    pad_heads_to: int = 0             # pad n_heads for even TP sharding
    moe_impl: str = "sort_scatter"    # sort_scatter | gather
    remat_policy: str = "full"        # full | dots (save matmul outputs)
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    logit_dtype: Any = jnp.float32
    # long-context support marker (SSM/hybrid only; gates long_500k cells)
    subquadratic: bool = False

    def __post_init__(self):
        assert self.tp_fusion in TP_FUSIONS, self.tp_fusion
        for m in self.block_pattern:
            assert m in MIXERS, m
        for f in self.ffn_pattern:
            assert f in FFNS, f
        period = self.period
        assert self.n_layers % period == 0, \
            f"{self.name}: n_layers {self.n_layers} % period {period} != 0"

    # ---- derived ----
    @property
    def period(self) -> int:
        return _lcm(len(self.block_pattern), len(self.ffn_pattern))

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    def layer_plan(self) -> Tuple[Tuple[str, str], ...]:
        """(mixer, ffn) for each position within a period."""
        return tuple(
            (self.block_pattern[i % len(self.block_pattern)],
             self.ffn_pattern[i % len(self.ffn_pattern)])
            for i in range(self.period))

    def encoder_layer_plan(self) -> Tuple[Tuple[str, str], ...]:
        return tuple(
            (self.encoder_block_pattern[i % len(self.encoder_block_pattern)],
             "mlp") for i in range(len(self.encoder_block_pattern)))

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank or max(1, math.ceil(self.d_model / 16))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (MODEL_FLOPS = 6*N*D uses these) ----
    def param_count(self, active_only: bool = False) -> int:
        return _param_count(self, active_only)


def _lcm(a: int, b: int) -> int:
    return a * b // math.gcd(a, b)


def _attn_params(c: ModelConfig) -> int:
    hd = c.head_dim_
    p = c.d_model * (c.n_heads * hd) + 2 * c.d_model * (c.n_kv_heads * hd) \
        + (c.n_heads * hd) * c.d_model
    if c.qkv_bias:
        p += (c.n_heads + 2 * c.n_kv_heads) * hd
    return p


def _mlp_params(c: ModelConfig, d_ff: int) -> int:
    gates = 2 if c.act == "silu" else 1          # SwiGLU has gate+up
    return c.d_model * d_ff * gates + d_ff * c.d_model


def _mamba_params(c: ModelConfig) -> int:
    di, st, dr = c.d_inner, c.ssm_state_dim, c.dt_rank_
    return (c.d_model * 2 * di          # in_proj (x, z)
            + di * c.conv_width         # depthwise conv
            + di * (dr + 2 * st)        # x -> (dt, B, C)
            + dr * di                   # dt up-proj
            + di * st                   # A (log) matrix
            + di                        # D skip
            + di * c.d_model)           # out_proj


def _xlstm_params(c: ModelConfig, kind: str) -> int:
    di = c.d_inner
    if kind == "mlstm":
        # up-proj (x,z), qkv over inner dim, igate/fgate/ogate, down-proj
        return (c.d_model * 2 * di + 3 * di * di + 3 * di + di * c.d_model)
    # slstm: 4 gates over d_model + small FFN folded in
    return 4 * c.d_model * c.d_model + 4 * c.d_model


def _layer_params(c: ModelConfig, mixer: str, ffn: str) -> Tuple[int, int]:
    """(dense_params, per_expert_extra) for one layer."""
    if mixer in ("attn", "attn_nocausal"):
        p = _attn_params(c)
    elif mixer == "mamba":
        p = _mamba_params(c)
    else:
        p = _xlstm_params(c, mixer)
    p += 2 * c.d_model                   # norms
    moe_extra = 0
    if ffn == "mlp":
        p += _mlp_params(c, c.d_ff)
    elif ffn == "moe":
        p += c.d_model * c.n_experts     # router
        moe_extra = _mlp_params(c, c.moe_d_ff or c.d_ff)
        if c.moe_shared_expert:
            p += _mlp_params(c, c.moe_d_ff or c.d_ff)
    return p, moe_extra


def _param_count(c: ModelConfig, active_only: bool) -> int:
    total = c.vocab_size * c.d_model     # embedding
    if not c.tie_embeddings:
        total += c.vocab_size * c.d_model
    if c.frontend != "token":
        total += (c.frontend_dim or c.d_model) * c.d_model
    plan = c.layer_plan()
    for i in range(c.n_layers):
        mixer, ffn = plan[i % c.period]
        dense, per_expert = _layer_params(c, mixer, ffn)
        total += dense
        if per_expert:
            n_e = c.experts_per_token if active_only else c.n_experts
            total += per_expert * n_e
    if c.encoder_decoder:
        for i in range(c.n_encoder_layers):
            dense, _ = _layer_params(c, "attn_nocausal", "mlp")
            total += dense
            # decoder cross-attention (one per decoder layer)
        total += c.n_layers * _attn_params(c)
    return total


# ---------------------------------------------------------------------------
# input shapes (assignment: 4 shapes per arch)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 500k decode needs sub-quadratic "
                       "attention (see DESIGN.md §5)")
    return True, ""
