"""qwen3-moe-30b-a3b: all-MoE, 128 experts top-8, GQA kv=4, head_dim=128.
[hf:Qwen/Qwen3-30B-A3B]"""

from repro.configs.base import ModelConfig

ID = "qwen3-moe-30b-a3b"


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name=ID,
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=4,
        head_dim=128,
        d_ff=768,
        vocab_size=151936,
        ffn_pattern=("moe",),
        n_experts=128,
        experts_per_token=8,
        moe_d_ff=768,
        rope_theta=1_000_000.0,
        act="silu",
        norm="rmsnorm",
        n_workers=16,
    ).with_(**overrides)


def reduced(**overrides) -> ModelConfig:
    import jax.numpy as jnp
    defaults = dict(
                n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, moe_d_ff=32, vocab_size=256, n_experts=8,
        experts_per_token=2, n_workers=2, dtype=jnp.float32,
        param_dtype=jnp.float32, remat=False)
    defaults.update(overrides)
    return config().with_(**defaults)
