"""Paper §IV-A configuration: multi-sensor MNIST denoising reconstruction.

Exact paper hyperparameters: N=4 sensors, 784-d flattened views, encoders
{512, 256, 128} -> K=64 embedding, decoder {128, 256, 512} -> 784,
sigma=2 observation noise, max-pool aggregation.
"""

from repro.core.vertical import VerticalConfig

ID = "fedocs-mnist"

N_WORKERS = 4
SIGMA = 2.0
IMAGE_HW = 28


def config(**overrides) -> VerticalConfig:
    defaults = dict(
        n_workers=N_WORKERS,
        input_dim=IMAGE_HW * IMAGE_HW,
        encoder_dims=(512, 256, 128),
        embed_dim=64,
        head_dims=(128, 256, 512),
        output_dim=IMAGE_HW * IMAGE_HW,
        task="reconstruction",
        aggregation="max",
    )
    defaults.update(overrides)
    return VerticalConfig(**defaults)


def reduced(**overrides) -> VerticalConfig:
    defaults = dict(input_dim=64, encoder_dims=(64,), embed_dim=16,
                    head_dims=(64,), output_dim=64)
    defaults.update(overrides)
    return config(**defaults)
