"""jamba-1.5-large-398b: hybrid Mamba+attention 1:7 interleave, MoE 16e
top-2 on alternating layers.  Attention KV cache is sequence-sharded for the
long_500k cell (DESIGN.md §4). [arXiv:2403.19887]"""

from repro.configs.base import ModelConfig

ID = "jamba-1.5-large-398b"

_PERIOD = ("mamba", "mamba", "mamba", "attn",
           "mamba", "mamba", "mamba", "mamba")


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name=ID,
        family="hybrid",
        n_layers=72,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=24576,
        vocab_size=65536,
        block_pattern=_PERIOD,
        ffn_pattern=("mlp", "moe"),
        n_experts=16,
        experts_per_token=2,
        moe_d_ff=24576,
        ssm_expand=2,
        ssm_state_dim=16,
        conv_width=4,
        use_rope=False,          # jamba uses no positional encoding
        act="silu",
        norm="rmsnorm",
        subquadratic=True,
        n_workers=16,
    ).with_(**overrides)


def reduced(**overrides) -> ModelConfig:
    import jax.numpy as jnp
    defaults = dict(
                n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        moe_d_ff=64, vocab_size=256, n_experts=4, experts_per_token=2,
        n_workers=2, dtype=jnp.float32, param_dtype=jnp.float32,
        remat=False)
    defaults.update(overrides)
    return config().with_(**defaults)
