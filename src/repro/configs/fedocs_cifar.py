"""Paper §IV-B configuration: classification from patch grids.

The paper uses MobileNetV2 per worker on CIFAR-10 (2x2 grid, 4 workers) /
CIFAR-100 (3x3 grid, 9 workers) and a {512,512,512} fusion head.  Offline we
pair the same split/head structure with MLP encoders on the synthetic
relational patch task (DESIGN.md §8.5); `grid`/`n_classes` pick the
CIFAR-10-like (4-worker) or CIFAR-100-like (9-worker) geometry.
"""

from repro.core.vertical import VerticalConfig

ID = "fedocs-cifar"


def config(grid: int = 2, n_classes: int = 10, hw: int = 32,
           **overrides) -> VerticalConfig:
    patch = hw // grid
    defaults = dict(
        n_workers=grid * grid,
        input_dim=patch * patch,
        encoder_dims=(256, 128),          # MobileNetV2 stand-in at MLP scale
        embed_dim=64,
        head_dims=(512, 512, 512),        # the paper's fusion head
        output_dim=n_classes,
        task="classification",
        aggregation="max",
    )
    defaults.update(overrides)
    return VerticalConfig(**defaults)


def cifar10_like(**overrides) -> VerticalConfig:
    return config(grid=2, n_classes=10, **overrides)


def cifar100_like(**overrides) -> VerticalConfig:
    return config(grid=3, n_classes=100, **overrides)


def reduced(**overrides) -> VerticalConfig:
    defaults = dict(encoder_dims=(64,), embed_dim=16, head_dims=(64,))
    defaults.update(overrides)
    return config(**defaults)
