"""minicpm-2b: llama-like dense; trains with the WSD schedule
(see optim/schedules.py). [arXiv:2404.06395]

36 heads do not divide the 16-way TP axis -> plain attention layout.
"""

from repro.configs.base import ModelConfig

ID = "minicpm-2b"


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name=ID,
        family="dense",
        n_layers=40,
        d_model=2304,
        n_heads=36,
        n_kv_heads=36,
        d_ff=5760,
        vocab_size=122753,
        rope_theta=10000.0,
        act="silu",
        norm="rmsnorm",
        tie_embeddings=True,
        n_workers=16,
    ).with_(**overrides)


def reduced(**overrides) -> ModelConfig:
    import jax.numpy as jnp
    defaults = dict(
                n_layers=2, d_model=70, n_heads=5, n_kv_heads=5, d_ff=128,
        vocab_size=256, n_workers=2, dtype=jnp.float32,
        param_dtype=jnp.float32, remat=False)
    defaults.update(overrides)
    return config().with_(**defaults)
