"""qwen1.5-0.5b: dense, MHA-ish (kv=16), QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""

from repro.configs.base import ModelConfig

ID = "qwen1.5-0.5b"


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name=ID,
        family="dense",
        n_layers=24,
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=2816,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=10000.0,
        act="silu",
        norm="rmsnorm",
        tie_embeddings=True,
        n_workers=16,
    ).with_(**overrides)


def reduced(**overrides) -> ModelConfig:
    import jax.numpy as jnp
    defaults = dict(
                n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, n_workers=2, dtype=jnp.float32,
        param_dtype=jnp.float32, remat=False)
    defaults.update(overrides)
    return config().with_(**defaults)
