"""Architecture config registry: --arch <id> resolution."""

from __future__ import annotations

import importlib
from typing import Callable, Dict

from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES,
                                shape_applicable)

_MODULES = {
    "glm4-9b": "glm4_9b",
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "minicpm-2b": "minicpm_2b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "xlstm-125m": "xlstm_125m",
    "pixtral-12b": "pixtral_12b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "whisper-base": "whisper_base",
}

ARCH_IDS = tuple(_MODULES)


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str, **overrides) -> ModelConfig:
    return _module(arch_id).config(**overrides)


def get_reduced(arch_id: str, **overrides) -> ModelConfig:
    return _module(arch_id).reduced(**overrides)


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCH_IDS",
           "get_config", "get_reduced", "shape_applicable"]
