"""llama4-scout-17b-a16e: MoE 16 experts top-1 + shared expert; the
multimodal early-fusion frontend is out of scope for the LM backbone cells
(the assignment lists the transformer backbone only).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

40 heads do not divide the 16-way TP axis -> plain attention layout.
"""

from repro.configs.base import ModelConfig

ID = "llama4-scout-17b-a16e"


def config(**overrides) -> ModelConfig:
    return ModelConfig(
        name=ID,
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab_size=202048,
        ffn_pattern=("moe",),
        n_experts=16,
        experts_per_token=1,
        moe_d_ff=8192,
        moe_shared_expert=True,
        rope_theta=500_000.0,
        act="silu",
        norm="rmsnorm",
        n_workers=16,
    ).with_(**overrides)


def reduced(**overrides) -> ModelConfig:
    import jax.numpy as jnp
    defaults = dict(
                n_layers=2, d_model=64, n_heads=5, n_kv_heads=1, d_ff=64,
        moe_d_ff=64, vocab_size=256, n_experts=4, experts_per_token=1,
        n_workers=2, dtype=jnp.float32, param_dtype=jnp.float32,
        remat=False)
    defaults.update(overrides)
    return config().with_(**defaults)
