"""Production training launcher.

Assembles (arch config x mesh x data x optimizer x trainer) from the CLI.
On the CPU container use ``--smoke`` (reduced config, tiny synthetic data);
on a real pod the same command line runs the full config against the
production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke \
      --steps 50 --fusion max --ckpt-dir /tmp/ck

Recommended XLA flags on real TPU (comm/compute overlap):
  --xla_tpu_enable_async_collective_fusion=true
  --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true
  --xla_tpu_overlap_compute_collective_tc=true
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.data import pipeline
from repro.models import model as M
from repro.optim import optimizers, schedules
from repro.parallel import sharding as sh
from repro.train import trainer
from repro.train.trainer import TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--fusion", default="max")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", type=float, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    get = get_reduced if args.smoke else get_config
    cfg = get(args.arch, tp_fusion=args.fusion)
    m = M.build(cfg)
    print(f"{cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"fusion={cfg.tp_fusion}, devices={jax.device_count()}")

    values, _ = sh.split_tree(m.init(jax.random.PRNGKey(args.seed)))
    pcfg = pipeline.for_model(cfg, batch=args.batch, seq_len=args.seq,
                              seed=args.seed)
    opt = optimizers.adamw(
        schedules.for_arch(args.arch, args.lr, args.steps),
        weight_decay=0.01)
    tcfg = TrainerConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                         ckpt_every=max(args.steps // 4, 1), log_every=10,
                         microbatches=args.microbatches,
                         compress_k=args.compress)
    res = trainer.train(m.loss, values, opt,
                        lambda s: pipeline.batch_for_step(pcfg, s), tcfg)
    for row in res.history:
        print(f"step {row['step']:6d}  nll {row.get('nll', float('nan')):8.4f}"
              f"  lr {row.get('lr', 0):.2e}  {row['step_time_s']:.2f}s")
    if res.straggler_flags:
        print("straggler-flagged steps:", res.straggler_flags)


if __name__ == "__main__":
    main()
