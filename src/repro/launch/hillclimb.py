import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Hillclimb driver: re-lowers the three chosen cells with each optimization
variant and records roofline deltas (EXPERIMENTS.md §Perf).

Cells (from the baseline table):
  * qwen3-moe-30b-a3b / train_4k   — worst compute fraction, most
    collective-bound (EP combine all-gather)
  * qwen2.5-32b / train_4k         — largest absolute collective term
    (uneven 40-head sharding all-gathers)
  * glm4-9b / train_4k             — most representative of the paper's
    technique (full FedOCS fusion coverage)
"""

import json
import time

from repro.launch.dryrun import run_cell

EXPERIMENTS = {
    "glm4-9b": [
        # paper-faithful baseline already recorded as __max
        ("sum", dict(tp_fusion="sum"), {}),                  # Megatron ref
        ("concat", dict(tp_fusion="concat"), {}),            # paper's bound
        ("q8", dict(tp_fusion="max_q8"), {}),
        ("q8_bf16s", dict(tp_fusion="max_q8"),
         dict(scores_dtype="bf16")),
    ],
    "qwen2.5-32b": [
        ("pad48", dict(tp_fusion="max"), dict(pad_heads_to=48)),
        ("pad48_q8_bf16s", dict(tp_fusion="max_q8"),
         dict(pad_heads_to=48, scores_dtype="bf16")),
    ],
    "qwen3-moe-30b-a3b": [
        ("gather", dict(tp_fusion="max"), dict(moe_impl="gather")),
        ("gather_q8_bf16s", dict(tp_fusion="max_q8"),
         dict(moe_impl="gather", scores_dtype="bf16")),
    ],
}


def main():
    out_dir = "artifacts/hillclimb"
    os.makedirs(out_dir, exist_ok=True)
    for arch, variants in EXPERIMENTS.items():
        for name, fusion_kw, overrides in variants:
            tag = f"{arch}__train_4k__sp__{name}"
            t0 = time.time()
            rec = run_cell(arch, "train_4k", multi_pod=False,
                           tp_fusion=fusion_kw["tp_fusion"],
                           overrides=overrides)
            rec["variant"] = name
            with open(os.path.join(out_dir, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
            if rec["status"] == "ok":
                r = rec["roofline"]
                print(f"[ok {time.time()-t0:5.0f}s] {tag} "
                      f"bn={r['bottleneck']} tc={r['t_compute_s']:.3e} "
                      f"tm={r['t_memory_s']:.3e} tl={r['t_collective_s']:.3e}",
                      flush=True)
            else:
                print(f"[ERR] {tag}: {rec.get('error','')[:200]}", flush=True)


if __name__ == "__main__":
    main()
