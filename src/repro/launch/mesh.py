"""Production mesh construction + per-cell sharding rules.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model) — the ``pod``
axis extends data parallelism across the ICI-connected superpod (DCN in
practice; the dry-run proves the program shards over it).
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.parallel import sharding as sh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 2):
    """Small host-device mesh for subprocess distribution tests."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh((data, model), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
    except (ImportError, TypeError):
        return jax.make_mesh((data, model), ("data", "model"))


def rules_for(shape_name: str, global_batch: int, mesh) -> dict:
    """Per-cell logical-axis rule table.

    long-context decode cells cannot shard their batch (B=1); the KV cache
    sequence is sharded over the data(+pod) axes instead (flash-decode-style
    sequence parallelism).  Other cells shard batch over (pod, data) and
    keep kv_seq local.
    """
    rules = dict(sh.DEFAULT_RULES)
    sizes = sh.mesh_axis_sizes(mesh)
    batch_ways = sizes.get("pod", 1) * sizes.get("data", 1)
    if global_batch % batch_ways != 0 or shape_name == "long_500k":
        rules["batch"] = None
        rules["kv_seq"] = ("pod", "data") if "pod" in sizes else ("data",)
    else:
        rules["kv_seq"] = None
    return rules
