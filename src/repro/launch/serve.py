"""Serving launcher: checkpoint -> slot-batched decode loop, optionally
with the simulated wireless channel in every decode tick.

CLI flags map 1:1 onto :class:`repro.serve.engine.ServeConfig`
(``--batch-slots``/``--max-seq``/``--eos-id``/``--sample``/``--seed`` plus
the ``--p-miss``/``--bits``/... protocol fields and the
``--tick-us``/``--slot-us`` clock); the request stream comes from the
Poisson load generator (``--requests``/``--rate``/...).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --batch-slots 4 --requests 8
  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --p-miss 0.05 --bits 8 --rate 0.5        # channel in the loop
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.checkpoint import checkpointer
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import model as M
from repro.parallel import sharding as sh
from repro.protocol import Protocol
from repro.serve.engine import ChannelClock, ServeConfig, ServeEngine
from repro.serve.load import near_far_protocol, poisson_requests


def _build_protocol(args, n_workers: int):
    if args.p_miss is None and not args.near_far:
        return None
    if args.near_far:
        return near_far_protocol(
            n_workers, bits=args.bits, p_near=args.p_miss or 0.0,
            p_far=args.p_far, max_rounds=args.max_rounds,
            backend=args.backend)
    p = np.full((n_workers,), args.p_miss, np.float32)
    return Protocol.ocs(bits=args.bits, p_miss=p,
                        max_rounds=args.max_rounds, backend=args.backend)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    # ServeConfig fields, 1:1
    ap.add_argument("--batch-slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--eos-id", type=int, default=-1)
    ap.add_argument("--sample", action="store_true",
                    help="categorical sampling instead of greedy argmax")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tick-us", type=float, default=50.0)
    ap.add_argument("--slot-us", type=float, default=1.0)
    # protocol fields (omit --p-miss/--near-far for channel-free serving)
    ap.add_argument("--p-miss", type=float, default=None,
                    help="carrier-sensing miss probability (all workers)")
    ap.add_argument("--near-far", action="store_true",
                    help="two-tier near/far p_miss mix (--p-miss=near tier)")
    ap.add_argument("--p-far", type=float, default=0.1)
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--max-rounds", type=int, default=3)
    ap.add_argument("--backend", default="scan", choices=("scan", "pallas"))
    # load generator
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--rate", type=float, default=1.0,
                    help="Poisson arrival rate (requests per decode tick)")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    get = get_reduced if args.smoke else get_config
    cfg = get(args.arch)
    m = M.build(cfg)
    values, _ = sh.split_tree(m.init(jax.random.PRNGKey(args.seed)))
    if args.ckpt_dir:
        restored, step, _ = checkpointer.restore(
            args.ckpt_dir, template={"values": values, "opt": None})
        values = restored["values"]
        print(f"restored checkpoint step {step}")

    clock = ChannelClock(tick_us=args.tick_us, slot_us=args.slot_us)
    config = ServeConfig(
        batch_slots=args.batch_slots, max_seq=args.max_seq,
        eos_id=args.eos_id, greedy=not args.sample,
        protocol=_build_protocol(args, cfg.n_workers), clock=clock,
        seed=args.seed)
    engine = ServeEngine(m, values, config)
    reqs = poisson_requests(args.requests, args.rate, cfg.vocab_size,
                            prompt_len=args.prompt_len,
                            max_new_tokens=args.max_new, seed=args.seed)
    outs = engine.run(reqs)
    for rid in sorted(outs):
        c = outs[rid]
        print(f"req {rid}: latency={c.latency_us(clock):.0f}us "
              f"({c.latency_ticks} ticks, {c.channel_slots} slots, "
              f"{c.uplink_bits} uplink bits) tokens={c.tokens}")


if __name__ == "__main__":
    main()
