"""Serving launcher: checkpoint -> slot-batched decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --slots 4 --requests 8
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.checkpoint import checkpointer
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import model as M
from repro.parallel import sharding as sh
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    get = get_reduced if args.smoke else get_config
    cfg = get(args.arch)
    m = M.build(cfg)
    values, _ = sh.split_tree(m.init(jax.random.PRNGKey(args.seed)))
    if args.ckpt_dir:
        restored, step, _ = checkpointer.restore(
            args.ckpt_dir, template={"values": values, "opt": None})
        values = restored["values"]
        print(f"restored checkpoint step {step}")

    engine = ServeEngine(m, values, batch_slots=args.slots,
                         max_seq=args.max_seq, eos_id=-1)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(
                        0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    outs = engine.run(reqs)
    for rid in sorted(outs):
        print(f"req {rid}: {outs[rid].tokens}")


if __name__ == "__main__":
    main()
