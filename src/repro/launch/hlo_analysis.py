"""Parse collective traffic out of (S)HLO text for the roofline analysis.

``cost_analysis()`` does not expose collective bytes, so we scan the
partitioned module for all-reduce / all-gather / reduce-scatter / all-to-all
/ collective-permute ops, read their per-device result shapes, and convert to
estimated per-device link bytes with ring-algorithm factors:

    all-reduce(P)        2 * P * (g-1)/g      (reduce-scatter + all-gather)
    all-gather(->P)      P * (g-1)/g
    reduce-scatter(->P)  P * (g-1)            (operand is g*P)
    all-to-all(P)        P * (g-1)/g
    collective-permute   P

where P = per-device result bytes and g = collective group size (parsed from
replica_groups, both explicit-list and iota forms).
"""

from __future__ import annotations

import dataclasses
import re
import warnings
from collections import defaultdict
from typing import Dict, List

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPS = ("all-reduce-start", "all-gather-start", "reduce-scatter",
        "all-to-all", "collective-permute-start", "all-reduce",
        "all-gather", "collective-permute")
_CANON = {
    "all-reduce-start": "all-reduce",
    "all-gather-start": "all-gather",
    "collective-permute-start": "collective-permute",
}
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    payload_bytes: Dict[str, int]      # sum of per-device result bytes
    link_bytes: float                  # ring-estimated per-device link bytes

    def total_payload(self) -> int:
        return sum(self.payload_bytes.values())


# unknown dtypes encountered in non-strict parses: dtype -> occurrence count
_UNKNOWN_DTYPES: Dict[str, int] = defaultdict(int)


def unknown_dtype_counts() -> Dict[str, int]:
    """Dtypes skipped by non-strict parses since the last reset (counted so
    reports can surface them instead of silently corrupting byte totals)."""
    return dict(_UNKNOWN_DTYPES)


def reset_unknown_dtype_counts() -> None:
    _UNKNOWN_DTYPES.clear()


def _shape_bytes(dtype: str, dims: str, strict: bool = True) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    width = DTYPE_BYTES.get(dtype)
    if width is None:
        if strict:
            raise ValueError(
                f"unknown HLO dtype {dtype!r}: add it to "
                f"hlo_analysis.DTYPE_BYTES (guessing a width would corrupt "
                f"the roofline byte totals)")
        if dtype not in _UNKNOWN_DTYPES:
            warnings.warn(
                f"unknown HLO dtype {dtype!r}: its shapes are excluded from "
                f"collective byte totals (add it to "
                f"hlo_analysis.DTYPE_BYTES)", stacklevel=3)
        _UNKNOWN_DTYPES[dtype] += 1
        return 0
    return n * width


def _result_bytes(line: str, op_pos: int, strict: bool = True) -> int:
    """Sum all shaped results appearing before the op name on the line."""
    total = 0
    for m in _SHAPE_RE.finditer(line[:op_pos]):
        total += _shape_bytes(m.group(1), m.group(2), strict)
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(len(first), 1)
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    return default


def parse_collectives(hlo_text: str, default_group: int = 2, *,
                      strict: bool = True) -> CollectiveStats:
    """Collective counts/bytes of one HLO module.

    ``strict=True`` (the default) raises on collective result dtypes
    missing from :data:`DTYPE_BYTES` — an unknown f8/int4 width must not
    silently corrupt roofline numbers.  ``strict=False`` warns once per
    dtype, counts it in :func:`unknown_dtype_counts` and excludes its
    shapes from the byte totals (for callers that only need op *counts*,
    like the analysis pass's collective-freedom check).
    """
    counts: Dict[str, int] = defaultdict(int)
    payload: Dict[str, int] = defaultdict(int)
    link = 0.0
    for line in hlo_text.splitlines():
        for op in _OPS:
            pos = line.find(f" {op}(")
            if pos < 0:
                continue
            canon = _CANON.get(op, op)
            pb = _result_bytes(line, pos, strict)
            if pb == 0:
                continue
            g = _group_size(line, default_group)
            counts[canon] += 1
            payload[canon] += pb
            if canon == "all-reduce":
                link += 2 * pb * (g - 1) / g
            elif canon == "all-gather":
                link += pb * (g - 1) / g
            elif canon == "reduce-scatter":
                link += pb * (g - 1)
            elif canon == "all-to-all":
                link += pb * (g - 1) / g
            else:                       # collective-permute
                link += pb
            break
    return CollectiveStats(counts=dict(counts), payload_bytes=dict(payload),
                           link_bytes=link)


# hardware constants (TPU v5e)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (one direction)


def roofline_terms(flops_per_dev: float, hbm_bytes_per_dev: float,
                   link_bytes_per_dev: float) -> Dict[str, float]:
    t_compute = flops_per_dev / PEAK_FLOPS_BF16
    t_memory = hbm_bytes_per_dev / HBM_BW
    t_collective = link_bytes_per_dev / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)),
        key=lambda kv: kv[1])[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "bottleneck": dominant,
    }
