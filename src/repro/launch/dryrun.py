import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import (jax locks the device
# count at first init). 512 placeholder host devices back both the single-pod
# (16,16) and multi-pod (2,16,16) production meshes.

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape x mesh) cell this lowers + compiles the
real step function (train_step incl. optimizer update / prefill / decode) at
the production mesh, prints ``memory_analysis()`` and ``cost_analysis()``,
parses per-device collective bytes out of the partitioned HLO, and writes a
JSON artifact consumed by the roofline benchmark and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all --out artifacts/dryrun
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, SHAPES, get_config, shape_applicable)
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh, rules_for
from repro.models import model as M
from repro.optim import optimizers, schedules
from repro.parallel import sharding as sh
from repro.train.train_step import make_train_step


def _shardings(axes_tree, values_tree, mesh, rules):
    return sh.tree_shardings_for_values(axes_tree, values_tree, mesh, rules)


def _replicated(tree, mesh):
    rep = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())
    return jax.tree.map(lambda _: rep, tree)


# activation-memory control: grad-accumulation microbatches per train cell
TRAIN_MICROBATCHES = {
    "jamba-1.5-large-398b": 8,
    "qwen2.5-32b": 2,
    "llama4-scout-17b-a16e": 2,
}
# FSDP threshold: shard compute params over the fsdp axes too when the plain
# TP layout leaves more than this many bytes per device (jamba-398B)
FSDP_PARAM_BYTES = 8 << 30


def _per_dev_bytes(values_sds, shardings) -> int:
    import math
    total = 0
    for leaf, shd in zip(jax.tree.leaves(values_sds),
                         jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(
                             x, "shard_shape"))):
        total += math.prod(shd.shard_shape(leaf.shape)) * leaf.dtype.itemsize
    return total


def build_cell(arch: str, shape_name: str, mesh, tp_fusion: str = "max",
               overrides: Optional[Dict[str, Any]] = None):
    """Returns (jitted fn, example args as ShapeDtypeStructs, cfg)."""
    shape = SHAPES[shape_name]
    overrides = dict(overrides or {})
    microbatches = overrides.pop(
        "microbatches",
        TRAIN_MICROBATCHES.get(arch, 1) if shape_name == "train_4k" else 1)
    cfg = get_config(arch, n_workers=16, tp_fusion=tp_fusion, **overrides)
    rules = rules_for(shape_name, shape.global_batch, mesh)
    m = M.build(cfg)

    params_tagged = jax.eval_shape(m.init, jax.random.PRNGKey(0))
    values_sds, axes = sh.split_tree(params_tagged)
    param_sh = _shardings(axes, values_sds, mesh, rules)
    # FSDP for very large models: TP alone leaves too many bytes per device
    if _per_dev_bytes(values_sds, param_sh) > FSDP_PARAM_BYTES:
        axes = sh.zero_axes_tree(axes, values_sds, mesh, rules)
        param_sh = _shardings(axes, values_sds, mesh, rules)
    specs, in_axes = m.input_specs(shape)
    batch_sh = _shardings(in_axes, specs, mesh, rules)

    if shape.kind == "train":
        opt = optimizers.adamw(schedules.constant(1e-4))
        opt_sds = jax.eval_shape(opt.init, values_sds)
        zaxes = sh.zero_axes_tree(axes, values_sds, mesh, rules)
        opt_axes = {
            "step": (),
            "master": zaxes,
            "m": zaxes,
            "v": zaxes,
        }
        opt_sh = _shardings(opt_axes, opt_sds, mesh, rules)
        step = make_train_step(m.loss, opt, microbatches=microbatches)
        fn = jax.jit(step,
                     in_shardings=(param_sh, opt_sh, batch_sh),
                     out_shardings=(param_sh, opt_sh, None),
                     donate_argnums=(0, 1))
        args = (values_sds, opt_sds, specs)
    elif shape.kind == "prefill":
        def prefill_fn(values, batch):
            return m.prefill(values, batch, max_seq=_prefill_len(cfg, shape))
        fn = jax.jit(prefill_fn, in_shardings=(param_sh, batch_sh))
        args = (values_sds, specs)
    elif shape.kind == "decode":
        cache_sds = specs["cache"]
        cache_sh = _shardings(in_axes["cache"], cache_sds, mesh, rules)
        fn = jax.jit(m.decode_step,
                     in_shardings=(param_sh, batch_sh["token"],
                                   batch_sh["positions"], cache_sh),
                     out_shardings=(None, cache_sh),
                     donate_argnums=(3,))
        args = (values_sds, specs["token"], specs["positions"], cache_sds)
    else:
        raise ValueError(shape.kind)
    return fn, args, cfg, rules


def _prefill_len(cfg, shape):
    if cfg.encoder_decoder:
        return min(M.WHISPER_DECODER_LEN, shape.seq_len)
    return shape.seq_len


def _memory_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:                                  # CPU backend gaps
        return {"error": str(e)}
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
        val = getattr(ma, field, None)
        if val is not None:
            out[field] = int(val)
    if not out:
        out["repr"] = repr(ma)
    return out


def _cost_dict(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    keep = {}
    for k, v in dict(ca).items():
        if k in ("flops", "bytes accessed", "transcendentals",
                 "optimal_seconds") or k.startswith("bytes accessed"):
            keep[k] = float(v)
    return keep


def _compile_and_measure(arch, shape_name, mesh, tp_fusion, overrides,
                         save_hlo=None):
    t0 = time.time()
    fn, args, cfg, rules = build_cell(arch, shape_name, mesh, tp_fusion,
                                      overrides)
    with sh.use_mesh(mesh, rules):
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    cost = _cost_dict(compiled)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = hlo_analysis.parse_collectives(hlo, default_group=16)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    return {
        "cfg": cfg,
        "compiled": compiled,
        "cost": cost,
        "coll": coll,
        "t_lower": t_lower,
        "t_compile": t_compile,
    }


def _scaled_variants(cfg, microbatches: int
                     ) -> Optional[Dict[str, Any]]:
    """Scan-cost extrapolation variants.

    XLA's cost_analysis counts a while-loop (lax.scan) body ONCE regardless
    of trip count (verified empirically), so both the layer scan and the
    microbatch-accumulation scan under-report.  We lower the cell with
      B: 1 period,  unrolled layers, microbatches=1 (full batch in one shot)
      C: 2 periods, unrolled layers, microbatches=1
    and apply the two-point rule per metric (period clamped at >= 0):
      true = B + (n_periods - 1) * (C - B)
    Because every per-step cost (FLOPs, HBM bytes, collective payloads) is
    linear in the batch dimension, gradient accumulation does not change the
    per-step total — lowering the variants at microbatches=1 with the full
    batch makes the whole step visible to cost_analysis, which is all the
    correction the accumulation scan needs.  Encoder stacks (whisper) scale
    alongside — their trip count equals the decoder's.
    """
    period = cfg.period
    n = cfg.n_periods
    if n <= 1 and not cfg.encoder_decoder and microbatches == 1:
        return None
    enc1 = len(cfg.encoder_layer_plan()) if cfg.encoder_decoder else 0
    over_b = {"n_layers": period, "scan_layers": False, "microbatches": 1}
    over_c = {"n_layers": 2 * period, "scan_layers": False,
              "microbatches": 1}
    if cfg.encoder_decoder:
        n_enc = cfg.n_encoder_layers // enc1
        assert n_enc == n, "enc/dec trip counts must match for extrapolation"
        over_b["n_encoder_layers"] = enc1
        over_c["n_encoder_layers"] = 2 * enc1
    return {"b": over_b, "c": over_c, "n_periods": n,
            "microbatches": microbatches}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             tp_fusion: str = "max",
             overrides: Optional[Dict[str, Any]] = None,
             save_hlo: Optional[str] = None,
             extrapolate: bool = True) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    cfg_probe = get_config(arch)
    ok, why = shape_applicable(cfg_probe, shape)
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "tp_fusion": tp_fusion,
    }
    if not ok:
        record["status"] = "skipped"
        record["reason"] = why
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    try:
        full = _compile_and_measure(arch, shape_name, mesh, tp_fusion,
                                    overrides, save_hlo=save_hlo)
        cfg = full["cfg"]
        mem = _memory_dict(full["compiled"])
        flops = full["cost"].get("flops", 0.0)
        hbm_bytes = full["cost"].get("bytes accessed", 0.0)
        link_bytes = full["coll"].link_bytes
        extrap_info = None

        cell_mb = (overrides or {}).get(
            "microbatches",
            TRAIN_MICROBATCHES.get(arch, 1)
            if shape_name == "train_4k" else 1)
        variants = (_scaled_variants(cfg, cell_mb) if extrapolate else None)
        if variants is not None:
            ov = dict(overrides or {})
            ov.pop("microbatches", None)
            b = _compile_and_measure(arch, shape_name, mesh, tp_fusion,
                                     {**ov, **variants["b"]})
            c = _compile_and_measure(arch, shape_name, mesh, tp_fusion,
                                     {**ov, **variants["c"]})
            n = variants["n_periods"]

            def metric(rec, key):
                if key == "link":
                    return rec["coll"].link_bytes
                return rec["cost"].get(key, 0.0)

            def extrap(key):
                vb, vc = metric(b, key), metric(c, key)
                return vb + (n - 1) * max(vc - vb, 0.0)

            flops = extrap("flops")
            hbm_bytes = extrap("bytes accessed")
            link_bytes = extrap("link")
            extrap_info = {
                "n_periods": n,
                "microbatches": variants["microbatches"],
                "period_flops": metric(c, "flops") - metric(b, "flops"),
                "period_link_bytes": metric(c, "link") - metric(b, "link"),
                "collective_counts_2p": c["coll"].counts,
            }

        terms = hlo_analysis.roofline_terms(flops, hbm_bytes, link_bytes)
        model_flops = _model_flops(cfg, shape)
        record.update({
            "status": "ok",
            "lower_s": round(full["t_lower"], 1),
            "compile_s": round(full["t_compile"], 1),
            "n_chips": n_chips,
            "memory": mem,
            "cost_raw_scanned": full["cost"],
            "flops_per_dev": flops,
            "hbm_bytes_per_dev": hbm_bytes,
            "collectives": {
                "counts": full["coll"].counts,
                "payload_bytes": full["coll"].payload_bytes,
                "link_bytes_per_dev": link_bytes,
            },
            "extrapolation": extrap_info,
            "roofline": terms,
            "model_flops_global": model_flops,
            "useful_flops_ratio": (
                model_flops / (flops * n_chips) if flops else None),
            "params": cfg.param_count(),
            "params_active": cfg.param_count(active_only=True),
        })
    except Exception as e:
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc(limit=20)
    return record


def _model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D per generated/prefilled token."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch          # one token per sequence
    return 2.0 * n_active * tokens


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS)
    ap.add_argument("--shape", default=None, choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--fusion", default="max",
                    help="tp_fusion mode (paper technique = max)")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-extrapolate", action="store_true",
                    help="skip the 1p/2p scan-cost extrapolation "
                         "(multi-pod compile-proof cells)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}__{args.fusion}"
            # multi-pod cells prove sharding/compile; roofline is single-pod
            extrap = not (args.no_extrapolate or mp)
            rec = run_cell(arch, shape, mp, tp_fusion=args.fusion,
                           extrapolate=extrap)
            path = os.path.join(args.out, tag + ".json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            status = rec["status"]
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (f" lower={rec['lower_s']}s compile={rec['compile_s']}s "
                         f"bottleneck={r['bottleneck']} "
                         f"tc={r['t_compute_s']:.3e} tm={r['t_memory_s']:.3e} "
                         f"tl={r['t_collective_s']:.3e}")
            elif status == "error":
                extra = " " + rec["error"][:200]
            print(f"[{status:7s}] {tag}{extra}", flush=True)


if __name__ == "__main__":
    main()
