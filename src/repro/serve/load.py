"""Load generation for the serving engine: Poisson arrivals + near/far
channel mixes.

The traffic model the serving bench drives: request arrivals are a Poisson
process over the engine's discrete tick clock (exponential inter-arrival
gaps accumulated and floored to ticks), and the wireless side is the
heterogeneous near/far cell of ``repro.sim.scenarios.near_far_p_miss`` —
cell-center workers sense cleanly, cell-edge workers miss blocking signals
more often — bound as the per-worker ``p_miss`` leaf of one OCS
:class:`~repro.protocol.Protocol`.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.protocol import Protocol
from repro.serve.engine import Request
from repro.sim.scenarios import near_far_p_miss


def poisson_requests(n_requests: int, rate_per_tick: float,
                     vocab_size: int, prompt_len: int = 8,
                     max_new_tokens: int = 16, seed: int = 0,
                     ) -> List[Request]:
    """Sample a Poisson request stream over the engine's tick clock.

    ``rate_per_tick`` is the mean arrival rate lambda (requests per decode
    tick); inter-arrival gaps are iid Exponential(1/lambda), accumulated
    and floored to integer ``arrival_tick``s (so bursts land on one tick).
    Prompts are uniform random token ids — the serving benches measure the
    engine, not the language model.  Deterministic in ``seed``.
    """
    if n_requests < 1:
        raise ValueError("n_requests must be >= 1")
    if rate_per_tick <= 0:
        raise ValueError("rate_per_tick must be > 0")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_per_tick, n_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(np.int64)
    return [
        Request(rid=i,
                prompt=rng.integers(0, vocab_size,
                                    prompt_len).astype(np.int32),
                max_new_tokens=max_new_tokens,
                arrival_tick=int(arrivals[i]))
        for i in range(n_requests)
    ]


def near_far_protocol(n_workers: int, bits: int = 8,
                      p_near: float = 0.0, p_far: float = 0.1,
                      max_rounds: int = 3, backend: str = "scan",
                      n_channels: int = 1,
                      payload_bits: Optional[int] = None) -> Protocol:
    """An OCS protocol whose per-worker ``p_miss`` leaf is the two-tier
    near/far profile (first half cell-center at ``p_near``, second half
    cell-edge at ``p_far``)."""
    p = np.asarray(near_far_p_miss(n_workers, p_near, p_far), np.float32)
    return Protocol.ocs(bits=bits, p_miss=p, max_rounds=max_rounds,
                        backend=backend, n_channels=n_channels,
                        payload_bits=payload_bits)
