"""Channel-in-the-loop serving: slot-based continuous batching with the
wireless aggregation protocol inside the decode tick.

A fixed budget of B slots decodes in lock-step.  Each tick is ONE fused
jitted dispatch — decode through the stack (optionally aggregating every
mlp-FFN worker fusion through a simulated :class:`repro.protocol.Protocol`
channel), next-token selection (greedy argmax or categorical sampling) and
the position increment all live inside the same compiled program, and the
protocol rides in as a traced pytree argument so rebinding ``p_miss``
(e.g. sweeping channel quality) never recompiles.  Finished slots (EOS or
length cap) retire and refill from the arrival queue by running a
single-request prefill and scattering its KV cache into the batch cache at
the slot index — the standard continuous-batching structure, minus
speculative/paged refinements.

Airtime accounting: the contention core measures the channel slots each
tick actually consumed (``ProtocolAccounting`` summed over the stack's
:func:`repro.models.model.channel_sites`), and a :class:`ChannelClock`
converts ticks + slots to wall time, so every :class:`Completion` carries
its end-to-end latency decomposed into compute ticks vs channel slots.

Dispatch/trace counters mirror ``repro.sim.train_curves``:
``dispatch_counts()["tick"]`` counts host->device decode-tick dispatches
(exactly one per tick — self-checked by ``benchmarks/bench_serve.py``) and
``trace_counts()["tick"]`` counts compilations of the fused tick.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import faults
from repro.protocol import Protocol

_TRACE_COUNTS = {"tick": 0}
_DISPATCH_COUNTS = {"tick": 0}


def trace_counts() -> Dict[str, int]:
    return dict(_TRACE_COUNTS)


def dispatch_counts() -> Dict[str, int]:
    return dict(_DISPATCH_COUNTS)


def reset_trace_counts() -> None:
    _TRACE_COUNTS["tick"] = 0


def reset_dispatch_counts() -> None:
    _DISPATCH_COUNTS["tick"] = 0


@dataclasses.dataclass(frozen=True)
class ChannelClock:
    """Converts the engine's discrete accounting to wall time.

    ``tick_us`` is the compute cost of one lock-step decode tick (the
    forward pass over all B slots); ``slot_us`` the airtime of one channel
    sub-slot (contention bit-slots and payload bits are both billed in
    ``ProtocolAccounting.contention_slots`` units by the contention core).
    """

    tick_us: float = 50.0
    slot_us: float = 1.0

    def __post_init__(self):
        if self.tick_us <= 0 or self.slot_us <= 0:
            raise ValueError("ChannelClock times must be positive")

    def latency_us(self, ticks: int, slots: int) -> float:
        return ticks * self.tick_us + slots * self.slot_us


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Typed serving surface (replaces ``ServeEngine``'s kwarg pile).

    ``protocol=None`` keeps serving channel-free (the zero-cost default:
    the decode tick runs the exact historical ops).  An OCS protocol must
    carry a bound ``p_miss``; per-run overrides go through
    ``ServeEngine.run(requests, protocol=...)`` which rebinds only the
    traced leaf, so a quality sweep never recompiles.
    """

    batch_slots: int = 4
    max_seq: int = 128
    eos_id: int = 1
    greedy: bool = True
    protocol: Optional[Protocol] = None
    fault: Optional[faults.FaultModel] = None
    clock: ChannelClock = dataclasses.field(default_factory=ChannelClock)
    seed: int = 0

    def __post_init__(self):
        if self.batch_slots < 1:
            raise ValueError("batch_slots must be >= 1")
        if self.max_seq < 2:
            raise ValueError("max_seq must be >= 2")
        if self.protocol is not None and self.protocol.kind == "concat":
            raise ValueError(
                "concat protocols cannot serve in-block fusion (the fused "
                "width N*K does not match the residual width K)")
        if self.fault is not None and self.protocol is None:
            raise ValueError(
                "fault injection needs a channel protocol (fault models "
                "perturb the sensing channel; channel-free serving has "
                "no channel to fault)")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 32
    arrival_tick: int = 0        # Poisson load generators set this


@dataclasses.dataclass
class Completion:
    """One served request, self-describing under the channel budget.

    ``latency_ticks`` spans arrival to retirement inclusive (queue wait
    included); ``channel_slots`` is the measured contention+payload airtime
    the shared channel consumed over that span; ``uplink_bits`` the
    analytic per-request uplink (``Protocol.comm_load`` per aggregate call
    x channel sites x channel-decoded tokens).  All three are 0 for
    channel-free serving.

    Under fault injection (``ServeConfig.fault``) two degradation counters
    ride along: ``degraded_tokens`` counts tokens this request emitted on
    outage ticks (every worker offline — the degrade policy substituted a
    filler instead of wedging the FIFO), and ``retry_ticks`` counts ticks
    the whole batch stalled re-contending under the ``retry`` policy.
    """

    rid: int
    tokens: List[int]
    prompt_len: int
    latency_ticks: int = 0
    channel_slots: int = 0
    uplink_bits: int = 0
    degraded_tokens: int = 0
    retry_ticks: int = 0

    def latency_us(self, clock: ChannelClock) -> float:
        return clock.latency_us(self.latency_ticks, self.channel_slots)


_UNSET = object()


class ServeEngine:
    """Slot-batched serving engine over an optional simulated channel.

    One engine instance holds ONE compiled tick per protocol *structure*
    (channel-free, or one per protocol treedef); sweeping ``p_miss``
    through ``run(requests, protocol=...)`` reuses the compiled tick.
    """

    def __init__(self, model, values, config: ServeConfig):
        self.m = model
        self.values = values
        self.config = config
        self.B = config.batch_slots
        self.max_seq = config.max_seq
        self.eos = config.eos_id
        cfg = model.cfg
        self._sites = model.channel_sites()
        self._bits_per_site = {}      # protocol id -> analytic uplink bits
        self.cache = model.cache_init(self.B, self.max_seq)
        self.positions = jnp.zeros((self.B,), jnp.int32)
        self.cur_token = jnp.zeros((self.B, 1), jnp.int32)
        self.active = np.zeros((self.B,), bool)
        self.budget = np.zeros((self.B,), np.int64)
        self.slot_req: List[Optional[Request]] = [None] * self.B
        self.outputs: Dict[int, Completion] = {}

        base_key = jax.random.PRNGKey(config.seed)
        sample_key = jax.random.fold_in(base_key, 0x5A)

        def _tick(v, protocol, fault, fstate, cur_token, positions, cache,
                  tick):
            _TRACE_COUNTS["tick"] += 1
            if protocol is None:
                logits, new_cache = model.decode_step(v, cur_token,
                                                      positions, cache)
                chan = None
            elif fault is None:
                rng = jax.random.fold_in(base_key, tick)
                logits, new_cache, chan = model.decode_step_channel(
                    v, cur_token, positions, cache, protocol, rng)
            else:
                # evolve the Gilbert-Elliott sensing chain + dropout spans
                # one step per tick, then rebind the protocol's traced
                # leaves -- fault parameters never recompile the tick
                rng = jax.random.fold_in(base_key, tick)
                new_bad, new_offline = faults.step_chains(fault, fstate, rng)
                online = ~new_offline
                proto_f = protocol.with_p_miss(
                    faults.effective_p_miss(fault, new_bad)
                ).with_online(online)
                logits, new_cache, chan = model.decode_step_channel(
                    v, cur_token, positions, cache, proto_f, rng)
            if config.greedy:
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            else:
                nxt = jax.random.categorical(
                    jax.random.fold_in(sample_key, tick),
                    logits).astype(jnp.int32)
            if fault is None:
                return nxt, positions + 1, new_cache, chan, fstate, None
            # Degrade instead of wedging: on an outage tick (every worker
            # offline) the pooled fusions resolved nothing, so the decode
            # output is garbage -- the policy decides what the slots emit.
            ok = jnp.any(online)
            consec = jnp.where(ok, jnp.int32(0),
                               fstate.consec + jnp.int32(1))
            age = jnp.where(ok, jnp.int32(0), fstate.age + jnp.int32(1))
            kind = fault.policy.kind                     # static meta
            if kind == "retry":
                retrying = (~ok) & (
                    consec <= jnp.int32(fault.policy.retry_budget))
            else:
                retrying = jnp.bool_(False)
            if kind == "stale":
                deg_tok = cur_token[:, 0]     # repeat the last token
            else:                             # zero_fill / exhausted retry
                deg_tok = jnp.zeros_like(nxt)
            nxt = jnp.where(ok, nxt, deg_tok)
            # a retry tick makes no progress: token/positions/cache hold
            # while the chain re-contends (airtime still billed via chan)
            commit = ok | ~retrying
            nxt = jnp.where(commit, nxt, cur_token[:, 0])
            new_positions = jnp.where(commit, positions + 1, positions)
            new_cache = jax.tree.map(
                lambda nc, oc: jnp.where(commit, nc, oc), new_cache, cache)
            new_fstate = dataclasses.replace(
                fstate, bad=new_bad, offline=new_offline, age=age,
                consec=consec)
            flags = {"ok": ok, "retrying": retrying}
            return nxt, new_positions, new_cache, chan, new_fstate, flags

        self._tick = jax.jit(_tick)
        self._prefill = jax.jit(
            lambda v, b: model.prefill(v, b, max_seq=self.max_seq))
        self._d_model = cfg.d_model
        self._n_workers = cfg.n_workers

    # -- analytic uplink accounting ----------------------------------------

    def _uplink_bits_per_tick(self, protocol: Optional[Protocol]) -> int:
        """Per-slot analytic uplink bits of one channel-decoded token."""
        if protocol is None:
            return 0
        key = dataclasses.replace(protocol, p_miss=None)  # static meta only
        if key not in self._bits_per_site:
            load = protocol.comm_load(self._n_workers, self._d_model)
            self._bits_per_site[key] = load.uplink_bits * self._sites
        return self._bits_per_site[key]

    # -- slot management ----------------------------------------------------

    def _reset(self) -> None:
        """Clear slot state between runs (the cache is reused: a prefill
        scatter overwrites a slot's rows end to end before it activates)."""
        self.positions = jnp.zeros((self.B,), jnp.int32)
        self.cur_token = jnp.zeros((self.B, 1), jnp.int32)
        self.active[:] = False
        self.budget[:] = 0
        self.slot_req = [None] * self.B
        self.outputs = {}

    def _insert(self, slot: int, req: Request):
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache1 = self._prefill(self.values, {"tokens": tokens})
        # scatter the single-request cache into the batch cache at `slot`
        def put(batch_leaf, one_leaf):
            # find the batch axis: the axis where sizes differ (B vs 1)
            axis = _batch_axis(batch_leaf.shape, one_leaf.shape, self.B)
            idx = [slice(None)] * batch_leaf.ndim
            idx[axis] = slice(slot, slot + 1)
            return batch_leaf.at[tuple(idx)].set(
                one_leaf.astype(batch_leaf.dtype))

        self.cache = jax.tree.map(put, self.cache, cache1)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[0]
        self.cur_token = self.cur_token.at[slot, 0].set(tok)
        self.positions = self.positions.at[slot].set(len(req.prompt))
        self.active[slot] = True
        self.budget[slot] = req.max_new_tokens - 1
        self.slot_req[slot] = req
        self.outputs[req.rid] = Completion(
            rid=req.rid, tokens=[int(tok)], prompt_len=len(req.prompt))

    def _retire(self, slot: int):
        self.active[slot] = False
        self.slot_req[slot] = None

    # -- main loop ----------------------------------------------------------

    def run(self, requests: List[Request],
            protocol=_UNSET, fault=_UNSET) -> Dict[int, Completion]:
        """Serve ``requests`` to completion; returns ``{rid: Completion}``.

        Requests are admitted FIFO by ``arrival_tick`` (ties keep
        submission order); with no slot free and no arrival due, the tick
        counter fast-forwards to the next arrival instead of dispatching
        empty decode ticks.  ``protocol`` overrides the config's (pass
        ``None`` for an explicitly channel-free run) — only the traced
        ``p_miss`` leaf differs between runs of equal structure, so the
        compiled tick is reused.  ``fault`` likewise overrides
        ``config.fault`` (a ``repro.faults.FaultModel``): bursty sensing
        fades and worker outages then ride the decode tick, with outage
        ticks *degrading* completions per the model's policy instead of
        wedging the FIFO — every fault parameter is a traced leaf, so a
        fault sweep reuses the compiled tick too.
        """
        proto = self.config.protocol if protocol is _UNSET else protocol
        fm = self.config.fault if fault is _UNSET else fault
        if fm is not None and proto is None:
            raise ValueError("fault injection needs a channel protocol")
        fstate = (faults.init_state(self._n_workers)
                  if fm is not None else None)
        bits_per_tok = self._uplink_bits_per_tick(proto)
        self._reset()
        pending = sorted(requests, key=lambda r: r.arrival_tick)
        admissible: List[Request] = []
        tick = 0
        total_slots = 0                       # cumulative measured airtime
        slots_at_arrival: Dict[int, int] = {}
        arrival_of: Dict[int, int] = {}
        while pending or admissible or self.active.any():
            while pending and pending[0].arrival_tick <= tick:
                r = pending.pop(0)
                admissible.append(r)
                slots_at_arrival[r.rid] = total_slots
                arrival_of[r.rid] = r.arrival_tick
            if not self.active.any() and not admissible:
                tick = pending[0].arrival_tick   # idle: jump to next arrival
                continue
            for slot in range(self.B):
                if not self.active[slot] and admissible:
                    self._insert(slot, admissible.pop(0))
            _DISPATCH_COUNTS["tick"] += 1
            nxt, self.positions, self.cache, chan, fstate, flags = \
                self._tick(self.values, proto, fm, fstate, self.cur_token,
                           self.positions, self.cache, jnp.int32(tick))
            self.cur_token = nxt[:, None]
            tick += 1
            if chan is not None:
                total_slots += int(chan["contention_slots"])
            if flags is not None and bool(flags["retrying"]):
                # retry tick: the batch held position re-contending; bill
                # the stall against every in-flight request and move on
                for slot in range(self.B):
                    if self.active[slot]:
                        self.outputs[self.slot_req[slot].rid].retry_ticks \
                            += 1
                continue
            degraded = flags is not None and not bool(flags["ok"])
            nxt_np = np.asarray(nxt)
            for slot in range(self.B):
                if not self.active[slot]:
                    continue
                req = self.slot_req[slot]
                out = self.outputs[req.rid]
                out.tokens.append(int(nxt_np[slot]))
                out.uplink_bits += bits_per_tok
                if degraded:
                    out.degraded_tokens += 1
                self.budget[slot] -= 1
                done = (int(nxt_np[slot]) == self.eos
                        or self.budget[slot] <= 0
                        or int(self.positions[slot]) >= self.max_seq - 1)
                if done:
                    out.latency_ticks = tick - arrival_of[req.rid]
                    out.channel_slots = (
                        total_slots - slots_at_arrival[req.rid])
                    self._retire(slot)
        return self.outputs


def _batch_axis(batch_shape, one_shape, b: int) -> int:
    for i, (bs, os) in enumerate(zip(batch_shape, one_shape)):
        if bs == b and os == 1:
            return i
    # fall back: first axis of size B
    for i, bs in enumerate(batch_shape):
        if bs == b:
            return i
    raise ValueError(f"no batch axis in {batch_shape} vs {one_shape}")
