"""Batched serving engine: prefill + decode with slot-based continuous
batching (lite).

A fixed budget of B slots decodes in lock-step (one jitted ``decode_step``
per tick over the whole batch).  Finished slots (EOS or length cap) retire
and are refilled from the request queue by running a single-request prefill
and scattering its KV cache into the batch cache at the slot index — the
standard continuous-batching structure, minus speculative/paged refinements.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 32


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: List[int]
    prompt_len: int


class ServeEngine:
    def __init__(self, model, values, batch_slots: int, max_seq: int,
                 eos_id: int = 1, greedy: bool = True):
        self.m = model
        self.values = values
        self.B = batch_slots
        self.max_seq = max_seq
        self.eos = eos_id
        cfg = model.cfg
        self.cache = model.cache_init(batch_slots, max_seq)
        self.positions = jnp.zeros((batch_slots,), jnp.int32)
        self.cur_token = jnp.zeros((batch_slots, 1), jnp.int32)
        self.active = np.zeros((batch_slots,), bool)
        self.budget = np.zeros((batch_slots,), np.int64)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.outputs: Dict[int, Completion] = {}

        self._decode = jax.jit(model.decode_step)
        self._prefill = jax.jit(
            lambda v, b: model.prefill(v, b, max_seq=max_seq))

    # -- slot management ----------------------------------------------------

    def _insert(self, slot: int, req: Request):
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache1 = self._prefill(self.values, {"tokens": tokens})
        # scatter the single-request cache into the batch cache at `slot`
        def put(batch_leaf, one_leaf):
            # find the batch axis: the axis where sizes differ (B vs 1)
            axis = _batch_axis(batch_leaf.shape, one_leaf.shape, self.B)
            idx = [slice(None)] * batch_leaf.ndim
            idx[axis] = slice(slot, slot + 1)
            return batch_leaf.at[tuple(idx)].set(
                one_leaf.astype(batch_leaf.dtype))

        self.cache = jax.tree.map(put, self.cache, cache1)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[0]
        self.cur_token = self.cur_token.at[slot, 0].set(tok)
        self.positions = self.positions.at[slot].set(len(req.prompt))
        self.active[slot] = True
        self.budget[slot] = req.max_new_tokens - 1
        self.slot_req[slot] = req
        self.outputs[req.rid] = Completion(
            rid=req.rid, tokens=[int(tok)], prompt_len=len(req.prompt))

    def _retire(self, slot: int):
        self.active[slot] = False
        self.slot_req[slot] = None

    # -- main loop ----------------------------------------------------------

    def run(self, requests: List[Request]) -> Dict[int, Completion]:
        queue = list(requests)
        while queue or self.active.any():
            for slot in range(self.B):
                if not self.active[slot] and queue:
                    self._insert(slot, queue.pop(0))
            logits, self.cache = self._decode(
                self.values, self.cur_token, self.positions, self.cache)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)     # (B,)
            self.cur_token = nxt[:, None]
            self.positions = self.positions + 1
            nxt_np = np.asarray(nxt)
            for slot in range(self.B):
                if not self.active[slot]:
                    continue
                req = self.slot_req[slot]
                self.outputs[req.rid].tokens.append(int(nxt_np[slot]))
                self.budget[slot] -= 1
                done = (int(nxt_np[slot]) == self.eos
                        or self.budget[slot] <= 0
                        or int(self.positions[slot]) >= self.max_seq - 1)
                if done:
                    self._retire(slot)
        return self.outputs


def _batch_axis(batch_shape, one_shape, b: int) -> int:
    for i, (bs, os) in enumerate(zip(batch_shape, one_shape)):
        if bs == b and os == 1:
            return i
    # fall back: first axis of size B
    for i, bs in enumerate(batch_shape):
        if bs == b:
            return i
    raise ValueError(f"no batch axis in {batch_shape} vs {one_shape}")
