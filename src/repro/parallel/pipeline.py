"""Pipeline parallelism: GPipe-style microbatched schedule over a ``stage``
mesh axis, realized with ``shard_map`` + ``ppermute``.

Off by default in the 40-cell sweep (the assigned production mesh has no
stage axis); provided — and covered by ``tests/test_pipeline.py`` on a forced
multi-device host — as the depth-parallel option for 1000+-node deployments
where (pod, data, model) alone leaves layers too deep for one stage's HBM.

Schedule: ``n_micro + n_stages - 1`` ticks; at tick t, stage s processes
microbatch ``t - s`` (bubble fraction ``(S-1)/(M+S-1)``).  Activations hop
stages via ``collective_permute``; autodiff through the whole schedule gives
the matching 1F1B-equivalent backward (bubbles included) for training.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def gpipe(stage_fn: Callable, mesh, axis: str = "stage"):
    """Build a pipelined apply: (stage_params, x_micro) -> y_micro.

    stage_fn(params, x) -> y is ONE stage's computation (same shape in/out).
    stage_params: leaves with leading stage axis, sharded over `axis`.
    x_micro: (n_micro, mb, ...) — microbatched input, replicated.
    """
    n_stages = mesh.shape[axis]

    def pipelined(stage_params, x_micro):
        n_micro = x_micro.shape[0]
        steps = n_micro + n_stages - 1

        def body(carry, t):
            # carry: (incoming activation buffer (mb, ...), outputs (n_micro, mb, ...))
            acts, outs = carry
            s = jax.lax.axis_index(axis)
            # stage 0 ingests microbatch t (when available); others use the
            # activation that arrived from stage s-1 last tick
            feed = jnp.where(t < n_micro, t, 0)
            inp = jnp.where(s == 0, x_micro[feed], acts)
            out = stage_fn(stage_params, inp)
            # last stage commits microbatch (t - (n_stages-1)) when valid
            mb_idx = t - (n_stages - 1)
            valid = jnp.logical_and(s == n_stages - 1, mb_idx >= 0)
            outs = jax.lax.cond(
                valid,
                lambda o: o.at[jnp.maximum(mb_idx, 0)].set(out),
                lambda o: o,
                outs)
            # shift activations one stage forward
            perm = [(i, i + 1) for i in range(n_stages - 1)]
            acts = jax.lax.ppermute(out, axis, perm)
            return (acts, outs), None

        acts0 = jnp.zeros_like(x_micro[0])
        outs0 = jnp.zeros_like(x_micro)
        (_, outs), _ = jax.lax.scan(body, (acts0, outs0),
                                    jnp.arange(steps))
        # only the last stage holds the committed outputs; broadcast them
        # so the replicated out_spec is well-defined on every shard
        return jax.lax.psum(outs, axis)

    # stage params sharded over `axis` (leading dim == n_stages, local slice
    # squeezed inside), activations replicated
    def stage_local(params, x_micro):
        params_local = jax.tree.map(lambda p: p[0], params)
        return pipelined(params_local, x_micro)

    kwargs = dict(mesh=mesh, in_specs=(P(axis), P()), out_specs=P())
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:            # jax<0.6: experimental namespace,
        from jax.experimental.shard_map import shard_map
        kwargs["check_rep"] = False  # replication check kwarg predates
    else:                            # its rename to check_vma
        kwargs["check_vma"] = False
    return shard_map(stage_local, **kwargs)


def sequential_reference(stage_fn: Callable, stage_params, x_micro):
    """Oracle: run the stages back-to-back without pipelining."""
    def one_micro(x):
        n_stages = jax.tree.leaves(stage_params)[0].shape[0]
        for s in range(n_stages):
            p = jax.tree.map(lambda q: q[s], stage_params)
            x = stage_fn(p, x)
        return x
    return jax.vmap(one_micro)(x_micro)
