"""Logical-axis sharding substrate (MaxText-style rules).

Every parameter is created as a :class:`Tagged` leaf carrying its logical axis
names; :func:`split_tree` separates the value tree (fed to jit) from the axes
tree (turned into ``NamedSharding``s via :data:`DEFAULT_RULES`).  Activation
sharding is asserted with :func:`constrain`, which is a no-op unless a mesh
context has been installed (so single-device smoke tests run untouched code).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes). Axes absent from the
# active mesh are dropped at resolution time, so one rule table serves the
# single-pod (data, model) and multi-pod (pod, data, model) meshes.
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "worker": "model",        # FedOCS worker axis == TP shard axis
    "heads": "model",
    "kv_heads": "model",
    "experts": "model",
    "vocab": "model",
    "ff": "model",
    "embed": None,
    "ff_local": None,
    "seq": None,
    "kv_seq": "data",         # sequence-parallel KV cache (long-context decode)
    "layers": None,
    "conv": None,
    "state": None,
    "fsdp": ("pod", "data"),  # ZeRO axis for optimizer state / master weights
    None: None,
}


class Tagged:
    """A parameter value bundled with its logical axis names.

    Registered as a pytree node so inits can be ``vmap``-ed to build stacked
    per-layer parameters (the aux data — axes — must then be identical across
    the mapped instances, which holds by construction).  Rank may temporarily
    disagree with ``axes`` inside such transforms; :func:`retag_stacked`
    prepends the ``layers`` axis afterwards.
    """

    __slots__ = ("value", "axes")

    def __init__(self, value: Any, axes: Sequence[Optional[str]]):
        self.value = value
        self.axes = tuple(axes)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"Tagged(shape={shape}, axes={self.axes})"


def _tagged_flatten(t: Tagged):
    return (t.value,), t.axes


def _tagged_unflatten(axes, children):
    return Tagged(children[0], axes)


jax.tree_util.register_pytree_node(Tagged, _tagged_flatten, _tagged_unflatten)


def retag_stacked(tree, lead_axis: str = "layers"):
    """Prepend a leading logical axis to every Tagged leaf (post-vmap init)."""
    return jax.tree.map(
        lambda t: Tagged(t.value, (lead_axis,) + t.axes), tree,
        is_leaf=_is_tagged)


def _is_tagged(x) -> bool:
    return isinstance(x, Tagged)


def split_tree(tree):
    """tagged tree -> (value tree, axes tree) with identical structure."""
    values = jax.tree.map(lambda t: t.value, tree, is_leaf=_is_tagged)
    axes = jax.tree.map(lambda t: t.axes, tree, is_leaf=_is_tagged)
    return values, axes


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_axes(logical_axes: Sequence[Optional[str]], mesh: Mesh,
                 rules: dict = DEFAULT_RULES) -> P:
    """logical axis names -> PartitionSpec valid on `mesh`."""
    names = set(mesh.axis_names)
    spec = []
    for ax in logical_axes:
        mapped = rules.get(ax, None)
        if mapped is None:
            spec.append(None)
            continue
        if isinstance(mapped, str):
            mapped = (mapped,)
        present = tuple(m for m in mapped if m in names)
        if not present:
            spec.append(None)
        elif len(present) == 1:
            spec.append(present[0])
        else:
            spec.append(present)
    return P(*spec)


def sharding_for(logical_axes, mesh: Mesh, rules: dict = DEFAULT_RULES
                 ) -> NamedSharding:
    return NamedSharding(mesh, resolve_axes(logical_axes, mesh, rules))


def sharding_for_shape(logical_axes, shape, mesh: Mesh,
                       rules: dict = DEFAULT_RULES) -> NamedSharding:
    """Like :func:`sharding_for`, but drops (replicates) any axis whose
    dimension is not divisible by its mesh extent — required for jit
    *argument* shardings (e.g. 36 attention heads or a 122753 vocab over a
    16-way axis; GSPMD pads internal values but arguments must be even)."""
    sizes = mesh_axis_sizes(mesh)
    base = resolve_axes(logical_axes, mesh, rules)
    spec = []
    for entry, dim in zip(tuple(base), tuple(shape)):
        if entry is None:
            spec.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        ways = 1
        for nm in names:
            ways *= sizes[nm]
        spec.append(entry if dim % ways == 0 else None)
    return NamedSharding(mesh, P(*spec))


def tree_shardings_for_values(axes_tree, values_tree, mesh: Mesh,
                              rules: dict = DEFAULT_RULES):
    """Per-leaf shape-aware shardings (axes_tree zipped with value shapes)."""
    def is_axes_leaf(x):
        return isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x)

    return jax.tree.map(
        lambda ax, v: sharding_for_shape(ax, v.shape, mesh, rules),
        axes_tree, values_tree, is_leaf=is_axes_leaf)


def tree_shardings(axes_tree, mesh: Mesh, rules: dict = DEFAULT_RULES):
    return jax.tree.map(
        lambda axes: sharding_for(axes, mesh, rules),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )


# ---------------------------------------------------------------------------
# activation constraints — thread-local mesh context
# ---------------------------------------------------------------------------

class _MeshCtx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: dict = DEFAULT_RULES


_CTX = _MeshCtx()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: dict = DEFAULT_RULES):
    """Install a mesh for activation constraints (and jax's global mesh)."""
    prev_mesh, prev_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev_mesh, prev_rules


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """Assert activation sharding; no-op when no mesh context is installed."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, sharding_for(logical_axes, mesh, _CTX.rules))


# ---------------------------------------------------------------------------
# ZeRO-1 optimizer-state sharding: add the fsdp axis to the largest
# unsharded-and-divisible dimension of each parameter.
# ---------------------------------------------------------------------------

def _resolves_unsharded(ax, mesh_names, rules) -> bool:
    """True if this logical axis maps to no axis of the active mesh."""
    mapped = rules.get(ax, None)
    if mapped is None:
        return True
    if isinstance(mapped, str):
        mapped = (mapped,)
    return not any(m in mesh_names for m in mapped)


def zero_axes(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
              fsdp_size: int, mesh_names=(), rules: dict = DEFAULT_RULES
              ) -> Tuple[Optional[str], ...]:
    """Add the fsdp axis to the largest *effectively unsharded* divisible dim
    (an axis like 'embed'/'ff_local' resolves to None and is eligible)."""
    if fsdp_size <= 1 or "fsdp" in axes:   # idempotent: never double-apply
        return axes
    best, best_dim = None, 0
    for i, (ax, dim) in enumerate(zip(axes, shape)):
        if (_resolves_unsharded(ax, mesh_names, rules)
                and dim % fsdp_size == 0 and dim > best_dim):
            best, best_dim = i, dim
    if best is None:
        return axes
    out = list(axes)
    out[best] = "fsdp"
    return tuple(out)


def zero_axes_tree(axes_tree, values_tree, mesh: Mesh,
                   rules: dict = DEFAULT_RULES):
    """Per-leaf ZeRO axes given actual shapes (values may be ShapeDtypeStructs)."""
    sizes = mesh_axis_sizes(mesh)
    names = set(mesh.axis_names)
    fsdp_axes = rules.get("fsdp", ())
    if isinstance(fsdp_axes, str):
        fsdp_axes = (fsdp_axes,)
    fsdp_size = int(np.prod([sizes[a] for a in fsdp_axes if a in sizes])) \
        if fsdp_axes else 1

    def one(axes, val):
        return zero_axes(axes, val.shape, fsdp_size, names, rules)

    return jax.tree.map(
        one, axes_tree, values_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x),
    )
