"""First-class wireless-aggregation protocol objects.

The paper's contribution is a *protocol* — D-bit quantized embeddings
max-pooled over a shared channel via opportunistic carrier sensing (§II-B,
Eq. 4-7) — and :class:`Protocol` makes it a value instead of a
``mode="max_noisy"`` string plus loose kwargs.  One frozen, pytree-registered
object carries every protocol-side knob and answers every question its
consumers used to scatter across ``fedocs.aggregate``, ``ChannelNoise``,
``VerticalConfig`` and the ``channel.py`` load helpers:

  * ``protocol.aggregate(h, rng) -> (pooled, ProtocolAccounting)`` — the
    aggregation law itself, with the winner-routed ``custom_vjp`` backward
    (paper Eq. 5-6) unchanged and bit-for-bit identical to the historical
    string-mode paths for every kind on both contention backends;
  * ``protocol.comm_load(n_workers, k)`` — the analytic uplink/latency
    accounting (paper §I / §IV), with ``payload_bits`` resolved from ONE
    source of truth (the protocol's own quantization depth, unless
    explicitly overridden);
  * ``protocol.output_dim(n_workers, k)`` — the fused feature width the
    head sees.

Pytree layout: ``p_miss`` (traced scalar or per-worker ``(N,)`` miss
probability) and ``online`` (optional ``(N,)`` worker-up mask, default
``None`` = everyone contends) are the only leaves, so a single compiled
computation (or a ``vmap`` lane axis) serves a whole miss-probability or
fault grid; every other field is static metadata
(``kind``, ``bits``, ``backend``, ``max_rounds``, ``tie_break``,
``n_channels``, ``payload_bits``) baked into the compiled program.  The
quantization depth ``bits`` stays static because it selects the code dtype
(uint8/uint16) and the contention scan length; depth *scheduling* across
training is instead expressed with :class:`repro.protocol.BitsSchedule`,
which switches between per-``bits`` compiled branches on device.

Construct protocols with the named constructors::

    Protocol.ocs(bits=8, p_miss=0.05)      # noisy-OCS channel in the loop
    Protocol.ideal_max(bits=16)            # error-free quantized max-pool
    Protocol.max() / .mean() / .concat() / .sum()   # paper baselines
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel, fedocs, ocs

KINDS = ("sum", "max", "ideal_max", "ocs", "mean", "concat")

# string-mode names (fedocs.VALID_MODES) -> Protocol constructor arguments
_MODE_TO_KIND = {
    "sum": "sum",
    "max": "max",
    "max_q16": "ideal_max",
    "max_q8": "ideal_max",
    "max_noisy": "ocs",
    "mean": "mean",
    "concat": "concat",
}


@dataclasses.dataclass(frozen=True)
class ProtocolAccounting:
    """Measured channel accounting of one ``Protocol.aggregate`` call.

    Non-trivial only for ``kind="ocs"`` (the simulated noisy contention);
    ideal collectives report zeros — they consume no simulated channel.
    ``collisions`` counts collided (sub-frame, round) events — a sub-frame
    is billed once per round it stays collided, so the total lies in
    ``[0, K * max_rounds]`` — ``rounds`` the contention rounds until every
    sub-frame resolved, and ``contention_slots`` the sub-slots billed to
    unresolved sub-frames — exactly the ``NoisyOCSResult`` counters of the
    contention core.
    ``correct_frac`` is the fraction of elements whose winner held the true
    max code (the accuracy telemetry :class:`repro.protocol.BitsSchedule`
    policies may consume).
    """

    rounds: jax.Array            # () int32
    collisions: jax.Array        # () int32
    contention_slots: jax.Array  # () int32
    correct_frac: jax.Array      # () float32

    @staticmethod
    def zeros() -> "ProtocolAccounting":
        return ProtocolAccounting(
            rounds=jnp.int32(0), collisions=jnp.int32(0),
            contention_slots=jnp.int32(0), correct_frac=jnp.float32(1.0))


jax.tree_util.register_dataclass(
    ProtocolAccounting,
    data_fields=["rounds", "collisions", "contention_slots", "correct_frac"],
    meta_fields=[])


# ---------------------------------------------------------------------------
# the noisy-OCS pooling law with accounting: custom_vjp, Eq. 5-6 backward
# ---------------------------------------------------------------------------

def _acct_from(res: ocs.NoisyOCSResult) -> ProtocolAccounting:
    return ProtocolAccounting(
        rounds=res.rounds, collisions=res.collisions,
        contention_slots=res.contention_slots,
        correct_frac=jnp.mean(res.correct.astype(jnp.float32)))


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _ocs_pool(h, rng, p_miss, online, bits, max_rounds, backend):
    """``fedocs.maxpool_noisy`` + the contention core's channel accounting.

    Shares ``fedocs._maxpool_noisy_impl`` with :func:`fedocs.maxpool_noisy`,
    so the pooled value, the winner-routed backward AND the accounting are
    bit-for-bit the historical path (the accounting was always computed by
    the core; it was just discarded before reaching the caller).  ``online``
    is the all-``True`` mask unless the protocol carries a dropout state
    (``repro.faults``): dark workers leave the contention entirely.
    """
    pooled, _, res = fedocs._maxpool_noisy_impl(h, rng, p_miss, bits,
                                                max_rounds, backend,
                                                online=online)
    return pooled, _acct_from(res)


def _ocs_pool_fwd(h, rng, p_miss, online, bits, max_rounds, backend):
    pooled, mask, res = fedocs._maxpool_noisy_impl(h, rng, p_miss, bits,
                                                   max_rounds, backend,
                                                   online=online)
    return (pooled, _acct_from(res)), (mask, rng, p_miss, online)


def _ocs_pool_bwd(bits, max_rounds, backend, residuals, g):
    mask, rng, p_miss, online = residuals
    g_pooled, _g_acct = g        # accounting is non-differentiable telemetry
    d_rng = np.zeros(np.shape(rng), jax.dtypes.float0)
    d_online = np.zeros(np.shape(online), jax.dtypes.float0)
    return (g_pooled[None] * mask, d_rng, jnp.zeros_like(p_miss), d_online)


_ocs_pool.defvjp(_ocs_pool_fwd, _ocs_pool_bwd)


# ---------------------------------------------------------------------------
# the Protocol object
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Protocol:
    """One wireless aggregation protocol as a frozen pytree value.

    Do not call the constructor directly — use the named constructors
    (:meth:`ocs`, :meth:`ideal_max`, :meth:`max`, :meth:`mean`,
    :meth:`concat`, :meth:`sum`, or :meth:`from_mode` for legacy
    string-mode names).  ``p_miss`` and ``online`` are the only pytree
    leaves; all other fields are static metadata.
    """

    kind: str                       # one of KINDS
    bits: Optional[int] = None      # D, backoff/payload depth (static)
    tie_break: str = "all"          # gradient routing at code ties
    max_rounds: int = 3             # ocs: re-contention bound
    backend: str = "scan"           # ocs: "scan" | "pallas" contention engine
    n_channels: int = 1             # OFDMA channels (comm_load latency)
    payload_bits: Optional[int] = None   # comm_load override; None derives
    #   from the protocol itself (D-bit code payload for ocs/ideal_max,
    #   full 32-bit float payload otherwise)
    p_miss: Optional[jax.Array] = None   # traced leaf: () or (N,) miss prob;
    #   None = unbound (supply per call via with_p_miss)
    online: Optional[jax.Array] = None   # traced leaf: (N,) bool worker-up
    #   mask; None = all workers contend (bit-for-bit the all-True mask)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown protocol kind {self.kind!r}; valid: {KINDS}")
        if self.kind in ("ideal_max", "ocs", "max"):
            if self.bits is None or not (1 <= self.bits <= 32):
                raise ValueError(
                    f"{self.kind} protocol needs bits in [1, 32], "
                    f"got {self.bits}")
        if self.tie_break not in ("all", "first"):
            raise ValueError(f"unknown tie_break {self.tie_break!r}")
        if self.kind == "ocs":
            if self.backend not in ocs.NOISY_BACKENDS:
                raise ValueError(
                    f"unknown ocs backend {self.backend!r}; "
                    f"valid: {ocs.NOISY_BACKENDS}")
            if self.max_rounds < 1:
                raise ValueError("max_rounds must be >= 1")
        if self.n_channels < 1:
            raise ValueError("n_channels must be >= 1")

    # -- constructors -------------------------------------------------------

    @classmethod
    def sum(cls, *, n_channels: int = 1) -> "Protocol":
        """All-reduce(add) fusion (Megatron-style TP reference)."""
        return cls(kind="sum", n_channels=n_channels)

    @classmethod
    def max(cls, *, bits: int = 16, tie_break: str = "all",
            n_channels: int = 1) -> "Protocol":
        """Ideal float max-pool (paper Eq. 4): the D ``bits`` drive the
        contention accounting only; the winner transmits its full float."""
        return cls(kind="max", bits=bits, tie_break=tie_break,
                   n_channels=n_channels, payload_bits=32)

    @classmethod
    def ideal_max(cls, bits: int, *, tie_break: str = "all",
                  n_channels: int = 1) -> "Protocol":
        """Error-free quantized max-pool on D-bit monotone codes (Eq. 7):
        the winner's uplink payload is the D-bit code itself."""
        return cls(kind="ideal_max", bits=bits, tie_break=tie_break,
                   n_channels=n_channels)

    @classmethod
    def ocs(cls, bits: int = 16, p_miss=None, *, max_rounds: int = 3,
            backend: str = "scan", n_channels: int = 1,
            payload_bits: Optional[int] = None) -> "Protocol":
        """The paper's OCS channel with imperfect carrier sensing in the
        loop: quantized D-bit contention, per-sub-slot miss detection,
        lowest-index capture after ``max_rounds``.  ``p_miss`` is a traced
        scalar or per-worker ``(N,)`` array (it may stay ``None`` and be
        bound per call via :meth:`with_p_miss`)."""
        return cls(kind="ocs", bits=bits, tie_break="first",
                   max_rounds=max_rounds, backend=backend,
                   n_channels=n_channels, payload_bits=payload_bits,
                   p_miss=p_miss)

    @classmethod
    def mean(cls, *, n_channels: int = 1) -> "Protocol":
        """Mean-pool baseline (paper "Avg. Workers Embed")."""
        return cls(kind="mean", n_channels=n_channels)

    @classmethod
    def concat(cls, *, n_channels: int = 1) -> "Protocol":
        """Concat baseline (paper "Concat Workers Embed", O(N*K) uplink)."""
        return cls(kind="concat", n_channels=n_channels)

    @classmethod
    def from_mode(cls, mode: str, *, tie_break: str = "all",
                  bits: int = 16, max_rounds: int = 3,
                  backend: str = "scan", p_miss=None) -> "Protocol":
        """Map a legacy ``fedocs.VALID_MODES`` string to a Protocol."""
        kind = _MODE_TO_KIND.get(mode)
        if kind is None:
            raise ValueError(
                f"unknown aggregation mode {mode!r}; "
                f"valid: {tuple(_MODE_TO_KIND)}")
        if mode == "max_q16":
            return cls.ideal_max(16, tie_break=tie_break)
        if mode == "max_q8":
            return cls.ideal_max(8, tie_break=tie_break)
        if mode == "max_noisy":
            return cls.ocs(bits=bits, p_miss=p_miss, max_rounds=max_rounds,
                           backend=backend)
        if mode == "max":
            return cls.max(bits=bits, tie_break=tie_break)
        return cls(kind=kind)

    # -- protocol state -----------------------------------------------------

    def with_p_miss(self, p_miss) -> "Protocol":
        """Bind (or rebind) the traced miss probability, e.g. one vmap lane."""
        return dataclasses.replace(self, p_miss=p_miss)

    def with_online(self, online) -> "Protocol":
        """Bind (or rebind) the worker-up mask — dark workers leave the
        contention entirely (``repro.faults`` dropout spans)."""
        return dataclasses.replace(self, online=online)

    # -- the aggregation law ------------------------------------------------

    def aggregate(self, h: jax.Array, rng: Optional[jax.Array] = None
                  ) -> Tuple[jax.Array, ProtocolAccounting]:
        """Pool a worker-leading feature tensor ``h: (N, ..., K)``.

        Returns ``(pooled, accounting)``.  The pooled value and its
        ``custom_vjp`` (winner-routed cotangent, paper Eq. 5-6) are
        bit-for-bit the historical ``fedocs`` aggregation laws; the
        accounting is the contention core's measured channel counters
        (zeros for the ideal kinds, which consume no simulated channel).

        ``kind="ocs"`` additionally needs ``rng`` (the per-sub-slot sensing
        key) and a bound ``p_miss``; both are ordinary traced values, so one
        compiled computation serves a whole miss-probability axis.
        """
        if self.kind == "sum":
            return jnp.sum(h, axis=0), ProtocolAccounting.zeros()
        if self.kind == "max":
            return fedocs.maxpool(h, self.tie_break), ProtocolAccounting.zeros()
        if self.kind == "ideal_max":
            return (fedocs.maxpool_quantized(h, self.bits, self.tie_break),
                    ProtocolAccounting.zeros())
        if self.kind == "mean":
            return fedocs.meanpool(h), ProtocolAccounting.zeros()
        if self.kind == "concat":
            return fedocs.concat(h), ProtocolAccounting.zeros()
        # kind == "ocs"
        if rng is None:
            raise ValueError(
                "Protocol.ocs aggregation needs rng (the sensing PRNG key)")
        if self.p_miss is None:
            raise ValueError(
                "Protocol.ocs has no p_miss bound; construct with "
                "Protocol.ocs(bits, p_miss=...) or bind via with_p_miss()")
        p = jnp.asarray(self.p_miss, jnp.float32)
        online = (jnp.ones((h.shape[0],), bool) if self.online is None
                  else jnp.asarray(self.online, bool))
        return _ocs_pool(h, rng, p, online, self.bits, self.max_rounds,
                         self.backend)

    # -- derived protocol facts --------------------------------------------

    def output_dim(self, n_workers: int, k: int) -> int:
        """Fused feature width the head sees: N*K for concat, K otherwise."""
        return n_workers * k if self.kind == "concat" else k

    def resolved_payload_bits(self) -> int:
        """The single payload-bits source of truth for :meth:`comm_load`:
        the explicit override if set, else the D-bit code width for the
        quantized-payload kinds (ocs/ideal_max), else a full 32-bit float."""
        if self.payload_bits is not None:
            return self.payload_bits
        if self.kind in ("ocs", "ideal_max"):
            return self.bits
        return 32

    def comm_load(self, n_workers: int, k: int) -> channel.CommLoad:
        """Analytic per-round uplink/downlink accounting (paper §I / §IV).

        Consolidates the ``channel.ocs_load``/``concat_load``/``mean_load``
        helpers behind the protocol object: the payload width comes from
        :meth:`resolved_payload_bits` and ``n_channels`` from the protocol,
        so callers no longer re-derive a ``ChannelConfig`` ad hoc.
        """
        cfg = channel.ChannelConfig(payload_bits=self.resolved_payload_bits(),
                                    n_channels=self.n_channels)
        if self.kind in ("max", "ideal_max", "ocs"):
            return channel.ocs_load(n_workers, k, bits=self.bits, cfg=cfg)
        if self.kind in ("mean", "sum"):
            # every worker transmits every element; the server reduces
            return channel.mean_load(n_workers, k, cfg=cfg)
        return channel.concat_load(n_workers, k, cfg=cfg)


jax.tree_util.register_dataclass(
    Protocol,
    data_fields=["p_miss", "online"],
    meta_fields=["kind", "bits", "tie_break", "max_rounds", "backend",
                 "n_channels", "payload_bits"])
