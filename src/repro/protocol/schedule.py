"""Channel-aware backoff-depth scheduling across training (``BitsSchedule``).

The quantization depth D (``Protocol.bits``) is *static* — it selects code
dtypes and the contention scan length — so it cannot be a traced value
inside one compiled step.  A :class:`BitsSchedule` instead declares a small
set of candidate depths and a pure on-device policy that picks the next
round's depth from the protocol telemetry the contention core already
returns (:class:`repro.protocol.ProtocolAccounting`: collisions, rounds,
winner-correctness).  The fused scan curve engine
(``repro.sim.train_curves.run_scheduled_curves``) compiles one training-step
branch per candidate and ``lax.switch``-es between them per round, so a
whole scheduled training run still costs ONE host dispatch.

Policy contract (all pure JAX, usable inside ``lax.scan``):

  * ``init_state() -> state``   — pytree of arrays carried through the scan;
  * ``update(state, telemetry) -> (state, index)`` — consume one round's
    telemetry (a dict with float32 scalars: ``collision_frac``, the
    fraction of the round's ``K * max_rounds`` re-contention opportunities
    that collided, in [0, 1]; ``rounds``; ``correct_frac``) and emit the
    *next* round's candidate index (traced int32 into ``candidates``).

``FixedBits`` is the degenerate schedule (always the same depth — a
scheduled run with it is bit-for-bit a plain ``run_curves`` lane).
``CollisionAdaptiveBits`` tracks an EMA of the collision fraction and
escalates to a deeper code when contention keeps colliding (deeper codes
have fewer ties, hence fewer collision rounds), de-escalating to cheaper
codes when the channel is quiet.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Telemetry = Dict[str, jax.Array]


@dataclasses.dataclass(frozen=True)
class BitsSchedule:
    """Base policy: candidate depths + a pure per-round update rule."""

    candidates: Tuple[int, ...]
    init_index: int = 0

    def __post_init__(self):
        if not self.candidates:
            raise ValueError("BitsSchedule needs at least one candidate")
        for b in self.candidates:
            if not (1 <= b <= 32):
                raise ValueError(f"candidate bits={b} outside [1, 32]")
        if not (0 <= self.init_index < len(self.candidates)):
            raise ValueError(
                f"init_index {self.init_index} outside the "
                f"{len(self.candidates)} candidates")

    def init_state(self):
        return jnp.int32(self.init_index)

    def update(self, state, telemetry: Telemetry):
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class FixedBits(BitsSchedule):
    """Always the same depth: ``FixedBits(bits)``.

    The identity schedule — ``run_scheduled_curves`` with ``FixedBits(b)``
    trains the exact trajectory of ``run_curves`` at ``bits=(b,)``
    (property-tested), so scheduled runs are a strict generalization of the
    fixed-depth engine.
    """

    def __init__(self, bits: int):
        super().__init__(candidates=(bits,), init_index=0)

    def update(self, state, telemetry: Telemetry):
        return state, jnp.int32(0)


@dataclasses.dataclass(frozen=True)
class CollisionAdaptiveBits(BitsSchedule):
    """Escalate the backoff depth while collisions persist, back off when
    the channel is quiet.

    Tracks ``ema <- decay * ema + (1 - decay) * collision_frac`` (the
    fraction of the round's re-contention opportunities that collided, from
    the contention core's accounting) and moves one candidate step per
    round: up when the EMA exceeds ``escalate``, down below ``deescalate``.
    Deeper codes shrink the tie sets that collide under sensing misses, at
    the price of more contention sub-slots — exactly the paper's Eq.-7
    depth/overhead trade, now driven by observed channel telemetry.
    """

    escalate: float = 0.03
    deescalate: float = 0.005
    decay: float = 0.8

    def __init__(self, candidates: Tuple[int, ...] = (8, 16),
                 init_index: int = 0, *, escalate: float = 0.03,
                 deescalate: float = 0.005, decay: float = 0.8):
        if not (0.0 <= deescalate <= escalate):
            raise ValueError(
                f"need 0 <= deescalate ({deescalate}) <= escalate "
                f"({escalate})")
        if not (0.0 <= decay < 1.0):
            raise ValueError(f"decay must be in [0, 1), got {decay}")
        object.__setattr__(self, "escalate", float(escalate))
        object.__setattr__(self, "deescalate", float(deescalate))
        object.__setattr__(self, "decay", float(decay))
        super().__init__(candidates=tuple(candidates), init_index=init_index)

    def init_state(self):
        return {"idx": jnp.int32(self.init_index),
                "ema": jnp.float32(0.0)}

    def update(self, state, telemetry: Telemetry):
        coll = jnp.asarray(telemetry["collision_frac"], jnp.float32)
        ema = self.decay * state["ema"] + (1.0 - self.decay) * coll
        top = jnp.int32(len(self.candidates) - 1)
        idx = state["idx"]
        idx = jnp.where(ema > self.escalate, jnp.minimum(idx + 1, top),
                        jnp.where(ema < self.deescalate,
                                  jnp.maximum(idx - 1, 0), idx))
        return {"idx": idx, "ema": ema}, idx
