"""First-class Protocol API: the paper's access scheme as a pytree value.

``Protocol``           — frozen, pytree-registered protocol object: one
                         ``aggregate(h, rng) -> (pooled, accounting)`` entry
                         point plus ``comm_load``/``output_dim``; traced
                         ``p_miss`` leaf, static everything else.
``ProtocolAccounting`` — measured channel counters of one aggregate call.
``BitsSchedule``       — per-round backoff-depth policy hook
                         (``FixedBits``, ``CollisionAdaptiveBits``) driven
                         by the accounting telemetry; executed on device by
                         ``repro.sim.train_curves.run_scheduled_curves``.
"""

from repro.protocol.protocol import (  # noqa: F401
    KINDS, Protocol, ProtocolAccounting,
)
from repro.protocol.schedule import (  # noqa: F401
    BitsSchedule, CollisionAdaptiveBits, FixedBits,
)

__all__ = ["KINDS", "Protocol", "ProtocolAccounting", "BitsSchedule",
           "CollisionAdaptiveBits", "FixedBits"]
