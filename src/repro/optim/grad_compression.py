"""Winner-sparse gradient compression with error feedback.

The FedOCS backward is exactly sparse (only argmax winners receive gradient
— paper Eq. 6).  This module generalizes that observation into a top-k
magnitude sparsifier with error feedback (memory) for the *data-parallel*
gradient reduction: each DP rank keeps the k largest-magnitude entries per
tensor, accumulates the residual locally, and adds it to the next step's
gradient.  With k = 1/16..1/64 the DP all-reduce payload shrinks
proportionally at negligible convergence cost (validated in
``tests/test_grad_compression.py``).

Three invariants this module guarantees (each was a bug once):

* ``topk_mask`` keeps **exactly** ``k = max(1, int(n * k_frac))`` entries
  per tensor, including under threshold ties — selection scatters over
  ``lax.top_k`` indices rather than comparing against the k-th value, so
  zero-heavy or quantized gradients cannot ship near-dense payloads.
* The error memory accumulates the **dtype-quantization residual** too:
  the residual is computed against the value actually transmitted
  (``sparse.astype(g.dtype)``), so for bf16/fp16 gradients the cast error
  feeds back instead of being silently dropped each step.  Exactly:
  ``sparse.astype(f32) + new_err == g.astype(f32) + err``.
* ``payload_fraction`` bills the **per-leaf** k floors: small leaves
  (biases, norms) keep ``max(1, int(n*k_frac))`` elements, which can be a
  far larger fraction of the leaf than ``k_frac``.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def topk_count(n: int, k_frac: float) -> int:
    """Number of entries kept for a tensor of ``n`` elements."""
    return max(1, int(n * k_frac))


def topk_mask(x: jax.Array, k_frac: float) -> jax.Array:
    """Boolean mask keeping exactly the k largest-|x| entries (per tensor).

    Ties at the threshold are broken by ``lax.top_k``'s stable ordering
    (lowest flat index wins), so the mask always has exactly
    ``max(1, int(n * k_frac))`` True entries.
    """
    flat = jnp.abs(x.reshape(-1))
    k = topk_count(flat.shape[0], k_frac)
    _, idx = jax.lax.top_k(flat, k)
    mask = jnp.zeros(flat.shape, jnp.bool_).at[idx].set(True)
    return mask.reshape(x.shape)


def compress_counted(g: jax.Array, err: jax.Array, k_frac: float
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (sparse gradient, new error memory, kept-element count).

    The residual is computed against the value actually applied /
    transmitted (``sparse`` in ``g.dtype``), so dtype-cast error is
    accumulated rather than lost.  The count is an int32 scalar equal to
    the number of nonzero mask entries (== ``topk_count``; traced so it
    composes with vmap/psum for measured payload accounting).
    """
    corrected = g.astype(jnp.float32) + err
    mask = topk_mask(corrected, k_frac)
    sparse = jnp.where(mask, corrected, 0.0).astype(g.dtype)
    new_err = corrected - sparse.astype(jnp.float32)
    return sparse, new_err, jnp.sum(mask, dtype=jnp.int32)


def compress(g: jax.Array, err: jax.Array, k_frac: float
             ) -> Tuple[jax.Array, jax.Array]:
    """Returns (sparse gradient, new error memory)."""
    sparse, new_err, _ = compress_counted(g, err, k_frac)
    return sparse, new_err


def compress_tree(grads, err_tree, k_frac: float):
    out = jax.tree.map(lambda g, e: compress(g, e, k_frac), grads, err_tree)
    sparse = jax.tree.map(lambda o: o[0], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return sparse, new_err


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def payload_fraction(tree, k_frac: float) -> float:
    """Analytic DP-collective payload ratio vs dense all-reduce (value+index
    encoding at 2x per kept element), honoring the per-leaf k floor.

    For a tree with leaf sizes ``n_i`` the kept count is
    ``sum_i max(1, int(n_i * k_frac))`` — small leaves (biases, norms)
    ship a higher fraction than ``k_frac`` — and the ratio is
    ``2 * kept / total`` capped at 1.
    """
    leaves = jax.tree.leaves(tree)
    if not leaves:
        raise ValueError("payload_fraction: tree has no leaves")
    sizes = [int(np.prod(np.shape(leaf))) for leaf in leaves]
    kept = sum(topk_count(n, k_frac) for n in sizes)
    return min(1.0, 2.0 * kept / sum(sizes))
