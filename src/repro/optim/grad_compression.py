"""Winner-sparse gradient compression with error feedback.

The FedOCS backward is exactly sparse (only argmax winners receive gradient
— paper Eq. 6).  This module generalizes that observation into a top-k
magnitude sparsifier with error feedback (memory) for the *data-parallel*
gradient reduction: each DP rank keeps the k largest-magnitude entries per
tensor, accumulates the residual locally, and adds it to the next step's
gradient.  With k = 1/16..1/64 the DP all-reduce payload shrinks
proportionally at negligible convergence cost (validated in
``tests/test_grad_compression.py``).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def topk_mask(x: jax.Array, k_frac: float) -> jax.Array:
    """Boolean mask keeping the k largest-|x| entries (per tensor)."""
    flat = jnp.abs(x.reshape(-1))
    k = max(1, int(flat.shape[0] * k_frac))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh)


def compress(g: jax.Array, err: jax.Array, k_frac: float
             ) -> Tuple[jax.Array, jax.Array]:
    """Returns (sparse gradient, new error memory)."""
    corrected = g.astype(jnp.float32) + err
    mask = topk_mask(corrected, k_frac)
    sparse = jnp.where(mask, corrected, 0.0)
    return sparse.astype(g.dtype), corrected - sparse


def compress_tree(grads, err_tree, k_frac: float):
    out = jax.tree.map(lambda g, e: compress(g, e, k_frac), grads, err_tree)
    sparse = jax.tree.map(lambda o: o[0], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return sparse, new_err


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def payload_fraction(tree, k_frac: float) -> float:
    """Analytic DP-collective payload ratio vs dense all-reduce (value+index
    encoding at 2x per kept element)."""
    return min(1.0, 2.0 * k_frac)
