"""Learning-rate schedules (pure functions step -> lr).

Includes WSD (Warmup-Stable-Decay) from MiniCPM (arXiv:2404.06395), the
schedule the assigned minicpm-2b config trains with.
"""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup_cosine(lr: float, warmup: int, total: int,
                         final_frac: float = 0.1):
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, lr * cos).astype(jnp.float32)
    return f


def wsd(lr: float, warmup: int, stable: int, decay: int,
        final_frac: float = 0.01):
    """Warmup-Stable-Decay: linear warmup, flat plateau, exponential-ish
    (here: cosine) decay over the last `decay` steps — MiniCPM §4."""
    def f(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / jnp.maximum(warmup, 1)
        t = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0, 1)
        dec = lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        out = jnp.where(step < warmup, warm,
                        jnp.where(step < warmup + stable, lr, dec))
        return out.astype(jnp.float32)
    return f


def for_arch(arch_id: str, lr: float, total_steps: int):
    if arch_id == "minicpm-2b":
        warm = max(total_steps // 100, 10)
        decay = max(total_steps // 10, 10)
        return wsd(lr, warm, total_steps - warm - decay, decay)
    return linear_warmup_cosine(lr, max(total_steps // 100, 10), total_steps)
