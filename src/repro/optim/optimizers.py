"""Optimizers in pure JAX (no optax in the offline container).

optax-like API:  ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (new_params, new_state, stats)``.
Master weights / moments are fp32 regardless of parameter dtype; the trainer
shards them ZeRO-style via ``parallel.sharding.zero_axes_tree``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), gn


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def adamw(lr_fn: Callable, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          max_grad_norm: Optional[float] = 1.0,
          moment_dtype=jnp.float32) -> Optimizer:
    """AdamW with decoupled weight decay and fp32 master weights."""

    def init(params):
        # a fresh buffer even for fp32 params (astype would alias), so the
        # params and master carries stay donatable side by side
        f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
        return {
            "step": jnp.zeros((), jnp.int32),
            "master": jax.tree.map(f32, params),
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype),
                              params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, moment_dtype),
                              params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr = lr_fn(step)
        stats = {}
        if max_grad_norm is not None:
            grads, gn = clip_by_global_norm(grads, max_grad_norm)
            stats["grad_norm"] = gn
        b1t = 1 - b1 ** step.astype(jnp.float32)
        b2t = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, master):
            g = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            mh = m_new / b1t
            vh = v_new / b2t
            new_master = master - lr * (mh / (jnp.sqrt(vh) + eps)
                                        + weight_decay * master)
            return (new_master, m_new.astype(moment_dtype),
                    v_new.astype(moment_dtype))

        out = jax.tree.map(upd, grads, state["m"], state["v"],
                           state["master"])
        new_master = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree.map(
            lambda mw, p: mw.astype(p.dtype), new_master, params)
        new_state = {"step": step, "master": new_master, "m": new_m,
                     "v": new_v}
        stats["lr"] = lr
        return new_params, new_state, stats

    return Optimizer(init=init, update=update)


def sgd(lr_fn: Callable, momentum: float = 0.9,
        max_grad_norm: Optional[float] = None) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr = lr_fn(step)
        stats = {}
        if max_grad_norm is not None:
            grads, gn = clip_by_global_norm(grads, max_grad_norm)
            stats["grad_norm"] = gn
        new_mom = jax.tree.map(
            lambda g, mo: momentum * mo + g.astype(jnp.float32),
            grads, state["mom"])
        new_params = jax.tree.map(
            lambda p, mo: (p.astype(jnp.float32) - lr * mo).astype(p.dtype),
            params, new_mom)
        stats["lr"] = lr
        return new_params, {"step": step, "mom": new_mom}, stats

    return Optimizer(init=init, update=update)
