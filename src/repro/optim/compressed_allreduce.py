"""Compressed data-parallel all-reduce as a first-class policy object.

:class:`repro.protocol.Protocol` made the *uplink* half of the paper's
communication story a frozen pytree value with measured accounting
(``ProtocolAccounting``).  :class:`CompressedAllReduce` does the same for
the *data-parallel* half: top-k sparsification with error feedback
(``optim/grad_compression.py``, the generalization of the Eq.-6
winner-sparse backward) behind ONE entry point,

    ``reduce(grads, err, axis_name=...) -> (reduced, new_err, DPAccounting)``

with the EF memory threaded as an ordinary traced pytree (a scan carry /
donated buffer, never a recompile trigger) and the payload bits billed from
the **actual kept-element counts** of the exact-k masks — so the number in
:class:`DPAccounting` is a measurement, not the analytic ``2*k_frac``
estimate (which the per-leaf k floor makes wrong for small leaves).

Pytree layout mirrors ``Protocol``'s discipline, with one inversion: a
``CompressedAllReduce`` has NO data leaves at all — ``k_frac`` and the
payload encoding are compile-time policy (they select top_k sizes), so the
whole object is static, hashable metadata.  What *is* traced is the state it
operates on (gradients, EF memory) and the counters it returns.

Determinism contract: the all-reduce is implemented as
``all_gather(axis=0)`` + ``jnp.sum(axis=0)`` rather than a raw ``psum`` so
that the floating-point reduction order is the fixed stacked-axis order on
every backend.  A ``vmap(axis_name=...)`` single-device run and a
``shard_map`` multi-device run therefore sum the *identical* ``(D, ...)``
array in the identical order — the bit-for-bit parity the 2-D curve engine
(``sim/train_curves.run_curves_dp``) asserts.  Integer accounting uses
``lax.psum`` (exact for ints).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import grad_compression


@dataclasses.dataclass(frozen=True)
class DPAccounting:
    """Measured payload accounting of one ``CompressedAllReduce.reduce``.

    All counters are () int32 arrays (traced, so they thread through scans
    and vmaps like ``ProtocolAccounting``), totalled over every
    participating rank when ``axis_name`` is given:

    * ``payload_bits`` — bits actually shipped into the all-reduce this
      step: per leaf, (kept nonzeros) x (value_bits + index bits), summed
      over leaves and ranks.  Kept counts come from the exact-k masks, so
      with the tie-exact ``topk_mask`` this equals the analytic
      ``CompressedAllReduce.payload_bits(tree) * n_ranks``.
    * ``kept_elems`` — total kept (transmitted) elements across leaves and
      ranks.
    * ``dense_bits`` — what an uncompressed all-reduce would have shipped
      (total elements x value_bits x n_ranks), the denominator for the
      achieved compression ratio.
    """

    payload_bits: jax.Array  # () int32
    kept_elems: jax.Array    # () int32
    dense_bits: jax.Array    # () int32

    @staticmethod
    def zeros() -> "DPAccounting":
        return DPAccounting(payload_bits=jnp.int32(0),
                            kept_elems=jnp.int32(0),
                            dense_bits=jnp.int32(0))


jax.tree_util.register_dataclass(
    DPAccounting,
    data_fields=["payload_bits", "kept_elems", "dense_bits"],
    meta_fields=[])


def _leaf_index_bits(n: int) -> int:
    """Bits to address one element of an n-element leaf (>= 1)."""
    return max(1, math.ceil(math.log2(max(n, 2))))


@dataclasses.dataclass(frozen=True)
class CompressedAllReduce:
    """One DP gradient-compression policy as a frozen (all-static) pytree.

    Do not call the constructor directly — use :meth:`topk`.  Fields:

    * ``k_frac`` — kept fraction per tensor; each leaf keeps exactly
      ``max(1, int(n * k_frac))`` largest-|.| entries (error feedback
      accumulates the rest, including the dtype-cast residual).
    * ``value_bits`` — wire width of one kept value (32 = raw float32).
    * ``index_bits`` — wire width of one kept index; ``None`` derives
      ``ceil(log2(n))`` per leaf (the tight encoding), an int fixes a
      uniform width (e.g. 32 for the naive value+index encoding that
      ``grad_compression.payload_fraction`` bills at 2x per element).
    """

    k_frac: float
    value_bits: int = 32
    index_bits: Optional[int] = None

    def __post_init__(self):
        if not (0.0 < self.k_frac <= 1.0):
            raise ValueError(f"k_frac must be in (0, 1], got {self.k_frac}")
        if not (1 <= self.value_bits <= 32):
            raise ValueError(
                f"value_bits must be in [1, 32], got {self.value_bits}")
        if self.index_bits is not None and self.index_bits < 1:
            raise ValueError(
                f"index_bits must be >= 1 or None, got {self.index_bits}")

    @classmethod
    def topk(cls, k_frac: float, *, value_bits: int = 32,
             index_bits: Optional[int] = None) -> "CompressedAllReduce":
        """Top-k magnitude sparsification with error feedback."""
        return cls(k_frac=float(k_frac), value_bits=value_bits,
                   index_bits=index_bits)

    # -- EF state -----------------------------------------------------------

    def init_error(self, params):
        """Zero error-feedback memory shaped like ``params`` (f32)."""
        return grad_compression.init_error(params)

    # -- analytic payload facts (host-side, ints) ---------------------------

    def leaf_index_bits(self, n: int) -> int:
        return (self.index_bits if self.index_bits is not None
                else _leaf_index_bits(n))

    def leaf_payload_bits(self, n: int) -> int:
        """Wire bits for ONE rank's push of an n-element leaf."""
        kept = grad_compression.topk_count(n, self.k_frac)
        return kept * (self.value_bits + self.leaf_index_bits(n))

    def payload_bits(self, tree) -> int:
        """Analytic wire bits for ONE rank's push of the whole tree.

        ``reduce``'s measured ``DPAccounting.payload_bits`` equals this
        times the rank count — the exact-k masks guarantee it.
        """
        sizes = _leaf_sizes(tree)
        return sum(self.leaf_payload_bits(n) for n in sizes)

    def dense_bits(self, tree) -> int:
        """Wire bits an uncompressed push of the tree would cost (one rank)."""
        return sum(n * self.value_bits for n in _leaf_sizes(tree))

    def payload_fraction(self, tree) -> float:
        """Achieved compression ratio vs dense (one rank)."""
        return self.payload_bits(tree) / self.dense_bits(tree)

    # -- the reduction law --------------------------------------------------

    def reduce(self, grads, err, *, axis_name: Optional[str] = None
               ) -> Tuple[object, object, DPAccounting]:
        """Compress, all-reduce, and bill one gradient tree.

        ``grads``/``err`` are per-rank trees (no leading rank axis); inside
        a ``shard_map`` or ``vmap(axis_name=...)`` over the DP axis, pass
        that ``axis_name`` and every rank receives the summed sparse
        gradients plus accounting totalled over ranks.  With
        ``axis_name=None`` this is the degenerate 1-rank all-reduce:
        ``reduced`` is the rank's own sparse tree.

        Returns ``(reduced, new_err, DPAccounting)``.  ``reduced`` is NOT
        divided by the rank count — callers choose sum vs mean semantics.
        """
        leaves, treedef = jax.tree.flatten(grads)
        err_leaves = treedef.flatten_up_to(err)

        sparse_leaves, new_err_leaves = [], []
        payload = jnp.int32(0)
        kept_total = jnp.int32(0)
        for g, e in zip(leaves, err_leaves):
            sparse, new_err, kept = grad_compression.compress_counted(
                g, e, self.k_frac)
            n = int(np.prod(np.shape(g)))
            bits_per = jnp.int32(self.value_bits + self.leaf_index_bits(n))
            payload = payload + kept * bits_per
            kept_total = kept_total + kept
            sparse_leaves.append(sparse)
            new_err_leaves.append(new_err)

        dense = jnp.int32(self.dense_bits(grads))
        if axis_name is None:
            reduced_leaves = sparse_leaves
        else:
            # all_gather + fixed-order sum (not raw psum): both the vmap
            # fallback and the mesh path reduce the identical (D, ...) stack
            # in the identical order -> bitwise parity across topologies.
            reduced_leaves = [
                jnp.sum(jax.lax.all_gather(s, axis_name, axis=0), axis=0)
                for s in sparse_leaves]
            payload = jax.lax.psum(payload, axis_name)
            kept_total = jax.lax.psum(kept_total, axis_name)
            dense = jax.lax.psum(dense, axis_name)

        acct = DPAccounting(payload_bits=payload, kept_elems=kept_total,
                            dense_bits=dense)
        return (treedef.unflatten(reduced_leaves),
                treedef.unflatten(new_err_leaves), acct)


def _leaf_sizes(tree):
    leaves = jax.tree.leaves(tree)
    if not leaves:
        raise ValueError("CompressedAllReduce: tree has no leaves")
    return [int(np.prod(np.shape(leaf))) for leaf in leaves]


jax.tree_util.register_dataclass(
    CompressedAllReduce,
    data_fields=[],
    meta_fields=["k_frac", "value_bits", "index_bits"])
