"""Train-step construction: loss + grad (+ microbatch accumulation) + update.

Microbatch gradient accumulation is a ``lax.scan`` over microbatches, which
lets the XLA latency-hiding scheduler overlap microbatch i+1's compute with
microbatch i's DP gradient all-reduce (reduce-scatter under ZeRO), the
standard comm/compute overlap structure.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim import grad_compression


def make_train_step(loss_fn: Callable, optimizer, microbatches: int = 1,
                    compress_k: Optional[float] = None) -> Callable:
    """loss_fn(values, batch) -> (loss, metrics dict).

    Returns train_step(values, opt_state, batch, err) ->
        (values, opt_state, err, metrics)
    ``err`` is the error-feedback memory when compress_k is set (else None —
    pass jnp.zeros(()) sentinel-free via the same pytree each call).
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(values, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(values, batch)
            return grads, loss, metrics

        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape((microbatches, b // microbatches) + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(values, mb)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), metrics

        zeros = jax.tree.map(lambda v: jnp.zeros(v.shape, jnp.float32),
                             values)
        (acc, loss_sum), metrics = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), micro)
        grads = jax.tree.map(lambda g: g / microbatches, acc)
        last_metrics = jax.tree.map(lambda m: m[-1], metrics)
        return grads, loss_sum / microbatches, last_metrics

    if compress_k is not None:
        def train_step(values, opt_state, batch, err):
            grads, loss, metrics = compute_grads(values, batch)
            grads, err = grad_compression.compress_tree(grads, err,
                                                        compress_k)
            values, opt_state, stats = optimizer.update(grads, opt_state,
                                                        values)
            metrics = dict(metrics)
            metrics.update(stats)
            metrics["loss_mean"] = loss
            return values, opt_state, err, metrics
        return train_step

    def train_step(values, opt_state, batch):
        grads, loss, metrics = compute_grads(values, batch)
        values, opt_state, stats = optimizer.update(grads, opt_state, values)
        metrics = dict(metrics)
        metrics.update(stats)
        metrics["loss_mean"] = loss
        return values, opt_state, metrics

    return train_step
