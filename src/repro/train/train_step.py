"""Train-step construction: loss + grad (+ microbatch accumulation) + update.

Microbatch gradient accumulation is a ``lax.scan`` over microbatches, which
lets the XLA latency-hiding scheduler overlap microbatch i+1's compute with
microbatch i's DP gradient all-reduce (reduce-scatter under ZeRO), the
standard comm/compute overlap structure.
"""

from __future__ import annotations

from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp

from repro.optim.compressed_allreduce import CompressedAllReduce


def make_train_step(loss_fn: Callable, optimizer, microbatches: int = 1,
                    compress_k: Optional[Union[float,
                                               CompressedAllReduce]] = None,
                    with_rng: bool = False,
                    donate: bool = False,
                    dp_axis: Optional[str] = None) -> Callable:
    """loss_fn(values, batch) -> (loss, metrics dict).

    Returns train_step(values, opt_state, batch, err) ->
        (values, opt_state, err, metrics)
    ``err`` is the error-feedback memory when compress_k is set (else None —
    pass jnp.zeros(()) sentinel-free via the same pytree each call).
    ``compress_k`` is either a kept-fraction float (sugar for
    ``CompressedAllReduce.topk(k)``) or a full
    :class:`repro.optim.compressed_allreduce.CompressedAllReduce` policy;
    compressed steps report the measured ``dp_payload_bits`` /
    ``dp_kept_elems`` in the metrics dict.  ``dp_axis`` names a mapped
    data-parallel axis (``shard_map`` or ``vmap(axis_name=...)``) to
    all-reduce the compressed gradients over — the reduced gradient is the
    rank **mean** and the payload counters are totals across ranks.

    ``with_rng=True`` switches the contract to a stochastic forward (e.g. the
    channel-in-the-loop OCS aggregation): ``loss_fn(values, batch, rng)``
    and ``train_step(values, opt_state, batch, rng[, err])``.  ``rng`` is
    any pytree of traced arrays — a PRNG key, or a ``(key,
    repro.protocol.Protocol)`` channel-state tuple as the curve engine
    passes — under microbatching each microbatch receives ``fold_in``-style
    decorrelated keys via the scan index (key-typed leaves are folded;
    float leaves like the protocol's ``p_miss`` pass through untouched).

    ``donate=True`` returns the step pre-jitted with the train-state carries
    (``values``, ``opt_state``) donated, so params/optimizer moments are
    updated in place instead of double-buffering across dispatches.  The
    caller's input buffers are consumed: rebind them from the step's outputs
    (the usual ``values, opt_state, ... = step(values, opt_state, ...)``
    loop) and copy any initial state that must survive the first call.
    """
    if with_rng:
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    else:
        grad_fn = jax.value_and_grad(
            lambda values, batch, rng: loss_fn(values, batch), has_aux=True)

    def compute_grads(values, batch, rng):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(values, batch, rng)
            return grads, loss, metrics

        def split(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape((microbatches, b // microbatches) + x.shape[1:])

        micro = jax.tree.map(split, batch)

        def is_key_like(r):
            dtype = jnp.asarray(r).dtype
            prng_key = getattr(jax.dtypes, "prng_key", None)
            if prng_key is not None and jnp.issubdtype(dtype, prng_key):
                return True                   # new-style typed PRNG keys
            return jnp.issubdtype(dtype, jnp.integer)   # legacy uint32 keys

        def fold_rng(i):
            if not with_rng:
                return rng
            # decorrelate microbatches: fold the scan index into every
            # key-typed leaf (legacy uint32 or typed PRNG keys); float
            # leaves (p_miss) pass through untouched.
            return jax.tree.map(
                lambda r: jax.random.fold_in(r, i) if is_key_like(r) else r,
                rng)

        def body(carry, im):
            i, mb = im
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(values, mb, fold_rng(i))
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), metrics

        zeros = jax.tree.map(lambda v: jnp.zeros(v.shape, jnp.float32),
                             values)
        (acc, loss_sum), metrics = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)),
            (jnp.arange(microbatches), micro))
        grads = jax.tree.map(lambda g: g / microbatches, acc)
        last_metrics = jax.tree.map(lambda m: m[-1], metrics)
        return grads, loss_sum / microbatches, last_metrics

    def apply_update(values, opt_state, grads, loss, metrics):
        values, opt_state, stats = optimizer.update(grads, opt_state, values)
        metrics = dict(metrics)
        metrics.update(stats)
        metrics["loss_mean"] = loss
        return values, opt_state, metrics

    def finalize(step):
        # the first two positions are the train-state carries in every
        # contract variant: updated in place when donated
        return jax.jit(step, donate_argnums=(0, 1)) if donate else step

    if compress_k is not None:
        compress = (compress_k if isinstance(compress_k, CompressedAllReduce)
                    else CompressedAllReduce.topk(float(compress_k)))

        def reduce_grads(grads, err, metrics):
            grads, err, acct = compress.reduce(grads, err, axis_name=dp_axis)
            if dp_axis is not None:
                n_ranks = jax.lax.psum(jnp.int32(1), dp_axis)
                grads = jax.tree.map(lambda g: g / n_ranks, grads)
            metrics = dict(metrics)
            metrics["dp_payload_bits"] = acct.payload_bits
            metrics["dp_kept_elems"] = acct.kept_elems
            return grads, err, metrics

    if compress_k is not None and with_rng:
        def train_step(values, opt_state, batch, rng, err):
            grads, loss, metrics = compute_grads(values, batch, rng)
            grads, err, metrics = reduce_grads(grads, err, metrics)
            values, opt_state, metrics = apply_update(values, opt_state,
                                                      grads, loss, metrics)
            return values, opt_state, err, metrics
        return finalize(train_step)

    if compress_k is not None:
        def train_step(values, opt_state, batch, err):
            grads, loss, metrics = compute_grads(values, batch, None)
            grads, err, metrics = reduce_grads(grads, err, metrics)
            values, opt_state, metrics = apply_update(values, opt_state,
                                                      grads, loss, metrics)
            return values, opt_state, err, metrics
        return finalize(train_step)

    if with_rng:
        def train_step(values, opt_state, batch, rng):
            grads, loss, metrics = compute_grads(values, batch, rng)
            return apply_update(values, opt_state, grads, loss, metrics)
        return finalize(train_step)

    def train_step(values, opt_state, batch):
        grads, loss, metrics = compute_grads(values, batch, None)
        return apply_update(values, opt_state, grads, loss, metrics)

    return finalize(train_step)
