"""Training loop with checkpoint/restart, straggler mitigation, and logging.

Fault-tolerance model (DESIGN.md §6):
  * auto-resume: on start, the newest COMMITted checkpoint (if any) is
    restored — a preempted job relaunches with the same command line;
  * index-derived data: batches are pure functions of (seed, step), so resume
    replays the exact stream with no data-loader state;
  * straggler mitigation: a per-step data deadline — a host that misses it
    substitutes the previous step's batch (deterministic, auditable via the
    `substituted_steps` log); a step-time watchdog flags slow steps for the
    launcher's eviction/elastic-re-mesh path;
  * elastic rescale: checkpoints are mesh-agnostic; `restore` takes target
    shardings (see checkpoint/checkpointer.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import numpy as np
import jax.numpy as jnp

from repro.checkpoint import checkpointer
from repro.optim import grad_compression
from repro.optim.compressed_allreduce import CompressedAllReduce
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    microbatches: int = 1
    # kept-fraction float (sugar for CompressedAllReduce.topk) or a full
    # CompressedAllReduce policy; compressed steps log dp_payload_bits
    compress_k: Optional[Union[float, CompressedAllReduce]] = None
    data_deadline_s: Optional[float] = None     # straggler: batch deadline
    watchdog_factor: float = 3.0                # step-time anomaly threshold
    resume: bool = True
    # stochastic-forward support (channel-in-the-loop training): when set,
    # loss_fn takes a third rng argument and each step receives a key derived
    # as fold_in(PRNGKey(seed), step) — resume replays the exact noise stream.
    channel_rng_seed: Optional[int] = None
    # the watchdog's clock, injectable so straggler detection can be driven
    # deterministically in tests (the loop itself never reads wall time)
    clock: Callable[[], float] = time.monotonic


@dataclasses.dataclass
class TrainResult:
    values: Any
    opt_state: Any
    history: List[Dict[str, float]]
    substituted_steps: List[int]
    straggler_flags: List[int]
    final_step: int


def train(loss_fn: Callable, init_values, optimizer, data_fn: Callable,
          tcfg: TrainerConfig,
          shardings: Optional[Dict[str, Any]] = None,
          delay_injector: Optional[Callable[[int], float]] = None
          ) -> TrainResult:
    """data_fn(step) -> batch pytree; delay_injector simulates slow hosts."""
    # the train-state carries are donated to the jitted step (updated in
    # place, no double-buffering); copy the caller's init so their arrays
    # survive the first step — train(loss, init, ...) stays re-runnable.
    values = jax.tree.map(lambda x: jnp.array(x, copy=True), init_values)
    opt_state = optimizer.init(values)
    err = grad_compression.init_error(values)
    start_step = 0

    if tcfg.ckpt_dir and tcfg.resume:
        step = checkpointer.latest_step(tcfg.ckpt_dir)
        if step is not None:
            state_template = {"values": values, "opt": opt_state}
            restored, step, _ = checkpointer.restore(
                tcfg.ckpt_dir, step, template=state_template,
                shardings=shardings)
            values, opt_state = restored["values"], restored["opt"]
            start_step = step

    with_rng = tcfg.channel_rng_seed is not None
    step_fn = make_train_step(
        loss_fn, optimizer, microbatches=tcfg.microbatches,
        compress_k=tcfg.compress_k, with_rng=with_rng, donate=True)
    base_rng = (jax.random.PRNGKey(tcfg.channel_rng_seed) if with_rng
                else None)

    history: List[Dict[str, float]] = []
    substituted: List[int] = []
    flagged: List[int] = []
    durations: List[float] = []

    for step in range(start_step, tcfg.steps):
        t0 = tcfg.clock()
        if delay_injector is not None and tcfg.data_deadline_s is not None:
            delay = delay_injector(step)
            if delay > tcfg.data_deadline_s:
                # deadline missed: substitute the previous step's batch
                batch = data_fn(max(step - 1, 0))
                substituted.append(step)
            else:
                batch = data_fn(step)
        else:
            batch = data_fn(step)
        args = (values, opt_state, batch)
        if with_rng:
            args += (jax.random.fold_in(base_rng, step),)
        if tcfg.compress_k is not None:
            values, opt_state, err, metrics = step_fn(*args, err)
        else:
            values, opt_state, metrics = step_fn(*args)
        dt = tcfg.clock() - t0
        if durations and dt > tcfg.watchdog_factor * float(
                np.median(durations)):
            flagged.append(step)
        durations.append(dt)
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            row = {k: float(v) for k, v in metrics.items()
                   if jnp.ndim(v) == 0}
            row["step"] = step
            row["step_time_s"] = dt
            history.append(row)
        if (tcfg.ckpt_dir and tcfg.ckpt_every
                and (step + 1) % tcfg.ckpt_every == 0):
            checkpointer.save(tcfg.ckpt_dir, step + 1,
                              {"values": values, "opt": opt_state})

    if tcfg.ckpt_dir:
        checkpointer.save(tcfg.ckpt_dir, tcfg.steps,
                          {"values": values, "opt": opt_state})
    return TrainResult(values=values, opt_state=opt_state, history=history,
                       substituted_steps=substituted, straggler_flags=flagged,
                       final_step=tcfg.steps)
