"""Training loop with checkpoint/restart, straggler mitigation, and logging.

Fault-tolerance model (DESIGN.md §6):
  * auto-resume: on start, the newest COMMITted checkpoint (if any) is
    restored — a preempted job relaunches with the same command line;
  * index-derived data: batches are pure functions of (seed, step), so resume
    replays the exact stream with no data-loader state;
  * straggler mitigation: a per-step data deadline — a host that misses it
    substitutes the previous step's batch (deterministic, auditable via the
    `substituted_steps` log); a step-time watchdog flags slow steps for the
    launcher's eviction/elastic-re-mesh path;
  * elastic rescale: checkpoints are mesh-agnostic; `restore` takes target
    shardings (see checkpoint/checkpointer.py).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import numpy as np
import jax.numpy as jnp

from repro.checkpoint import checkpointer
from repro.optim import grad_compression
from repro.optim.compressed_allreduce import CompressedAllReduce
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    microbatches: int = 1
    # kept-fraction float (sugar for CompressedAllReduce.topk) or a full
    # CompressedAllReduce policy; compressed steps log dp_payload_bits
    compress_k: Optional[Union[float, CompressedAllReduce]] = None
    data_deadline_s: Optional[float] = None     # straggler: batch deadline
    watchdog_factor: float = 3.0                # step-time anomaly threshold
    resume: bool = True
    # stochastic-forward support (channel-in-the-loop training): when set,
    # loss_fn takes a third rng argument and each step receives a key derived
    # as fold_in(PRNGKey(seed), step) — resume replays the exact noise stream.
    channel_rng_seed: Optional[int] = None
    # auxiliary carried state (e.g. a repro.faults.FaultState: burst-chain
    # states, dropout masks, the stale-winner cache).  When set, loss_fn's
    # rng argument becomes the pair ``(key, aux)`` and its metrics must
    # return the evolved carry under ``metrics["aux_state"]``; the carry is
    # checkpointed and restored alongside params/opt state.  Requires
    # channel_rng_seed and microbatches == 1 (the microbatch rng-folding
    # machinery treats integer leaves as PRNG keys and would corrupt the
    # carry's int32/bool leaves).
    aux_state: Optional[Any] = None
    # save a checkpoint immediately when the step-time watchdog flags a
    # stall, so a subsequent relaunch resumes from right before the stall
    # instead of the last periodic checkpoint
    ckpt_on_stall: bool = False
    # the watchdog's clock, injectable so straggler detection can be driven
    # deterministically in tests (the loop itself never reads wall time)
    clock: Callable[[], float] = time.monotonic


@dataclasses.dataclass
class TrainResult:
    values: Any
    opt_state: Any
    history: List[Dict[str, float]]
    substituted_steps: List[int]
    straggler_flags: List[int]
    final_step: int
    aux_state: Any = None        # evolved TrainerConfig.aux_state carry


def train(loss_fn: Callable, init_values, optimizer, data_fn: Callable,
          tcfg: TrainerConfig,
          shardings: Optional[Dict[str, Any]] = None,
          delay_injector: Optional[Callable[[int], float]] = None
          ) -> TrainResult:
    """data_fn(step) -> batch pytree; delay_injector simulates slow hosts."""
    # the train-state carries are donated to the jitted step (updated in
    # place, no double-buffering); copy the caller's init so their arrays
    # survive the first step — train(loss, init, ...) stays re-runnable.
    values = jax.tree.map(lambda x: jnp.array(x, copy=True), init_values)
    opt_state = optimizer.init(values)
    err = grad_compression.init_error(values)
    aux = (jax.tree.map(lambda x: jnp.array(x, copy=True), tcfg.aux_state)
           if tcfg.aux_state is not None else None)
    if aux is not None:
        if tcfg.channel_rng_seed is None:
            raise ValueError("aux_state rides the per-step rng argument; "
                             "set channel_rng_seed")
        if tcfg.microbatches != 1:
            raise ValueError(
                "aux_state requires microbatches == 1: the microbatch "
                "rng-folding treats integer leaves as PRNG keys and would "
                "corrupt the carry's int32/bool leaves")
    start_step = 0

    def carry_state():
        """The FULL training carry — everything resume needs to continue
        bitwise-identically to an uninterrupted run: params, opt state,
        the error-feedback memory (compressed steps), and any auxiliary
        fault/stale caches."""
        state = {"values": values, "opt": opt_state}
        if tcfg.compress_k is not None:
            state["err"] = err
        if aux is not None:
            state["aux"] = aux
        return state

    if tcfg.ckpt_dir and tcfg.resume:
        step = checkpointer.latest_step(tcfg.ckpt_dir)
        if step is not None:
            restored, step, _ = checkpointer.restore(
                tcfg.ckpt_dir, step, template=carry_state(),
                shardings=shardings)
            values, opt_state = restored["values"], restored["opt"]
            err = restored.get("err", err)
            aux = restored.get("aux", aux)
            start_step = step

    with_rng = tcfg.channel_rng_seed is not None
    step_fn = make_train_step(
        loss_fn, optimizer, microbatches=tcfg.microbatches,
        compress_k=tcfg.compress_k, with_rng=with_rng, donate=True)
    base_rng = (jax.random.PRNGKey(tcfg.channel_rng_seed) if with_rng
                else None)

    history: List[Dict[str, float]] = []
    substituted: List[int] = []
    flagged: List[int] = []
    durations: List[float] = []

    for step in range(start_step, tcfg.steps):
        t0 = tcfg.clock()
        if delay_injector is not None and tcfg.data_deadline_s is not None:
            delay = delay_injector(step)
            if delay > tcfg.data_deadline_s:
                # deadline missed: substitute the previous step's batch
                batch = data_fn(max(step - 1, 0))
                substituted.append(step)
            else:
                batch = data_fn(step)
        else:
            batch = data_fn(step)
        args = (values, opt_state, batch)
        if with_rng:
            key = jax.random.fold_in(base_rng, step)
            args += ((key, aux) if aux is not None else key,)
        if tcfg.compress_k is not None:
            values, opt_state, err, metrics = step_fn(*args, err)
        else:
            values, opt_state, metrics = step_fn(*args)
        if aux is not None:
            metrics = dict(metrics)
            aux = metrics.pop("aux_state")
        dt = tcfg.clock() - t0
        if durations and dt > tcfg.watchdog_factor * float(
                np.median(durations)):
            flagged.append(step)
            if tcfg.ckpt_on_stall and tcfg.ckpt_dir:
                # stall detected: persist the full carry NOW so a relaunch
                # resumes from right before the stall, not the last
                # periodic checkpoint
                checkpointer.save(tcfg.ckpt_dir, step + 1, carry_state())
        durations.append(dt)
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            row = {k: float(v) for k, v in metrics.items()
                   if jnp.ndim(v) == 0}
            row["step"] = step
            row["step_time_s"] = dt
            history.append(row)
        if (tcfg.ckpt_dir and tcfg.ckpt_every
                and (step + 1) % tcfg.ckpt_every == 0):
            checkpointer.save(tcfg.ckpt_dir, step + 1, carry_state())

    if tcfg.ckpt_dir:
        checkpointer.save(tcfg.ckpt_dir, tcfg.steps, carry_state())
    return TrainResult(values=values, opt_state=opt_state, history=history,
                       substituted_steps=substituted, straggler_flags=flagged,
                       final_step=tcfg.steps, aux_state=aux)
